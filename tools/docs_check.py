"""Docs gate behind the CI ``docs-check`` job.

Two checks, both over the committed Markdown:

* every fenced ``python`` block in the top-level README is executed
  verbatim (CPU, ``timeout 120`` per block) — the quickstart is
  executable documentation, same standing as ``examples/``;
* every relative Markdown link in README.md, docs/ and
  src/repro/serving/README.md must resolve to a file in the tree —
  renames can't silently orphan the doc graph.

Run locally:  PYTHONPATH=src python tools/docs_check.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BLOCK_TIMEOUT_S = 120  # per fenced block, matching the examples-smoke cap

# files whose fenced python blocks must run (others are checked for
# links only — the serving README's blocks are illustrative fragments)
EXECUTE = ("README.md",)
LINK_CHECK = ("README.md", "docs", "src/repro/serving/README.md")

_FENCE = re.compile(r"```python\n(.*?)```", re.S)
# inline [text](target) links; images excluded via the (?<!!) lookbehind
_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")


def markdown_files() -> list[Path]:
    out: list[Path] = []
    for entry in LINK_CHECK:
        p = REPO / entry
        out.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    return out


def run_python_blocks(path: Path) -> list[str]:
    """Execute each fenced python block of ``path``; returns failures."""
    failures: list[str] = []
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    for i, block in enumerate(_FENCE.findall(path.read_text())):
        with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
            f.write(block)
            script = f.name
        try:
            proc = subprocess.run(
                [sys.executable, script],
                cwd=REPO,
                env=env,
                capture_output=True,
                text=True,
                timeout=BLOCK_TIMEOUT_S,
            )
            if proc.returncode != 0:
                failures.append(
                    f"{path.relative_to(REPO)} python block {i}: exit "
                    f"{proc.returncode}\n{proc.stderr.strip()[-2000:]}"
                )
            else:
                print(f"  block {i}: OK")
        except subprocess.TimeoutExpired:
            failures.append(
                f"{path.relative_to(REPO)} python block {i}: timed out "
                f"after {BLOCK_TIMEOUT_S}s"
            )
        finally:
            os.unlink(script)
    return failures


def check_links(path: Path) -> list[str]:
    """Every relative link in ``path`` must resolve; returns failures."""
    failures: list[str] = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        resolved = (path.parent / target.split("#", 1)[0]).resolve()
        if not resolved.exists():
            failures.append(f"{path.relative_to(REPO)}: dangling link -> {target}")
    return failures


def main() -> int:
    failures: list[str] = []
    for name in EXECUTE:
        print(f"executing python blocks of {name}")
        failures += run_python_blocks(REPO / name)
    for md in markdown_files():
        bad = check_links(md)
        failures += bad
        print(f"links {'FAIL' if bad else 'OK'}: {md.relative_to(REPO)}")
    if failures:
        print("\nDOCS CHECK FAILED:")
        for f in failures:
            print(" -", f)
        return 1
    print("docs check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Textual viewer for exported serving traces.

Reads a Chrome-trace JSON file written by ``Tracer.export`` (e.g. via
``EdgeCluster.export_trace`` or ``launch/serve.py --trace-out``) and
prints the per-phase latency breakdown: span counts and duration
percentiles by kind, per-server track activity, and the slowest
requests decomposed into their phases (queue wait vs prefill vs decode
vs cold-fetch stalls).

Run:  PYTHONPATH=src python tools/trace_view.py TRACE.json [--top N]

The viewer is dependency-free on purpose (stdlib only): it must load in
CI and on machines without the repo's accelerator stack.
"""

from __future__ import annotations

import argparse
import json
import sys

# phase printing order: request phases first, control plane after
KIND_ORDER = (
    "QUEUE_WAIT",
    "PREFILL_CHUNK",
    "DECODE_ROUND",
    "PREFIX_HIT",
    "SHED",
    "FAILOVER_REPREFILL",
    "COLD_FETCH_STALL",
    "PLACEMENT_REVIEW",
    "TRANSFER_TASK",
    "FAULT",
    "PREFETCH",
)


def _percentile(xs: list, q: float) -> float:
    """Nearest-rank percentile over a sorted copy (stdlib-only)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(round(q / 100.0 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome-trace document")
    return doc


def spans(doc: dict) -> list:
    """The complete ('X') events, in file order."""
    return [e for e in doc["traceEvents"] if e.get("ph") == "X"]


def phase_table(doc: dict) -> list:
    """Rows of (kind, count, total_ms, mean_ms, p50_ms, p99_ms)."""
    by_kind: dict = {}
    for e in spans(doc):
        by_kind.setdefault(e["name"], []).append(e["dur"] / 1e3)
    rows = []
    known = [k for k in KIND_ORDER if k in by_kind]
    extra = sorted(k for k in by_kind if k not in KIND_ORDER)
    for kind in known + extra:
        ds = by_kind[kind]
        rows.append(
            (
                kind,
                len(ds),
                sum(ds),
                sum(ds) / len(ds),
                _percentile(ds, 50),
                _percentile(ds, 99),
            )
        )
    return rows


def request_table(doc: dict, top: int = 10) -> list:
    """The ``top`` requests by total recorded span time, each row:
    (rid, total_ms, {kind: ms})."""
    by_rid: dict = {}
    for e in spans(doc):
        rid = e.get("args", {}).get("rid", -1)
        if rid < 0:
            continue
        phases = by_rid.setdefault(rid, {})
        phases[e["name"]] = phases.get(e["name"], 0.0) + e["dur"] / 1e3
    rows = [(rid, sum(ph.values()), ph) for rid, ph in by_rid.items()]
    rows.sort(key=lambda r: (-r[1], r[0]))
    return rows[:top]


def server_table(doc: dict) -> list:
    """Per-track rows of (name, events, busy_ms) from the thread
    metadata plus each track's span activity."""
    names = {}
    for e in doc["traceEvents"]:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[e["tid"]] = e["args"]["name"]
    stats: dict = {}
    for e in spans(doc):
        n, busy = stats.get(e["tid"], (0, 0.0))
        stats[e["tid"]] = (n + 1, busy + e["dur"] / 1e3)
    return [
        (names.get(tid, f"tid{tid}"), n, busy)
        for tid, (n, busy) in sorted(stats.items())
    ]


def render(doc: dict, top: int = 10) -> str:
    """The full textual report for one trace document."""
    other = doc.get("otherData", {})
    unit = "tick(ms)" if other.get("clock") == "ticks" else "ms"
    out = [
        f"trace: {other.get('spans', len(spans(doc)))} spans, "
        f"clock={other.get('clock', '?')}, "
        f"dropped={other.get('dropped', 0)}",
        "",
        f"{'phase':<20}{'count':>7}{'total':>12}{'mean':>10}"
        f"{'p50':>10}{'p99':>10}   [{unit}]",
    ]
    for kind, n, tot, mean, p50, p99 in phase_table(doc):
        out.append(
            f"{kind:<20}{n:>7}{tot:>12.3f}{mean:>10.3f}{p50:>10.3f}{p99:>10.3f}"
        )
    out += ["", f"{'track':<20}{'events':>7}{'busy':>12}   [{unit}]"]
    for name, n, busy in server_table(doc):
        out.append(f"{name:<20}{n:>7}{busy:>12.3f}")
    reqs = request_table(doc, top)
    if reqs:
        out += ["", f"slowest {len(reqs)} requests by recorded span time:"]
        for rid, tot, phases in reqs:
            detail = "  ".join(
                f"{k}={phases[k]:.3f}" for k in KIND_ORDER if k in phases
            )
            out.append(f"  rid {rid:<6} {tot:>10.3f}  {detail}")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="print the per-phase latency breakdown of an "
        "exported serving trace"
    )
    ap.add_argument("trace", help="path to a Tracer.export JSON file")
    ap.add_argument(
        "--top",
        type=int,
        default=10,
        help="how many slowest requests to decompose (default 10)",
    )
    args = ap.parse_args(argv)
    print(render(load(args.trace), top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Radix prefix cache payoff on an 80%-shared-prefix workload at *equal KV
memory*: prefill compute (chunks executed) and admitted concurrency, cache
on vs off.

Edge request streams are dominated by shared system prompts / few-shot
templates; with the cache on, the shared block-aligned prefix is prefilled
once and every later family member acquires the cached pages (refcount)
instead of recomputing and re-storing them — less prefill compute *and*
less KV memory per request, which turns directly into admitted concurrency
on a tight pool.

  PYTHONPATH=src python -m benchmarks.prefix_cache [--csv]

Prints ``prefix_cache,<case>,<value>`` CSV lines and asserts the >= 2x
prefill-compute reduction target. ``smoke()`` returns the same measurement
on a smaller stream as the ``BENCH_serving.json`` document for the CI
``bench-smoke`` job (see ``benchmarks/schema.py`` for the contract); since
``bench-serving/v2`` the document also carries the per-server
admitted/locality/routing metrics of an ``EdgeCluster`` run
(``cluster_smoke``: 3 paper-testbed servers, typed API request stream,
DanceMoE controller — since v3 with the testbed lifted into a
``serving.net.Topology``, so the section also reports the heterogeneous
per-server memory caps; the ``metrics.net`` link/migration section comes
from ``benchmarks.topology``; since v4 a third serving leg runs with
``warmup=True`` — AOT bucket-ladder compile + zero-stall loop — and fills
``metrics.perf`` with the warmup cost, retrace/stall counters and
decode-round/TTFT percentiles). The
CPU test config (mixtral-8x7b reduced, dense MoE impl — identical
attention/paging code paths, no shard_map overhead) runs anywhere tier-1
runs.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.schema import SCHEMA_NAME
from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime

CLUSTER_REQUESTS = 30

MAX_LEN = 64
BLOCK_SIZE = 8
SHARED, TAIL, STEPS = 40, 8, 4     # 40-token shared system prompt + tail
SHARED_FRAC = 0.8                  # 80% of the stream is one prompt family
ARRIVALS_PER_TICK = 2              # staggered stream (edge arrival process)


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    return ServingEngine(rt=rt, params=params, placement=None,
                         max_len=MAX_LEN)


def build_stream(vocab: int, n_requests: int):
    """80% shared-prefix family members (unique tails), 20% disjoint."""
    src = TaskTokenSource("prefix", vocab, seed=0)
    shared = src.sample(1, SHARED)[0]
    prompts = []
    for k in range(n_requests):
        if k < SHARED_FRAC * n_requests:
            tail = TaskTokenSource("prefix", vocab,
                                   seed=100 + k).sample(1, TAIL)[0]
            prompts.append(np.concatenate([shared, tail]))
        else:
            p = TaskTokenSource("prefix", vocab,
                                seed=500 + k).sample(1, SHARED + TAIL)[0]
            p[0] = (k + 1) % vocab          # disjoint first block
            prompts.append(p)
    return prompts


def serve(rtm: ServingRuntime, prompts, steps: int) -> dict:
    """Staggered submission; per-tick wall latency doubles as the decode
    round latency (one shared decode round per tick)."""
    submitted, tick_s = {}, []
    queue = list(prompts)
    tick = 0
    while queue or rtm.queue or rtm.active or rtm._pending:
        for p in queue[:ARRIVALS_PER_TICK]:
            h = rtm.enqueue(Request(prompt=p, max_new_tokens=steps))
            submitted[h.rid] = tick
        queue = queue[ARRIVALS_PER_TICK:]
        t0 = time.perf_counter()
        rtm.step()
        tick_s.append(time.perf_counter() - t0)
        tick += 1
    rtm.flush()            # zero-stall loop: apply any still-pending round
    lat = [rtm.finished_at[r] - t0_tick for r, t0_tick in submitted.items()]
    return {
        "peak_admitted": rtm.max_admitted,
        "peak_decode_batch": rtm.max_concurrency,
        "chunks_executed": rtm.chunks_executed,
        "prefill_calls": rtm.prefill_calls,
        "prefix_hits": rtm.prefix_hits,
        "prefix_tokens_skipped": rtm.prefix_tokens_skipped,
        "cow_copies": rtm.cow_copies,
        "deferrals": rtm.deferrals,
        "mean_latency_ticks": float(np.mean(lat)),
        "p95_latency_ticks": float(np.percentile(lat, 95)),
        "decode_round_s_mean": float(np.mean(tick_s)),
        "decode_round_s_p95": float(np.percentile(tick_s, 95)),
    }


def measure(eng, n_requests: int, n_blocks: int, max_slots: int):
    """cache-off / cache-on legs (the v1 comparison) plus the AOT-warmed
    zero-stall leg whose perf counters fill ``metrics.perf`` (v4)."""
    prompts = build_stream(eng.rt.cfg.vocab_size, n_requests)
    out = {}
    for label, opts in (
            ("nocache", {"prefix_cache": False}),
            ("cache", {"prefix_cache": True}),
            ("warm", {"prefix_cache": True, "warmup": True,
                      "warmup_origins": "untagged"})):
        rtm = ServingRuntime(eng, max_slots=max_slots,
                             block_size=BLOCK_SIZE, n_blocks=n_blocks,
                             **opts)
        out[label] = serve(rtm, prompts, STEPS)
        if label == "warm":
            out["perf"] = rtm.perf_metrics()
    return out


def cluster_smoke(n_requests: int = CLUSTER_REQUESTS) -> dict:
    """The ``metrics.cluster`` section of ``bench-serving/v2``: per-server
    admitted/locality/routing metrics emitted by a 3-server ``EdgeCluster``
    (sim backend — the numpy time model keeps the CI gate fast) serving a
    typed API request stream under a DanceMoE controller."""
    from repro.core.policies import (ClusterView, PlacementController,
                                     get_policy)
    from repro.data.traces import BIGBENCH_TASKS
    from repro.serving.cluster import (DEEPSEEK_V2_LITE_PROFILE, EdgeCluster,
                                       paper_testbed)
    from repro.serving.net import Topology

    pf = DEEPSEEK_V2_LITE_PROFILE
    spec = paper_testbed(mem_fraction=0.3)
    # the testbed's heterogeneous memory profiles (server3 has 2x), lifted
    # into the topology/link model both backends share since v3
    topo = Topology.from_cluster_spec(spec)
    ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=None,
        cluster=ClusterView.from_cluster(spec, pf), interval=30.0)
    ec = EdgeCluster("sim", spec=spec, profile=pf, controller=ctrl, seed=0,
                     topology=topo)
    rng = np.random.default_rng(0)
    t = 0.0
    for k in range(n_requests):
        t += float(rng.exponential(5.0))
        origin = k % spec.n
        ec.submit(Request(
            prompt=np.zeros(max(int(rng.normal(128, 32)), 8), np.int32),
            max_new_tokens=20, origin=origin, arrival=t,
            task=BIGBENCH_TASKS[origin]))
    ec.run()
    m = ec.metrics()
    return {
        "n_servers": m["n_servers"],
        # admitted is per *origin* (submitted), routed is per *serving*
        # server — independent signals once the router redirects traffic
        "per_server_admitted": m["per_server"]["submitted"],
        "per_server_routed": m["per_server"]["served"],
        "per_server_local_ratio": m["per_server"]["local_ratio"],
        "redirected_total": m["redirected_total"],
        "per_server_mem_gb": m["net"]["per_server_mem_gb"],
    }


def to_bench_doc(r: dict, *, mode: str, n_requests: int,
                 n_blocks: int, cluster: dict) -> dict:
    """Shape the measurement as the ``BENCH_serving.json`` document (see
    ``benchmarks.schema`` for the required fields)."""
    chunk_ratio = r["nocache"]["chunks_executed"] / max(
        r["cache"]["chunks_executed"], 1)
    return {
        "schema": SCHEMA_NAME,
        "mode": mode,
        "config": {
            "arch": "mixtral-8x7b(reduced)",
            "requests": n_requests,
            "shared_frac": SHARED_FRAC,
            "block_size": BLOCK_SIZE,
            "n_blocks": n_blocks,
            "prompt_tokens": SHARED + TAIL,
            "decode_steps": STEPS,
        },
        "metrics": {
            "admitted_concurrency": {
                "cache": r["cache"]["peak_admitted"],
                "nocache": r["nocache"]["peak_admitted"],
            },
            "prefill_chunks_executed": {
                "cache": r["cache"]["chunks_executed"],
                "nocache": r["nocache"]["chunks_executed"],
            },
            "prefill_chunk_reduction": chunk_ratio,
            "prefix_hits": r["cache"]["prefix_hits"],
            "prefill_tokens_skipped": r["cache"]["prefix_tokens_skipped"],
            "cow_copies": r["cache"]["cow_copies"],
            "deferrals": {
                "cache": r["cache"]["deferrals"],
                "nocache": r["nocache"]["deferrals"],
            },
            "decode_round_latency_s": {
                "mean": r["cache"]["decode_round_s_mean"],
                "p95": r["cache"]["decode_round_s_p95"],
            },
            "mean_latency_ticks": {
                "cache": r["cache"]["mean_latency_ticks"],
                "nocache": r["nocache"]["mean_latency_ticks"],
            },
            "cluster": cluster,
            # v4: AOT bucket-ladder warmup + zero-stall loop counters from
            # the warmed serving leg
            "perf": r["perf"],
        },
    }


def smoke() -> dict:
    """Tiny CI-gate measurement (<5 min on a CPU runner): returns the
    ``BENCH_serving.json`` document."""
    eng = build_engine()
    n_requests, n_blocks, max_slots = 10, 15, 8
    r = measure(eng, n_requests, n_blocks, max_slots)
    return to_bench_doc(r, mode="smoke", n_requests=n_requests,
                        n_blocks=n_blocks, cluster=cluster_smoke())


def main(csv: bool = False):
    eng = build_engine()
    n_requests, n_blocks, max_slots = 20, 15, 8
    r = measure(eng, n_requests, n_blocks, max_slots)
    doc = to_bench_doc(r, mode="full", n_requests=n_requests,
                       n_blocks=n_blocks, cluster=cluster_smoke())
    m = doc["metrics"]
    ratio = m["prefill_chunk_reduction"]
    print(f"# {int(SHARED_FRAC * 100)}%-shared-prefix stream, "
          f"{n_requests} requests, pool {n_blocks - 1}x{BLOCK_SIZE} "
          f"(equal KV memory)")
    for label in ("nocache", "cache"):
        s = r[label]
        print(f"{label:8s}: chunks={s['chunks_executed']} "
              f"calls={s['prefill_calls']} "
              f"peak_admitted={s['peak_admitted']} "
              f"mean_latency={s['mean_latency_ticks']:.1f} ticks "
              f"deferrals={s['deferrals']}")
    p = m["perf"]
    print(f"warm    : aot={p['executables_compiled']} exes in "
          f"{p['warmup_seconds']:.1f}s "
          f"retraces={p['traces_after_warmup']} stalls={p['host_syncs']} "
          f"decode_round_ms p50={p['decode_round_ms']['p50']:.2f} "
          f"p99={p['decode_round_ms']['p99']:.2f} "
          f"ttft_ms p50={p['ttft_ms']['p50']:.2f}")
    print(f"prefill-compute reduction: {ratio:.1f}x "
          f"({'>= 2x OK' if ratio >= 2 else 'BELOW TARGET'}); "
          f"admitted concurrency {m['admitted_concurrency']['nocache']} -> "
          f"{m['admitted_concurrency']['cache']}; "
          f"{m['prefill_tokens_skipped']} prompt tokens skipped via "
          f"{m['prefix_hits']} hits ({m['cow_copies']} CoW clones)")
    if csv:
        print(f"prefix_cache,chunk_reduction,{ratio:.2f}")
        print(f"prefix_cache,cache_peak_admitted,"
              f"{m['admitted_concurrency']['cache']}")
        print(f"prefix_cache,nocache_peak_admitted,"
              f"{m['admitted_concurrency']['nocache']}")
        print(f"prefix_cache,tokens_skipped,{m['prefill_tokens_skipped']}")
    assert ratio >= 2.0, (
        f"prefix cache cut prefill chunks only {ratio:.2f}x on the "
        f"{int(SHARED_FRAC * 100)}%-shared stream (target: 2x)")
    assert (m["admitted_concurrency"]["cache"]
            >= m["admitted_concurrency"]["nocache"]), \
        "prefix sharing should never lower admitted concurrency"


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Fig. 7: migration effectiveness under a workload shift — 200 MultiData
requests/server followed by 200 BIG-bench requests/server, DeepSeek-V2-Lite,
migration-enabled vs static placement."""
from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_testbed, MODELS
from repro.core.migration import CostModel
from repro.core.placement import dancemoe_placement
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.traces import (BIGBENCH_TASKS, MULTIDATA_TASKS, Request,
                               Workload, poisson_workload)
from repro.serving.simulator import EdgeSimulator


def shifted_workload(pf, n_requests: int = 200, inter: float = 6.0,
                     seed: int = 0):
    dur = n_requests * inter
    wl1 = poisson_workload(list(MULTIDATA_TASKS), num_layers=pf.num_layers,
                           num_experts=pf.num_experts,
                           mean_interarrival=inter, duration=dur, seed=seed)
    wl2 = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                           num_experts=pf.num_experts,
                           mean_interarrival=inter, duration=dur,
                           seed=seed + 1)
    reqs = wl1.requests + [Request(r.arrival + dur, r.server, r.task,
                                   r.prompt_tokens, r.decode_tokens)
                           for r in wl2.requests]
    return Workload(requests=reqs, tasks={**wl1.tasks, **wl2.tasks},
                    duration=2 * dur), dur


def run(seed: int = 1):
    pf, frac = MODELS["deepseek-v2-lite"]
    cl = calibrated_testbed(frac)
    wl, shift_t = shifted_workload(pf)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    cm = CostModel(expert_bytes=pf.expert_bytes,
                   activation_bytes=128 * pf.hidden_bytes_per_token,
                   bandwidth=cl.bandwidth,
                   io_speed=np.array([s.io_speed for s in cl.servers]),
                   tokens_per_horizon=2e4)
    # static ("w/o"): placed from phase-1 statistics only
    phase1 = Workload(requests=[r for r in wl.requests
                                if r.arrival < shift_t],
                      tasks=wl.tasks, duration=shift_t)
    static_plan = dancemoe_placement(phase1.freqs_by_server(cl.n), cap,
                                     slots)
    r_wo = EdgeSimulator(cl, pf, wl, plan=static_plan, seed=seed).run()
    ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView(capacity=cap, slots_cap=slots), interval=300.0)
    r_w = EdgeSimulator(cl, pf, wl, controller=ctrl, seed=seed).run()
    return r_wo, r_w, wl, shift_t


def main(csv: bool = False):
    r_wo, r_w, wl, shift_t = run()
    arr = np.array([q.arrival for q in wl.requests])
    rows = [
        ("avg_latency_w/o_migration", round(r_wo.avg_latency, 3)),
        ("avg_latency_w/_migration", round(r_w.avg_latency, 3)),
        ("phase2_latency_w/o", round(float(
            r_wo.latencies[arr >= shift_t].mean()), 3)),
        ("phase2_latency_w/", round(float(
            r_w.latencies[arr >= shift_t].mean()), 3)),
        ("migrations", len(r_w.migrations)),
        ("migration_times_s", [round(m["time"]) for m in r_w.migrations]),
    ]
    for k, v in rows:
        print(f"fig7,{k},{v}" if csv else f"{k:28s} {v}")
    assert r_w.avg_latency < r_wo.avg_latency        # paper: ~10% reduction
    assert len(r_w.migrations) >= 1
    return rows


if __name__ == "__main__":
    main()

"""Unified-observability benchmark: one traced run, every span source.

The scenario stacks every event source the span tracer covers into ONE
sim-backend run over the tiered WAN testbed of ``benchmarks.tiers``:
sharply skewed task profiles with the mid-run shift (placement reviews
-> a staged migration -> per-link ``TRANSFER_TASK`` spans), host-RAM
expert tiers with activation-aware prefetch (``PREFETCH`` /
``COLD_FETCH_STALL`` spans), and a timed WAN-link brownout from a
``FaultSchedule`` (``FAULT`` spans) — plus the per-request
``QUEUE_WAIT`` / ``PREFILL_CHUNK`` / ``DECODE_ROUND`` phases of every
served request.

The leg runs the scenario twice and the two exported Chrome-trace
documents must be **byte-identical** — the determinism contract of
``repro.serving.obs`` (span records carry model-clock times and
sequence numbers only; the wall clock never enters the export).

Reported (``metrics.obs`` of ``BENCH_serving.json``, schema
``bench-serving/v8``): span counts by kind, total events, the dropped
counter (gated == 0), the tracer's wall-clock recording overhead, and
``replay_identical`` (gated == 1). ``smoke(trace_out=...)`` also writes
the exported trace — the CI artifact uploaded next to
``BENCH_serving.json`` and schema-checked by ``validate_trace_doc``.

  PYTHONPATH=src python -m benchmarks.obs [--csv]
"""

from __future__ import annotations

import json
import sys

from benchmarks.tiers import _primed_stats, _sharp_task_profile, tiered_testbed
from benchmarks.topology import BENCH_PROFILE, build_requests
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.cluster import EdgeCluster
from repro.serving.faults import FaultSchedule
from repro.serving.net import CommCostModel

# WAN-link brownout window (seconds, sim clock): opens inside the
# serving span of the request stream, restored before the tail drains
BROWNOUT = dict(time=8.0, src=0, dst=2, factor=0.3, restore_at=30.0)

# every span kind the scenario must produce at least once (the bench is
# worthless if a source silently stops emitting)
EXPECTED_KINDS = (
    "QUEUE_WAIT",
    "PREFILL_CHUNK",
    "DECODE_ROUND",
    "PLACEMENT_REVIEW",
    "TRANSFER_TASK",
    "FAULT",
    "PREFETCH",
)


def run_leg(n_requests: int, seed: int = 0) -> dict:
    """One traced pass over the faulted + migrating + tiered scenario;
    returns the obs metrics plus the exported trace bytes."""
    pf = BENCH_PROFILE
    topo = tiered_testbed()
    cm = CommCostModel(
        topology=topo,
        expert_bytes=pf.expert_bytes,
        activation_bytes=pf.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, pf, tiered=True),
        interval=20.0,
        topology=topo,
        stats=_primed_stats(topo, pf, seed),
    )
    ec = EdgeCluster(
        "sim",
        topology=topo,
        profile=pf,
        controller=ctrl,
        seed=seed,
        fault_schedule=FaultSchedule.link_brownout(**BROWNOUT),
        trace=True,
    )
    for t in range(2 * topo.n):
        name = f"task{t}"
        ec.backend.workload.tasks[name] = _sharp_task_profile(name, t, pf, seed)
    for r in build_requests(n_requests, 3, seed=seed):
        ec.submit(r)
    handles = ec.run()
    # the export's exact byte form: what Tracer.export writes to disk
    trace = json.dumps(ec.tracer.to_trace_doc(), sort_keys=True, indent=1) + "\n"
    return {
        "obs": ec.metrics()["obs"],
        "trace": trace,
        "completed": sum(1 for h in handles if h.done),
        "n_requests": len(handles),
        "cluster_events": len(ec.events),
    }


def measure(n_requests: int, seed: int = 0) -> dict:
    """The traced run and its replay (byte-identity check)."""
    first = run_leg(n_requests, seed)
    replay = run_leg(n_requests, seed)
    return {
        "first": first,
        "replay_identical": int(first["trace"] == replay["trace"]),
    }


def obs_section(results: dict) -> dict:
    """The ``metrics.obs`` section (since ``bench-serving/v8``)."""
    out = dict(results["first"]["obs"])
    out["replay_identical"] = results["replay_identical"]
    return out


def smoke(n_requests: int = 40, trace_out: str | None = None) -> dict:
    """Small CI-gate measurement: the ``metrics.obs`` document section,
    with the tracing acceptance gates asserted. ``trace_out`` writes the
    exported trace (the artifact the CI job validates and uploads)."""
    results = measure(n_requests)
    first = results["first"]
    obs = first["obs"]
    assert first["completed"] == first["n_requests"], (
        f"traced run incomplete ({first['completed']}/{first['n_requests']})"
    )
    assert obs["dropped_events"] == 0, (
        f"tracer dropped {obs['dropped_events']} events — raise max_events"
    )
    for kind in EXPECTED_KINDS:
        assert obs["span_counts"].get(kind, 0) >= 1, (
            f"no {kind} spans recorded — an emission source went silent"
        )
    assert results["replay_identical"] == 1, (
        "rerunning the faulted + migrating + tiered scenario must export "
        "a byte-identical trace"
    )
    if trace_out is not None:
        with open(trace_out, "w") as f:
            f.write(first["trace"])
    return obs_section(results)


def main(csv: bool = False):
    n_requests = 60
    results = measure(n_requests)
    first = results["first"]
    obs = first["obs"]
    print(
        f"# unified tracing: {obs['events']} spans over "
        f"{first['n_requests']} requests "
        f"(clock={obs['clock']}, dropped={obs['dropped_events']}, "
        f"overhead={obs['overhead_ms']:.2f}ms wall)"
    )
    print(f"{'span kind':22s} {'count':>7s}")
    for kind, n in sorted(obs["span_counts"].items()):
        print(f"{kind:22s} {n:7d}")
    print(
        f"cluster events (seq-stamped): {first['cluster_events']}, "
        f"replay byte-identical: {bool(results['replay_identical'])}"
    )
    if csv:
        for kind, n in sorted(obs["span_counts"].items()):
            print(f"obs,spans_{kind},{n}")
        print(f"obs,replay_identical,{results['replay_identical']}")
    assert results["replay_identical"] == 1


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Benchmark driver: one function per paper table/figure.
Prints ``name,case,value`` CSV lines (plus human-readable sections)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    import benchmarks.table1 as table1
    import benchmarks.table2 as table2
    import benchmarks.fig5 as fig5
    import benchmarks.fig6 as fig6
    import benchmarks.fig7 as fig7
    import benchmarks.fig8 as fig8
    import benchmarks.paged_pool as paged_pool
    import benchmarks.roofline_table as roofline_table

    csv = "--csv" in sys.argv
    for name, fn in [
        ("Table I  (offload vs collaboration)", table1.main),
        ("Table II (5 methods x 2 models x 2 workloads)", table2.main),
        ("Fig. 5   (latency vs remote fraction)", fig5.main),
        ("Fig. 6   (local compute ratio over time)", fig6.main),
        ("Fig. 7   (migration under workload shift)", fig7.main),
        ("Fig. 8   (scalability + bandwidth)", fig8.main),
        ("Roofline (single-pod dry-run)", roofline_table.main),
        ("Paged KV pool (occupancy + latency-vs-blocks)", paged_pool.main),
    ]:
        t0 = time.time()
        print(f"\n##### {name}")
        fn(csv=csv)
        print(f"##### done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

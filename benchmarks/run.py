"""Benchmark driver.

Default mode runs one function per paper table/figure and prints
``name,case,value`` CSV lines (plus human-readable sections).

``--smoke`` is the CI gate (``bench-smoke`` job): a tiny CPU serving
benchmark (<5 min) whose results are written — schema-validated — to
``BENCH_serving.json`` (``--out`` overrides the path), alongside the
exported span trace of the observability leg (``--trace-out``, default
``BENCH_trace.json``; Chrome-trace JSON, schema-checked by
``validate_trace_doc``). The process exits non-zero when either
document is schema-invalid or empty, so perf numbers land in every CI
run or the gate fails loudly.

  PYTHONPATH=src python -m benchmarks.run [--csv]
  PYTHONPATH=src python -m benchmarks.run --smoke [--out BENCH_serving.json]
      [--trace-out BENCH_trace.json]

Field-by-field documentation of every ``metrics.*`` section in the
emitted document lives in docs/benchmarks.md.
"""

from __future__ import annotations

import json
import sys
import time


def smoke(out_path: str, trace_path: str = "BENCH_trace.json") -> None:
    import benchmarks.failover as failover
    import benchmarks.obs as obs
    import benchmarks.prefix_cache as prefix_cache
    import benchmarks.tiers as tiers
    import benchmarks.topology as topology
    import benchmarks.workload as workload
    from benchmarks.schema import validate_bench_serving, validate_trace_doc

    t0 = time.time()
    doc = prefix_cache.smoke()
    doc["metrics"]["net"] = topology.smoke()  # v3: non-uniform-topology
    #   run (per-link dispatch bytes, staged-migration transfer totals)
    doc["metrics"]["faults"] = failover.smoke()  # v5: mid-run crash +
    #   failover vs no-failover baseline, deterministic replay asserted
    doc["metrics"]["tiers"] = tiers.smoke()  # v6: oversized model over
    #   host-RAM expert tiers, prefetch vs frozen residency
    doc["metrics"]["workload"] = workload.smoke()  # v7: seeded flash-crowd
    #   stream, SLO-aware scheduling vs blind FIFO goodput on it
    doc["metrics"]["obs"] = obs.smoke(trace_out=trace_path)  # v8: traced
    #   faults+migration+tiers run, byte-identical replay, trace artifact
    doc["elapsed_s"] = round(time.time() - t0, 2)
    validate_bench_serving(doc)  # raises (non-zero exit) on breakage
    with open(trace_path) as f:
        validate_trace_doc(json.load(f))  # the uploaded trace artifact
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    m = doc["metrics"]
    print(
        f"wrote {out_path} in {doc['elapsed_s']}s: "
        f"chunk_reduction={m['prefill_chunk_reduction']:.2f}x "
        f"admitted {m['admitted_concurrency']['nocache']} -> "
        f"{m['admitted_concurrency']['cache']} "
        f"decode_round={m['decode_round_latency_s']['mean'] * 1e3:.1f}ms"
    )
    c = m["cluster"]
    print(
        f"cluster[v2]: {int(c['n_servers'])} servers "
        f"admitted={c['per_server_admitted']} "
        f"local_ratio={c['per_server_local_ratio']} "
        f"redirected={int(c['redirected_total'])}"
    )
    n = m["net"]
    print(
        f"net[v3]: cross_server={n['cross_server_bytes']:.3g}B "
        f"(uniform {n['cross_server_bytes_by_policy']['uniform']:.3g}B) "
        f"migrations={int(n['migrations_completed'])} "
        f"transfer={n['migration_transfer_seconds']:.3g}s "
        f"mem_gb={n['per_server_mem_gb']}"
    )
    p = m["perf"]
    print(
        f"perf[v4]: warmup={p['warmup_seconds']:.1f}s "
        f"({int(p['executables_compiled'])} executables) "
        f"retraces={int(p['traces_after_warmup'])} "
        f"stalls={int(p['host_syncs'])} "
        f"decode_round_ms p50={p['decode_round_ms']['p50']:.2f} "
        f"p99={p['decode_round_ms']['p99']:.2f} "
        f"ttft_ms p50={p['ttft_ms']['p50']:.2f}"
    )
    fl = m["faults"]
    print(
        f"faults[v5]: injected={int(fl['injected'])} "
        f"recovered={int(fl['recovered'])} "
        f"recovery={fl['recovery_seconds']:.3g}s "
        f"tokens_lost={int(fl['tokens_lost'])} "
        f"(baseline {int(fl['baseline_tokens_lost'])}) "
        f"replay_identical={int(fl['replay_identical'])}"
    )
    t = m["tiers"]
    print(
        f"tiers[v6]: gpu_slots={t['per_server_gpu_slots']} "
        f"promotions={int(t['promotions'])} "
        f"hit_ratio={t['prefetch_hit_ratio']:.3f} "
        f"stall={t['on_demand_stall_seconds']:.3g}s "
        f"(no-prefetch {t['prefetch_off_stall_seconds']:.3g}s) "
        f"latency={t['mean_latency_s']:.4f}s "
        f"(no-prefetch {t['prefetch_off_mean_latency_s']:.4f}s)"
    )
    w = m["workload"]
    print(
        f"workload[v7]: {int(w['requests'])} requests "
        f"goodput={w['goodput_tokens_per_s']:.1f}tok/s "
        f"(fifo {w['fifo_goodput_tokens_per_s']:.1f}) "
        f"attainment={w['slo_attainment']:.3f} "
        f"sheds={int(w['sheds'])} "
        f"flash_migrations={int(w['flash_migrations'])} "
        f"replay_identical={int(w['replay_identical'])}"
    )
    o = m["obs"]
    print(
        f"obs[v8]: {int(o['events'])} spans over "
        f"{len(o['span_counts'])} kinds "
        f"dropped={int(o['dropped_events'])} "
        f"overhead={o['overhead_ms']:.1f}ms "
        f"replay_identical={int(o['replay_identical'])} "
        f"trace={trace_path}"
    )


def main() -> None:
    if "--help" in sys.argv or "-h" in sys.argv:
        print(__doc__)
        return
    if "--smoke" in sys.argv:
        out = "BENCH_serving.json"
        trace = "BENCH_trace.json"
        usage = (
            "usage: benchmarks.run --smoke [--out PATH] [--trace-out PATH]"
        )
        if "--out" in sys.argv:
            i = sys.argv.index("--out")
            if i + 1 >= len(sys.argv):
                sys.exit(usage)
            out = sys.argv[i + 1]
        if "--trace-out" in sys.argv:
            i = sys.argv.index("--trace-out")
            if i + 1 >= len(sys.argv):
                sys.exit(usage)
            trace = sys.argv[i + 1]
        smoke(out, trace)
        return

    import benchmarks.failover as failover
    import benchmarks.fig5 as fig5
    import benchmarks.obs as obs
    import benchmarks.fig6 as fig6
    import benchmarks.fig7 as fig7
    import benchmarks.fig8 as fig8
    import benchmarks.paged_pool as paged_pool
    import benchmarks.prefix_cache as prefix_cache
    import benchmarks.roofline_table as roofline_table
    import benchmarks.table1 as table1
    import benchmarks.table2 as table2
    import benchmarks.tiers as tiers
    import benchmarks.topology as topology
    import benchmarks.workload as workload

    csv = "--csv" in sys.argv
    for name, fn in [
        ("Table I  (offload vs collaboration)", table1.main),
        ("Table II (5 methods x 2 models x 2 workloads)", table2.main),
        ("Fig. 5   (latency vs remote fraction)", fig5.main),
        ("Fig. 6   (local compute ratio over time)", fig6.main),
        ("Fig. 7   (migration under workload shift)", fig7.main),
        ("Fig. 8   (scalability + bandwidth)", fig8.main),
        ("Roofline (single-pod dry-run)", roofline_table.main),
        ("Paged KV pool (occupancy + latency-vs-blocks)", paged_pool.main),
        ("Prefix cache (chunk reduction + concurrency)", prefix_cache.main),
        ("Topology  (non-uniform links, staged migration)", topology.main),
        ("Failover  (mid-run crash, recovery vs baseline)", failover.main),
        ("Tiers     (oversized model, host-RAM expert tiers)", tiers.main),
        ("Workload  (flash-crowd stream, SLO goodput)", workload.main),
        ("Obs       (unified tracing, byte-identical replay)", obs.main),
    ]:
        t0 = time.time()
        print(f"\n##### {name}")
        fn(csv=csv)
        print(f"##### done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

"""Schema contract for the serving benchmark trajectory
(``BENCH_serving.json``, produced by ``benchmarks/run.py --smoke`` and
gated by the CI ``bench-smoke`` job).

The document is intentionally small and versioned: every CI run uploads
one, so schema breaks show up as a failed gate — not as a silently empty
perf history. Validation is dependency-free (no jsonschema install on the
runner)."""

from __future__ import annotations

SCHEMA_NAME = "bench-serving/v8"

# metric key -> ("scalar" | "pair" | "stats") shape requirement.
# v2 extended v1 (same keys, same shapes) with the EdgeCluster section;
# v3 adds the heterogeneous-topology section (``metrics.net``) and the
# per-server profile caps; v4 adds the AOT warmup / zero-stall section
# (``metrics.perf``); v5 adds the fault-injection/failover section
# (``metrics.faults``); v6 adds the expert tier hierarchy section
# (``metrics.tiers``); v7 adds the streaming-workload / SLO-scheduling
# section (``metrics.workload``); v8 adds the unified-observability
# section (``metrics.obs``) and the exported-trace artifact contract
# (``validate_trace_doc``) — extend, don't fork, when adding serving
# metrics.
# Field-by-field documentation: docs/benchmarks.md.
_REQUIRED_METRICS = {
    "admitted_concurrency": "pair",  # {"cache": n, "nocache": n}
    "prefill_chunks_executed": "pair",
    "prefill_chunk_reduction": "scalar",
    "prefix_hits": "scalar",
    "prefill_tokens_skipped": "scalar",
    "cow_copies": "scalar",
    "deferrals": "pair",
    "decode_round_latency_s": "stats",  # {"mean": s, "p95": s}
    "mean_latency_ticks": "pair",
}

# v2: metrics.cluster — per-server serving metrics emitted by an
# EdgeCluster run ("list" = per-server list of n_servers numbers).
# v3 adds the heterogeneous profile caps each server ran under.
_REQUIRED_CLUSTER = {
    "n_servers": "scalar",
    "per_server_admitted": "list",  # requests admitted per origin
    "per_server_routed": "list",  # requests routed to each server
    "per_server_local_ratio": "list",  # local-compute ratio in [0, 1]
    "redirected_total": "scalar",  # requests served off-origin
    "per_server_mem_gb": "list",  # heterogeneous memory caps
}

# v3: metrics.net — the topology/communication section produced by
# ``benchmarks.topology`` (non-uniform 3-server topology, link-aware
# controller, staged migration). "matrix" = [n_servers][n_servers]
# non-negative numbers.
_REQUIRED_NET = {
    "n_servers": "scalar",
    "link_dispatch_bytes": "matrix",  # per-(src, dst) dispatch bytes
    "cross_server_bytes": "scalar",
    "migration_transfer_seconds": "scalar",  # staged-migration link time
    "migration_transfer_bytes": "scalar",
    "migrations_completed": "scalar",
    "per_server_mem_gb": "list",
    "per_server_expert_budget": "list",
}

# v4: metrics.perf — AOT bucket-ladder warmup + zero-stall decode loop
# ("p50p99" = {"p50": ms, "p99": ms}). Produced by the warmed serving leg
# of ``benchmarks.prefix_cache``.
_REQUIRED_PERF = {
    "warmup_seconds": "scalar",  # wall time of the AOT compile pass
    "executables_compiled": "scalar",  # bucket-ladder size
    "traces_after_warmup": "scalar",  # jit retraces past warmup (want 0)
    "host_syncs": "scalar",  # blocking host waits (stall count)
    "rounds_timed": "scalar",  # decode rounds behind the percentiles
    "decode_round_ms": "p50p99",  # per-round wall time, warmed loop
    "ttft_ms": "p50p99",  # wall-clock time to first token
}


# v5: metrics.faults — the deterministic fault-injection/failover section
# produced by ``benchmarks.failover`` (3-server WAN topology, mid-run
# crash of the memory-poor server, failover vs crash-oblivious baseline).
_REQUIRED_FAULTS = {
    "injected": "scalar",  # fault events consumed from the schedule
    "recovered": "scalar",  # crashes whose recovery review was adopted
    "tokens_lost": "scalar",  # failover leg (want 0)
    "recovery_seconds": "scalar",  # crash -> recovery-migration eta
    "requests_dropped": "scalar",  # failover leg (want 0)
    "baseline_tokens_lost": "scalar",  # no-failover comparison
    "baseline_requests_dropped": "scalar",
    "replay_identical": "scalar",  # 1 iff reruns were bit-identical
}


# v6: metrics.tiers — the expert tier hierarchy / oversized-model section
# produced by ``benchmarks.tiers`` (aggregate expert set > aggregate GPU
# memory; host-RAM tiers behind each GPU; activation-aware prefetch vs a
# frozen-residency baseline).
_REQUIRED_TIERS = {
    "n_servers": "scalar",
    "per_server_gpu_slots": "list",  # GPU-tier expert slots (whole server)
    "per_server_host_slots": "list",  # deepest-tier slots (cumulative)
    "per_server_gpu_resident": "list",  # experts GPU-resident at run end
    "per_server_host_resident": "list",  # experts parked in back tiers
    "promotions": "scalar",  # host->GPU prefetch fetches that landed
    "demotions": "scalar",  # GPU->back-tier moves (free: inclusive tiers)
    "prefetch_hit_ratio": "scalar",  # GPU-resident activation fraction
    "on_demand_fetches": "scalar",  # cold-expert fetch events
    "on_demand_stall_seconds": "scalar",  # modeled stall total
    "mean_latency_s": "scalar",  # prefetch leg, modeled seconds
    "prefetch_off_mean_latency_s": "scalar",  # frozen-residency baseline
    "prefetch_off_fetches": "scalar",
    "prefetch_off_stall_seconds": "scalar",
}


# v7: metrics.workload — the streaming-workload / SLO-aware-scheduling
# goodput section produced by ``benchmarks.workload`` (seeded flash-crowd
# stream over the WAN testbed; SLO-aware vs FIFO legs on the same stream;
# "p50p99" = {"p50": s, "p99": s}). ``phases`` is validated separately:
# a non-empty {phase: stats} object.
_REQUIRED_WORKLOAD = {
    "n_servers": "scalar",
    "requests": "scalar",  # stream length both legs consumed
    "sheds": "scalar",  # SLO-aware leg's shed count (gated >= 1)
    "deadline_redirects": "scalar",  # served off-route to make the SLO
    "flash_migrations": "scalar",  # migrations completed at/after crowd
    "goodput_tokens_per_s": "scalar",  # SLO-attained tokens / modeled s
    "fifo_goodput_tokens_per_s": "scalar",  # blind-FIFO baseline leg
    "slo_attainment": "scalar",  # fraction of SLO'd requests that met it
    "fifo_slo_attainment": "scalar",
    "ttft_s": "p50p99",  # modeled time-to-first-token, SLO-aware leg
    "itl_s": "p50p99",  # modeled inter-token latency
    "replay_identical": "scalar",  # 1 iff the rerun was bit-identical
}


# v8: metrics.obs — the unified-tracing section produced by
# ``benchmarks.obs`` (one sim run stacking faults + staged migration +
# tier prefetch, traced, exported and byte-compared against its rerun).
# ``clock`` ("ticks" | "seconds") and ``span_counts`` (non-empty
# {kind: count} object) are validated separately.
_REQUIRED_OBS = {
    "enabled": "scalar",  # 1 iff the tracer recorded (gated == 1)
    "events": "scalar",  # spans retained (gated >= 1)
    "dropped_events": "scalar",  # spans past max_events (gated == 0)
    "overhead_ms": "scalar",  # wall cost of recording (replay-exempt)
    "replay_identical": "scalar",  # 1 iff trace reruns byte-identical
}


class BenchSchemaError(ValueError):
    """Raised when a BENCH_serving.json document violates the contract."""


def _num(doc: dict, path: str, key: str) -> float:
    v = doc.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise BenchSchemaError(f"{path}.{key}: expected a number, got {v!r}")
    if v < 0:
        raise BenchSchemaError(f"{path}.{key}: negative value {v!r}")
    return v


def validate_bench_serving(doc) -> dict:
    """Validate a BENCH_serving.json document; returns it on success,
    raises ``BenchSchemaError`` on a missing/mis-typed/empty field."""
    if not isinstance(doc, dict) or not doc:
        raise BenchSchemaError("document must be a non-empty JSON object")
    if doc.get("schema") != SCHEMA_NAME:
        raise BenchSchemaError(
            f"schema: expected {SCHEMA_NAME!r}, got {doc.get('schema')!r}"
        )
    if doc.get("mode") not in ("smoke", "full"):
        raise BenchSchemaError(f"mode: invalid {doc.get('mode')!r}")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        raise BenchSchemaError("metrics: missing or empty")
    for key, kind in _REQUIRED_METRICS.items():
        if key not in metrics:
            raise BenchSchemaError(f"metrics.{key}: missing")
        if kind == "scalar":
            _num(metrics, "metrics", key)
            continue
        sub = metrics[key]
        if not isinstance(sub, dict):
            raise BenchSchemaError(f"metrics.{key}: expected an object")
        fields = ("cache", "nocache") if kind == "pair" else ("mean", "p95")
        for f in fields:
            if f not in sub:
                raise BenchSchemaError(f"metrics.{key}.{f}: missing")
            _num(sub, f"metrics.{key}", f)
    # an all-zero serving run means the benchmark didn't actually serve
    if (
        metrics["admitted_concurrency"]["cache"] < 1
        or metrics["prefill_chunks_executed"]["nocache"] < 1
    ):
        raise BenchSchemaError("metrics: empty run (nothing was served)")

    # -- v2: the EdgeCluster per-server section ---------------------------
    cluster = metrics.get("cluster")
    if not isinstance(cluster, dict) or not cluster:
        raise BenchSchemaError("metrics.cluster: missing or empty (v2)")
    _validate_section(cluster, "metrics.cluster", _REQUIRED_CLUSTER)
    if any(x > 1.0 for x in cluster["per_server_local_ratio"]):
        raise BenchSchemaError(
            "metrics.cluster.per_server_local_ratio: ratio > 1"
        )
    if sum(cluster["per_server_admitted"]) < 1:
        raise BenchSchemaError(
            "metrics.cluster: empty cluster run (nothing was served)"
        )

    # -- v3: the topology/communication section ---------------------------
    net = metrics.get("net")
    if not isinstance(net, dict) or not net:
        raise BenchSchemaError("metrics.net: missing or empty (v3)")
    _validate_section(net, "metrics.net", _REQUIRED_NET)
    if net["cross_server_bytes"] <= 0:
        raise BenchSchemaError(
            "metrics.net.cross_server_bytes: empty run (no dispatch "
            "traffic was metered)"
        )

    # -- v4: the AOT warmup / zero-stall perf section ---------------------
    perf = metrics.get("perf")
    if not isinstance(perf, dict) or not perf:
        raise BenchSchemaError("metrics.perf: missing or empty (v4)")
    for key, kind in _REQUIRED_PERF.items():
        if key not in perf:
            raise BenchSchemaError(f"metrics.perf.{key}: missing")
        if kind == "scalar":
            _num(perf, "metrics.perf", key)
            continue
        sub = perf[key]
        if not isinstance(sub, dict):
            raise BenchSchemaError(f"metrics.perf.{key}: expected an object")
        for f in ("p50", "p99"):
            if f not in sub:
                raise BenchSchemaError(f"metrics.perf.{key}.{f}: missing")
            _num(sub, f"metrics.perf.{key}", f)
    # an unwarmed or idle perf section means the warmed leg didn't run
    if perf["executables_compiled"] < 1:
        raise BenchSchemaError(
            "metrics.perf.executables_compiled: empty (no AOT warmup ran)"
        )
    if perf["decode_round_ms"]["p50"] <= 0 or perf["rounds_timed"] < 1:
        raise BenchSchemaError(
            "metrics.perf.decode_round_ms: empty (no decode rounds timed)"
        )

    # -- v5: the fault-injection / failover section -----------------------
    faults = metrics.get("faults")
    if not isinstance(faults, dict) or not faults:
        raise BenchSchemaError("metrics.faults: missing or empty (v5)")
    for key in _REQUIRED_FAULTS:
        if key not in faults:
            raise BenchSchemaError(f"metrics.faults.{key}: missing")
        _num(faults, "metrics.faults", key)
    if faults["injected"] < 1:
        raise BenchSchemaError(
            "metrics.faults.injected: empty run (no fault was injected)"
        )
    if faults["replay_identical"] != 1:
        raise BenchSchemaError(
            "metrics.faults.replay_identical: fault replay was not "
            "bit-identical"
        )

    # -- v6: the expert tier hierarchy / oversized-model section ----------
    tiers = metrics.get("tiers")
    if not isinstance(tiers, dict) or not tiers:
        raise BenchSchemaError("metrics.tiers: missing or empty (v6)")
    _validate_section(tiers, "metrics.tiers", _REQUIRED_TIERS)
    if tiers["promotions"] < 1:
        raise BenchSchemaError(
            "metrics.tiers.promotions: empty run (the prefetcher never "
            "promoted an expert)"
        )
    if tiers["prefetch_hit_ratio"] > 1.0:
        raise BenchSchemaError("metrics.tiers.prefetch_hit_ratio: ratio > 1")

    # -- v7: the streaming-workload / SLO-scheduling section --------------
    wl = metrics.get("workload")
    if not isinstance(wl, dict) or not wl:
        raise BenchSchemaError("metrics.workload: missing or empty (v7)")
    for key, kind in _REQUIRED_WORKLOAD.items():
        if key not in wl:
            raise BenchSchemaError(f"metrics.workload.{key}: missing")
        if kind == "scalar":
            _num(wl, "metrics.workload", key)
            continue
        sub = wl[key]
        if not isinstance(sub, dict):
            raise BenchSchemaError(
                f"metrics.workload.{key}: expected an object"
            )
        for f in ("p50", "p99"):
            if f not in sub:
                raise BenchSchemaError(f"metrics.workload.{key}.{f}: missing")
            _num(sub, f"metrics.workload.{key}", f)
    phases = wl.get("phases")
    if not isinstance(phases, dict) or not phases:
        raise BenchSchemaError(
            "metrics.workload.phases: missing or empty (v7)"
        )
    if wl["requests"] < 1:
        raise BenchSchemaError(
            "metrics.workload.requests: empty run (no stream was served)"
        )
    for key in ("slo_attainment", "fifo_slo_attainment"):
        if wl[key] > 1.0:
            raise BenchSchemaError(f"metrics.workload.{key}: ratio > 1")
    if wl["replay_identical"] != 1:
        raise BenchSchemaError(
            "metrics.workload.replay_identical: the seeded stream rerun "
            "was not bit-identical"
        )
    if wl["goodput_tokens_per_s"] <= wl["fifo_goodput_tokens_per_s"]:
        raise BenchSchemaError(
            "metrics.workload: SLO-aware goodput did not beat the FIFO "
            "baseline — the scheduling gate regressed"
        )

    # -- v8: the unified-observability / tracing section ------------------
    obs = metrics.get("obs")
    if not isinstance(obs, dict) or not obs:
        raise BenchSchemaError("metrics.obs: missing or empty (v8)")
    for key in _REQUIRED_OBS:
        if key not in obs:
            raise BenchSchemaError(f"metrics.obs.{key}: missing")
        _num(obs, "metrics.obs", key)
    if obs.get("clock") not in ("ticks", "seconds"):
        raise BenchSchemaError(
            f"metrics.obs.clock: expected 'ticks' or 'seconds', got "
            f"{obs.get('clock')!r}"
        )
    counts = obs.get("span_counts")
    if not isinstance(counts, dict) or not counts:
        raise BenchSchemaError("metrics.obs.span_counts: missing or empty")
    for kind, n in counts.items():
        if not isinstance(n, int) or isinstance(n, bool) or n < 1:
            raise BenchSchemaError(
                f"metrics.obs.span_counts.{kind}: invalid count {n!r}"
            )
    if obs["enabled"] != 1 or obs["events"] < 1:
        raise BenchSchemaError(
            "metrics.obs: empty run (the tracer recorded nothing)"
        )
    if obs["dropped_events"] != 0:
        raise BenchSchemaError(
            f"metrics.obs.dropped_events: {obs['dropped_events']} spans "
            "were dropped at the max_events cap"
        )
    if obs["replay_identical"] != 1:
        raise BenchSchemaError(
            "metrics.obs.replay_identical: the traced rerun did not "
            "export byte-identical JSON"
        )
    return doc


def validate_trace_doc(doc) -> dict:
    """Validate an exported Chrome-trace document (the ``bench-smoke``
    trace artifact, written by ``Tracer.export``); returns it on
    success, raises ``BenchSchemaError``. Deliberately self-contained —
    no ``repro`` import, so the CI gate stays dependency-free."""
    if not isinstance(doc, dict) or not doc:
        raise BenchSchemaError("trace: document must be a non-empty object")
    other = doc.get("otherData")
    if not isinstance(other, dict):
        raise BenchSchemaError("trace.otherData: missing")
    if other.get("clock") not in ("ticks", "seconds"):
        raise BenchSchemaError(
            f"trace.otherData.clock: invalid {other.get('clock')!r}"
        )
    if other.get("dropped") != 0:
        raise BenchSchemaError(
            f"trace.otherData.dropped: {other.get('dropped')!r} events "
            "were dropped"
        )
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise BenchSchemaError("trace.traceEvents: missing or empty")
    n_spans = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict) or e.get("ph") not in ("X", "M"):
            raise BenchSchemaError(f"trace.traceEvents[{i}]: invalid {e!r}")
        if e["ph"] == "M":
            continue
        n_spans += 1
        for key in ("name", "cat", "pid", "tid", "ts", "dur", "args"):
            if key not in e:
                raise BenchSchemaError(f"trace.traceEvents[{i}].{key}: missing")
        for key in ("ts", "dur"):
            v = e[key]
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                raise BenchSchemaError(
                    f"trace.traceEvents[{i}].{key}: invalid {v!r}"
                )
        if not isinstance(e["args"], dict) or "seq" not in e["args"]:
            raise BenchSchemaError(
                f"trace.traceEvents[{i}].args: missing the seq stamp"
            )
    if n_spans < 1:
        raise BenchSchemaError("trace: no complete ('X') events")
    if other.get("spans") != n_spans:
        raise BenchSchemaError(
            f"trace.otherData.spans: {other.get('spans')!r} != {n_spans} "
            "counted events"
        )
    return doc


def _validate_section(sec: dict, path: str, required: dict) -> None:
    """Shared per-server section validation: ``n_servers`` sizes every
    "list" (length n) and "matrix" (n x n, non-negative) entry."""
    n = _num(sec, path, "n_servers")
    if n < 1 or n != int(n):
        raise BenchSchemaError(f"{path}.n_servers: invalid {n!r}")
    n = int(n)

    def check_row(v, key, length):
        if not isinstance(v, list) or len(v) != length:
            raise BenchSchemaError(
                f"{path}.{key}: expected a list of {length} numbers, "
                f"got {v!r}"
            )
        for i, x in enumerate(v):
            if not isinstance(x, (int, float)) or isinstance(x, bool) or x < 0:
                raise BenchSchemaError(f"{path}.{key}[{i}]: invalid {x!r}")

    for key, kind in required.items():
        if key not in sec:
            raise BenchSchemaError(f"{path}.{key}: missing")
        if kind == "scalar":
            _num(sec, path, key)
        elif kind == "list":
            check_row(sec[key], key, n)
        elif kind == "matrix":
            rows = sec[key]
            if not isinstance(rows, list) or len(rows) != n:
                raise BenchSchemaError(
                    f"{path}.{key}: expected {n} rows, got {rows!r}"
                )
            for r, row in enumerate(rows):
                check_row(row, f"{key}[{r}]", n)

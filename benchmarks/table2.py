"""Table II: serve latency across 5 placement methods x 2 models x 2
workloads (BigBench 10s / MultiData 20s Poisson), 3 heterogeneous servers."""
from __future__ import annotations

import numpy as np

from benchmarks.common import all_plans, make_setup
from repro.serving.simulator import EdgeSimulator


def run(duration: float = 1200.0, seed: int = 1):
    out = {}
    for model in ("deepseek-v2-lite", "mixtral-8x7b"):
        for workload in ("bigbench", "multidata"):
            pf, cl, wl, cap, slots = make_setup(model, workload,
                                                duration=duration)
            plans = all_plans(pf, cl, wl, cap, slots)
            rows = []
            for name, plan in plans.items():
                r = EdgeSimulator(cl, pf, wl, plan=plan, seed=seed).run()
                per = r.avg_latency_per_server(cl.n)
                rows.append((name, *np.round(per, 2),
                             round(r.avg_latency, 2)))
            out[(model, workload)] = rows
    return out


def main(csv: bool = False, duration: float = 1200.0):
    out = run(duration=duration)
    for (model, workload), rows in out.items():
        if not csv:
            print(f"\n=== {model} / {workload} ===")
            print(f"{'Method':12s} {'S1':>8s} {'S2':>8s} {'S3':>8s} "
                  f"{'Avg':>8s}")
        best = min(r[-1] for r in rows)
        for name, s1, s2, s3, avg in rows:
            if csv:
                print(f"table2,{model}/{workload}/{name},{avg}")
            else:
                mark = " <= best" if avg == best else ""
                print(f"{name:12s} {s1:8.2f} {s2:8.2f} {s3:8.2f} "
                      f"{avg:8.2f}{mark}")
        by = {r[0]: r[-1] for r in rows}
        assert by["DanceMoE"] <= min(v for k, v in by.items()
                                     if k != "DanceMoE") * 1.02, \
            f"paper claim: DanceMoE best ({model}/{workload}): {by}"
    return out


if __name__ == "__main__":
    main()

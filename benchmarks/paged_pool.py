"""Paged KV pool vs legacy dense slot pool at *equal KV memory*:
admitted-concurrency (occupancy) and a latency-vs-blocks sweep.

The dense pool provisions every slot at ``max_len`` positions, so its
concurrency is ``memory / max_len`` no matter how short the requests are.
The paged pool holds the same bytes as ``n_blocks x block_size`` shared
positions and admits on *free blocks*, so a heterogeneous stream of
short-ish requests packs several times more concurrent work into the same
memory — the occupancy premise behind the paper's batch-size/latency
reproduction (Fig. 6/7) at scale.

  PYTHONPATH=src python -m benchmarks.paged_pool [--csv]

Prints ``paged_pool,<case>,<value>`` CSV lines. The CPU test config
(mixtral-8x7b reduced) is used so the script runs anywhere tier-1 runs.
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime

MAX_LEN = 64          # dense row length
DENSE_SLOTS = 4       # dense pool rows -> KV memory = 4 x 64 positions
BLOCK_SIZE = 8
PROMPT, STEPS = 12, 4  # short-ish requests: the heterogeneous-stream case
N_REQUESTS = 16


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                          slots=cfg.num_experts, capacity=4096,
                          slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls = tr.stack_placement(pl, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls,
                                            n_groups)
    return ServingEngine(rt=rt, params=params, placement=pls,
                         max_len=MAX_LEN)


def serve(rtm, prompts, steps):
    rids = [rtm.enqueue(Request(prompt=p, max_new_tokens=steps)).rid
            for p in prompts]
    rtm.run()
    lat = [rtm.finished_at[r] for r in rids]      # completion tick per req
    return {"peak_admitted": rtm.max_admitted,
            "peak_decode_batch": rtm.max_concurrency,
            "mean_latency_ticks": float(np.mean(lat)),
            "p95_latency_ticks": float(np.percentile(lat, 95)),
            "deferrals": rtm.deferrals}


def main(csv: bool = False):
    eng = build_engine()
    src = TaskTokenSource("occupancy", eng.rt.cfg.vocab_size, seed=0)
    prompts = [src.sample(1, PROMPT)[0] for _ in range(N_REQUESTS)]
    mem_positions = DENSE_SLOTS * MAX_LEN         # the shared KV budget

    dense = serve(ServingRuntime(eng, max_slots=DENSE_SLOTS, paged=False),
                  prompts, STEPS)
    # equal memory: n_blocks * block_size == dense positions (+ null block);
    # slot rows are decode batch width only, so give the paged pool enough
    equal_blocks = mem_positions // BLOCK_SIZE + 1
    paged = serve(ServingRuntime(eng, max_slots=N_REQUESTS,
                                 block_size=BLOCK_SIZE,
                                 n_blocks=equal_blocks),
                  prompts, STEPS)

    ratio = paged["peak_admitted"] / max(dense["peak_admitted"], 1)
    print(f"# occupancy at equal KV memory ({mem_positions} positions)")
    print(f"dense pool  ({DENSE_SLOTS}x{MAX_LEN}): "
          f"peak_admitted={dense['peak_admitted']} "
          f"mean_latency={dense['mean_latency_ticks']:.1f} ticks")
    print(f"paged pool ({equal_blocks - 1}x{BLOCK_SIZE}): "
          f"peak_admitted={paged['peak_admitted']} "
          f"mean_latency={paged['mean_latency_ticks']:.1f} ticks "
          f"deferrals={paged['deferrals']}")
    print(f"admitted-concurrency ratio: {ratio:.1f}x "
          f"({'>= 2x OK' if ratio >= 2 else 'BELOW TARGET'})")
    if csv:
        print(f"paged_pool,dense_peak_admitted,{dense['peak_admitted']}")
        print(f"paged_pool,paged_peak_admitted,{paged['peak_admitted']}")
        print(f"paged_pool,admitted_ratio,{ratio:.2f}")

    # AOT-warmed zero-stall leg on the equal-memory pool: same stream, no
    # mid-stream jit traces, decode rounds chained on device
    warm_rtm = ServingRuntime(eng, max_slots=N_REQUESTS,
                              block_size=BLOCK_SIZE, n_blocks=equal_blocks,
                              warmup=True, warmup_origins="untagged")
    warm = serve(warm_rtm, prompts, STEPS)
    p = warm_rtm.perf_metrics()
    print(f"warmed pool ({equal_blocks - 1}x{BLOCK_SIZE}): "
          f"aot={p['executables_compiled']} exes in "
          f"{p['warmup_seconds']:.1f}s "
          f"retraces={p['traces_after_warmup']} stalls={p['host_syncs']} "
          f"decode_round_ms p50={p['decode_round_ms']['p50']:.2f} "
          f"mean_latency={warm['mean_latency_ticks']:.1f} ticks")
    if csv:
        print(f"paged_pool,warm_decode_round_ms_p50,"
              f"{p['decode_round_ms']['p50']:.3f}")
        print(f"paged_pool,warm_retraces,{p['traces_after_warmup']}")

    # latency-vs-blocks sweep: shrink the pool below the dense budget and
    # watch deferrals trade memory for queueing latency
    print("\n# latency vs pool size (paged, same request stream)")
    print("n_blocks,capacity_pos,peak_admitted,mean_latency_ticks,"
          "p95_latency_ticks,deferrals")
    for n_blocks in (5, 9, 17, equal_blocks, 2 * equal_blocks - 1):
        r = serve(ServingRuntime(eng, max_slots=N_REQUESTS,
                                 block_size=BLOCK_SIZE, n_blocks=n_blocks),
                  prompts, STEPS)
        cap = (n_blocks - 1) * BLOCK_SIZE
        line = (f"{n_blocks},{cap},{r['peak_admitted']},"
                f"{r['mean_latency_ticks']:.1f},"
                f"{r['p95_latency_ticks']:.1f},{r['deferrals']}")
        print(line)
        if csv:
            print(f"paged_pool,latency_blocks_{n_blocks},"
                  f"{r['mean_latency_ticks']:.2f}")
    assert ratio >= 2.0, (
        f"paged pool admitted only {ratio:.1f}x the dense pool's "
        "concurrency at equal KV memory")


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Table I: why collaborate — MoE-Infinity offloading vs offloading with
request redirection (LB) vs naive collaboration, Mixtral on 3 edge servers."""
from __future__ import annotations

import numpy as np

from benchmarks.common import all_plans, make_setup
from repro.serving.simulator import EdgeSimulator


def run(duration: float = 1200.0, seed: int = 1):
    pf, cl, wl, cap, slots = make_setup("mixtral-8x7b", "bigbench",
                                        duration=duration)
    naive = all_plans(pf, cl, wl, cap, slots)["Redundance"]  # random collab
    rows = []
    for name, kw in [("MoE-Infinity", dict(mode="offload")),
                     ("MoE-Infinity (w/ LB)", dict(mode="offload",
                                                   redirect=True)),
                     ("Naive Collaboration", dict(mode="collab",
                                                  plan=naive))]:
        r = EdgeSimulator(cl, pf, wl, seed=seed, **kw).run()
        per = r.avg_latency_per_server(cl.n)
        rows.append((name, *np.round(per, 2), round(r.avg_latency, 2)))
    return rows


def main(csv: bool = False):
    rows = run()
    if csv:
        for name, s1, s2, s3, avg in rows:
            print(f"table1,{name},{avg}")
    else:
        print(f"{'Method':22s} {'S1':>7s} {'S2':>7s} {'S3':>7s} {'Avg':>7s}")
        for name, s1, s2, s3, avg in rows:
            print(f"{name:22s} {s1:7.2f} {s2:7.2f} {s3:7.2f} {avg:7.2f}")
    collab = rows[2][-1]
    off = rows[0][-1]
    assert collab < off, "paper claim: collaboration beats offloading"
    return rows


if __name__ == "__main__":
    main()

"""Failover under a mid-run server crash on the 3-server WAN topology.

Edge clusters churn; the question the paper's placement machinery has to
answer is what a crash *costs*. This benchmark serves the same typed
request stream through the ``EdgeCluster`` sim backend twice under one
deterministic ``FaultSchedule`` — the memory-poor WAN server crashes
mid-run — and compares:

* **failover** (default): the dead server's arrivals re-route through the
  router, the controller force-reviews placement around the lost capacity
  and stages the recovery transfers over the surviving links; requests
  that need a not-yet-recovered expert stall until the migration lands.
* **no-failover baseline**: the cluster is crash-oblivious — the dead
  server's arrivals are dropped and every token they owed is lost.

Reported: tokens lost and recovery time (crash -> recovery-migration eta)
per leg, plus the deterministic-replay check (two runs of the same
schedule must be *bit-identical* — the acceptance gate for the fault
subsystem).

  PYTHONPATH=src python -m benchmarks.failover [--csv]

``smoke()`` returns the ``metrics.faults`` section of
``BENCH_serving.json`` (since ``bench-serving/v5``) on a smaller stream
for the CI ``bench-smoke`` job.
"""

from __future__ import annotations

import sys

import numpy as np

from benchmarks.topology import (
    BENCH_PROFILE,
    _historical_stats,
    build_requests,
    wan_testbed,
)
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.cluster import EdgeCluster
from repro.serving.faults import FaultSchedule
from repro.serving.net import CommCostModel, Topology

CRASH_TIME = 60.0
# the WAN-linked memory-poor box: the two LAN survivors can still cover
# every expert, so recovery is feasible
DEAD_SERVER = 2


def crash_schedule() -> FaultSchedule:
    return FaultSchedule.server_crash(CRASH_TIME, DEAD_SERVER)


def run_leg(
    topo: Topology, requests, schedule: FaultSchedule, failover: bool, seed: int = 0
) -> dict:
    pf = BENCH_PROFILE
    cm = CommCostModel(
        topology=topo,
        expert_bytes=pf.expert_bytes,
        activation_bytes=pf.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, pf),
        interval=20.0,
        topology=topo,
        stats=_historical_stats(topo, pf, seed),
    )
    ec = EdgeCluster(
        "sim",
        topology=topo,
        profile=pf,
        controller=ctrl,
        seed=seed,
        fault_schedule=schedule,
        failover=failover,
    )
    for r in requests:
        ec.submit(r)
    handles = ec.run()
    done = [h for h in handles if h.done]
    m = ec.metrics()
    return {
        "completed": len(done),
        "n_requests": len(handles),
        "mean_latency_s": float(np.mean([h.metrics["latency"] for h in done])),
        "latencies": [h.metrics["latency"] for h in done],
        "timeline": [(e.type, e.rid, e.time) for e in ec.events],
        "faults": m["faults"],
        "link_bytes": m["net"]["link_bytes"],
    }


def measure(n_requests: int, seed: int = 0) -> dict:
    """Both legs plus the bit-identical replay of the failover leg."""
    # a fresh Topology per leg: faults mutate its shared LinkState
    reqs = build_requests(n_requests, 3, seed=seed)
    fo = run_leg(wan_testbed(), reqs, crash_schedule(), True, seed)
    fo2 = run_leg(wan_testbed(), reqs, crash_schedule(), True, seed)
    base = run_leg(wan_testbed(), reqs, crash_schedule(), False, seed)
    replay_identical = (
        fo["latencies"] == fo2["latencies"]
        and fo["timeline"] == fo2["timeline"]
        and fo["link_bytes"] == fo2["link_bytes"]
    )
    return {"failover": fo, "baseline": base, "replay_identical": replay_identical}


def faults_section(results: dict) -> dict:
    """The ``metrics.faults`` section (since ``bench-serving/v5``): the
    failover leg's recovery numbers plus the no-failover comparison."""
    fo, base = results["failover"], results["baseline"]
    return {
        "injected": fo["faults"]["injected"],
        "recovered": fo["faults"]["recovered"],
        "tokens_lost": fo["faults"]["tokens_lost"],
        "recovery_seconds": fo["faults"]["recovery_seconds"],
        "requests_dropped": fo["faults"]["requests_dropped"],
        "completed": fo["completed"],
        "n_requests": fo["n_requests"],
        "replay_identical": int(results["replay_identical"]),
        "baseline_tokens_lost": base["faults"]["tokens_lost"],
        "baseline_requests_dropped": base["faults"]["requests_dropped"],
    }


def smoke(n_requests: int = 40) -> dict:
    """Small CI-gate measurement: the ``metrics.faults`` document
    section, with the failover acceptance gates asserted."""
    results = measure(n_requests)
    fo, base = results["failover"], results["baseline"]
    assert fo["completed"] == fo["n_requests"], (
        "failover must complete every request after the mid-run crash "
        f"({fo['completed']}/{fo['n_requests']})"
    )
    assert base["faults"]["requests_dropped"] >= 1, (
        "the no-failover baseline should drop the dead server's arrivals "
        "— the crash landed after the stream ended?"
    )
    assert fo["faults"]["tokens_lost"] < base["faults"]["tokens_lost"], (
        "failover should lose fewer tokens than the drop-everything baseline"
    )
    assert results["replay_identical"], (
        "two runs of the same FaultSchedule must be bit-identical "
        "(event timelines, latencies, link-byte matrices)"
    )
    return faults_section(results)


def main(csv: bool = False):
    n_requests = 60
    results = measure(n_requests)
    fo, base = results["failover"], results["baseline"]
    print(
        f"# 3-server WAN topology, server {DEAD_SERVER} crashes at "
        f"t={CRASH_TIME:.0f}s ({n_requests} requests)"
    )
    print(
        f"{'leg':12s} {'completed':>10s} {'dropped':>8s} "
        f"{'tokens lost':>12s} {'recovery (s)':>13s} {'latency (s)':>12s}"
    )
    for name, r in (("failover", fo), ("no-failover", base)):
        f = r["faults"]
        print(
            f"{name:12s} {r['completed']:>7d}/{r['n_requests']:<2d} "
            f"{f['requests_dropped']:8d} {f['tokens_lost']:12d} "
            f"{f['recovery_seconds']:13.3f} {r['mean_latency_s']:12.4f}"
        )
    print(f"replay bit-identical: {results['replay_identical']}")
    if csv:
        for name, r in (("failover", fo), ("baseline", base)):
            print(f"failover,{name}_tokens_lost,{r['faults']['tokens_lost']}")
            print(f"failover,{name}_completed,{r['completed']}")
        print(f"failover,recovery_seconds,{fo['faults']['recovery_seconds']:.6f}")
    assert fo["completed"] == fo["n_requests"]
    assert results["replay_identical"]


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Oversized-model serving through the expert tier hierarchy.

The headline scenario no baseline handles: the aggregate expert set does
NOT fit in aggregate GPU memory. Three heterogeneous servers hold 14
GPU expert slots per layer against the 16 experts each layer needs —
every layer has experts that live only in a host-RAM tier behind some
server's GPU. The benchmark serves the same skewed request stream (with
the mid-run task shift from ``benchmarks.topology``) through the
``EdgeCluster`` sim backend twice:

* **prefetch on** (default): the activation-aware prefetcher promotes
  experts that turn hot — e.g. after the task shift — into GPU residency
  over the host<->device link, overlapped with decode.
* **prefetch off**: tier residency is frozen at the initial
  hottest-first split; every activation on a back-tier expert keeps
  paying the on-demand host-fetch stall (or invokes a remote replica).

Reported (``metrics.tiers`` of ``BENCH_serving.json``, schema
``bench-serving/v6``): per-server per-tier slot capacities and
residency, promotion/demotion counts, the prefetch hit ratio,
on-demand-fetch stalls, and the prefetch-off comparison numbers. The CI
gate asserts prefetch-on gives *strictly* fewer on-demand stalls and
strictly lower mean latency.

  PYTHONPATH=src python -m benchmarks.tiers [--csv]

Full mode also runs the runtime-backend leg (real jitted engines on 3
fake CPU devices) as a subprocess — see ``tests/md_scripts/
tiers_runtime.py``; the parent process cannot re-configure the JAX
device count once initialized.
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from benchmarks.topology import BENCH_PROFILE, build_requests
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.cluster import EdgeCluster
from repro.serving.net import CommCostModel, ServerProfile, Topology

# per-layer expert slots: GPU 6+5+3 = 14 < 16 experts/layer (oversized);
# host tiers hold the full set with room to spare
GPU_SLOTS = (6, 5, 3)
HOST_SLOTS = (16, 14, 12)


def _sharp_task_profile(name: str, idx: int, pf, seed: int):
    """A sharply skewed per-task activation profile (Zipf a in 2.2-2.8 vs
    the 0.3-1.6 library default), seeded off ``idx`` instead of
    ``hash(name)`` so results are bit-identical across *processes* (Python
    string hashing is randomized per interpreter). Sparse gating tails are
    what makes on-demand-fetch counts residency-sensitive — see
    ``run_leg``."""
    from repro.data.traces import TaskProfile

    rng = np.random.default_rng([seed, idx, 77])
    L, E = pf.num_layers, pf.num_experts
    probs = np.zeros((L, E))
    for l in range(L):
        a = 2.2 + 0.6 * rng.random()
        z = 1.0 / (np.arange(E) + 1.0) ** a
        perm = rng.permutation(E)
        probs[l] = z[np.argsort(perm)] / z.sum()
    return TaskProfile(name=name, probs=probs)


def _primed_stats(topo: Topology, pf, seed: int):
    """Prime the controller with the first-phase task profiles (the
    paper's 'historical' statistics) — the tiered analogue of
    ``benchmarks.topology._historical_stats``, using the deterministic
    sharp profiles above."""
    from repro.core.stats import ActivationStats

    stats = ActivationStats(pf.num_layers, topo.n, pf.num_experts, decay=0.9)
    for n in range(topo.n):
        tp = _sharp_task_profile(f"task{n}", n, pf, seed)
        stats.update_server(n, tp.probs * 500.0 * pf.top_k)
    return stats


def tiered_testbed() -> Topology:
    """The WAN-ish 3-server testbed of ``benchmarks.topology``, with
    host-RAM expert tiers behind each GPU. Aggregate GPU slots per layer
    (14) < experts per layer (16): some experts exist *only* in host
    tiers — the oversized-model scenario."""
    pf = BENCH_PROFILE
    eb, L = pf.expert_bytes, pf.num_layers
    # PCIe-ish host links, slowest on the memory-poor WAN server
    host_bw = (2e9, 2e9, 1e9)
    profiles = tuple(
        ServerProfile(
            f"edge{i}",
            mem_bytes=GPU_SLOTS[i] * L * eb,
            host_mem_bytes=HOST_SLOTS[i] * L * eb,
            host_bw=host_bw[i],
        )
        for i in range(3)
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    bw[0, 2] = bw[2, 0] = bw[1, 2] = bw[2, 1] = 25e6 / 8
    lat[0, 2] = lat[2, 0] = lat[1, 2] = lat[2, 1] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def run_leg(n_requests: int, prefetch: bool, seed: int = 0) -> dict:
    """One sim-backend pass over the oversized cluster; returns the
    tiers metrics plus completion/latency numbers."""
    pf = BENCH_PROFILE
    topo = tiered_testbed()
    cm = CommCostModel(
        topology=topo,
        expert_bytes=pf.expert_bytes,
        activation_bytes=pf.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, pf, tiered=True),
        interval=20.0,
        topology=topo,
        stats=_primed_stats(topo, pf, seed),
    )
    ec = EdgeCluster(
        "sim",
        topology=topo,
        profile=pf,
        controller=ctrl,
        seed=seed,
        prefetch=prefetch,
    )
    # Sharply skewed task profiles: each task concentrates on a handful
    # of hot experts, so a request's gating delta is *sparse* over the
    # 16-expert tail. With the post-shift hot set parked in host RAM, the
    # prefetch-off leg pays an on-demand fetch for those experts on every
    # request; the prefetch leg promotes them and stops paying. (Under
    # the default near-uniform tail, every back-tier cell fires every
    # request and the fetch count would be residency-invariant.)
    for t in range(2 * topo.n):
        name = f"task{t}"
        ec.backend.workload.tasks[name] = _sharp_task_profile(name, t, pf, seed)
    for r in build_requests(n_requests, 3, seed=seed):
        ec.submit(r)
    handles = ec.run()
    done = [h for h in handles if h.done]
    m = ec.metrics()
    return {
        "completed": len(done),
        "n_requests": len(handles),
        "mean_latency_s": float(np.mean([h.metrics["latency"] for h in done])),
        "tiers": m["tiers"],
    }


def measure(n_requests: int, seed: int = 0) -> dict:
    return {
        "prefetch": run_leg(n_requests, True, seed),
        "baseline": run_leg(n_requests, False, seed),
    }


def tiers_section(results: dict) -> dict:
    """The ``metrics.tiers`` section (since ``bench-serving/v6``): the
    prefetch leg's tier state + the prefetch-off comparison."""
    on, off = results["prefetch"], results["baseline"]
    out = dict(on["tiers"])
    out["mean_latency_s"] = round(on["mean_latency_s"], 6)
    out["prefetch_off_mean_latency_s"] = round(off["mean_latency_s"], 6)
    out["prefetch_off_fetches"] = off["tiers"]["on_demand_fetches"]
    out["prefetch_off_stall_seconds"] = off["tiers"]["on_demand_stall_seconds"]
    return out


def run_runtime_leg(timeout: float = 300.0) -> str:
    """The runtime-backend leg: real jitted engines over 3 fake CPU
    devices, tiered modeled budgets. Runs as a subprocess because the
    parent's JAX is already initialized with one device."""
    script = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "tests",
        "md_scripts",
        "tiers_runtime.py",
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0 or "ALL OK" not in proc.stdout:
        raise RuntimeError(f"runtime tier leg failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def smoke(n_requests: int = 40) -> dict:
    """Small CI-gate measurement: the ``metrics.tiers`` document section,
    with the oversized-serving acceptance gates asserted."""
    pf = BENCH_PROFILE
    results = measure(n_requests)
    on, off = results["prefetch"], results["baseline"]
    assert on["completed"] == on["n_requests"], (
        f"oversized serving must complete every request "
        f"({on['completed']}/{on['n_requests']})"
    )
    assert off["completed"] == off["n_requests"], "prefetch-off leg incomplete"
    gpu_total = sum(on["tiers"]["per_server_gpu_slots"])
    assert gpu_total < pf.num_layers * pf.num_experts, (
        "scenario must be oversized: aggregate GPU slots "
        f"({gpu_total}) >= aggregate expert set "
        f"({pf.num_layers * pf.num_experts})"
    )
    assert on["tiers"]["promotions"] >= 1, (
        "the prefetcher never promoted an expert — nothing was measured"
    )
    assert (
        on["tiers"]["on_demand_stall_seconds"]
        < off["tiers"]["on_demand_stall_seconds"]
    ), (
        "prefetch must strictly reduce on-demand-fetch stalls: "
        f"{on['tiers']['on_demand_stall_seconds']} vs "
        f"{off['tiers']['on_demand_stall_seconds']}"
    )
    assert on["mean_latency_s"] < off["mean_latency_s"], (
        "prefetch must strictly reduce mean latency: "
        f"{on['mean_latency_s']} vs {off['mean_latency_s']}"
    )
    return tiers_section(results)


def main(csv: bool = False):
    n_requests = 60
    results = measure(n_requests)
    on, off = results["prefetch"], results["baseline"]
    pf = BENCH_PROFILE
    gpu_total = sum(on["tiers"]["per_server_gpu_slots"])
    print(
        f"# oversized model: {pf.num_layers * pf.num_experts} expert "
        f"instances over {gpu_total} aggregate GPU slots "
        f"({n_requests} requests, 3 servers)"
    )
    print(
        f"{'leg':14s} {'hit ratio':>10s} {'fetches':>8s} "
        f"{'stall (s)':>10s} {'promoted':>9s} {'latency (s)':>12s}"
    )
    for name, r in (("prefetch", on), ("no-prefetch", off)):
        t = r["tiers"]
        print(
            f"{name:14s} {t['prefetch_hit_ratio']:10.4f} "
            f"{t['on_demand_fetches']:8d} "
            f"{t['on_demand_stall_seconds']:10.3f} {t['promotions']:9d} "
            f"{r['mean_latency_s']:12.4f}"
        )
    if csv:
        for name, r in (("prefetch", on), ("baseline", off)):
            t = r["tiers"]
            print(f"tiers,{name}_stall_seconds,{t['on_demand_stall_seconds']}")
            print(f"tiers,{name}_mean_latency_s,{r['mean_latency_s']:.6f}")
        print(f"tiers,promotions,{on['tiers']['promotions']}")
    assert on["mean_latency_s"] < off["mean_latency_s"]
    print("# runtime-backend leg (3 fake devices, subprocess)...")
    out = run_runtime_leg()
    print(out.strip().splitlines()[-1])


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

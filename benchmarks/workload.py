"""Flash-crowd goodput benchmark: SLO-aware scheduling vs blind FIFO.

The scenario the streaming workload engine exists for: a diurnal request
stream over the non-uniform WAN testbed gets hit by a flash crowd —
several minutes of multiplied arrival rate pinned to the memory-poor WAN
server (origin 2) and concentrated on one *new* task profile, so both
the scheduler and the Eq.-4 placement review are under attack at once:

* the **SLO-aware leg** (``EdgeCluster(slo_aware=True)``) sheds requests
  no live server can start by their deadline and redirects the rest to
  the earliest-start server;
* the **FIFO leg** (the default) admits everything in arrival order and
  burns timeline on requests that were already doomed.

Both legs consume the *same seeded stream* (``WorkloadStream`` restarts
bit-identically), so the only difference is the scheduling policy.
Reported per leg: goodput (SLO-attained tokens per modeled second), SLO
attainment, sheds, and p50/p99 TTFT / inter-token latency split by
scenario phase (flash / peak / offpeak). The placement side is checked
too: the crowd's task shift must drive at least one completed migration
at or after the crowd's onset (``flash_migrations``).

Acceptance gates (asserted in ``smoke()`` and validated by the
``bench-serving/v7`` schema):

* SLO-aware goodput is **strictly** higher than FIFO goodput on the same
  stream;
* the SLO-aware leg sheds at least one request (the crowd really
  overloads the cluster);
* a full rerun of the SLO-aware leg reproduces every reported number
  bit-for-bit (``replay_identical``).

  PYTHONPATH=src python -m benchmarks.workload [--csv]

``smoke()`` returns the ``metrics.workload`` section of
``BENCH_serving.json`` on the same scenario for the CI ``bench-smoke``
job (the sim backend models time, so small and fast is still faithful).
Full mode also serves the flash-crowd stream through the *warmed
multi-server runtime backend* — a real jitted 3-server
``EdgeCluster("runtime")`` with the AOT bucket ladder and SLO-aware
admission, goodput reported per scenario phase — as a subprocess (see
``tests/md_scripts/workload_runtime_cluster.py``; the parent process
cannot re-configure the JAX device count once initialized).
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from benchmarks.topology import (BENCH_PROFILE, _historical_stats,
                                 wan_testbed)
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.api import EventType
from repro.serving.cluster import EdgeCluster
from repro.serving.net import CommCostModel, Topology
from repro.serving.workload import (FlashCrowd, WorkloadSpec, WorkloadStream,
                                    drive, goodput_report)

CROWD = FlashCrowd(start=40.0, duration=30.0, multiplier=6.0, origin=2,
                   fraction=0.9, task="flashtask")

BENCH_SPEC = WorkloadSpec(
    duration=120.0, base_rate=2.0, n_origins=3, origin_skew=0.8,
    diurnal_period=80.0, diurnal_amplitude=0.4, crowds=(CROWD,),
    prompt_len=(96.0, 0.6, 8, 384), output_len=(16.0, 0.5, 4, 48),
    slo=6.0, seed=0)


def _controller(topo: Topology, seed: int) -> PlacementController:
    pf = BENCH_PROFILE
    cm = CommCostModel(topology=topo, expert_bytes=pf.expert_bytes,
                       activation_bytes=pf.hidden_bytes_per_token,
                       tokens_per_horizon=1e5)
    return PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView.from_topology(topo, pf),
        interval=20.0, topology=topo,
        stats=_historical_stats(topo, pf, seed))


def run_leg(spec: WorkloadSpec, slo_aware: bool, seed: int = 0) -> dict:
    """Serve one full pass of the seeded stream through the sim backend;
    returns the goodput report plus the leg's placement/shed counters."""
    topo = wan_testbed()
    ec = EdgeCluster("sim", topology=topo, profile=BENCH_PROFILE,
                     controller=_controller(topo, seed), seed=seed,
                     slo_aware=slo_aware)
    handles = drive(ec, WorkloadStream(spec), max_pending=64)
    rep = goodput_report(handles, phase_of=spec.phase_of)
    rep["deadline_redirects"] = int(
        getattr(ec.backend, "deadline_redirects", 0))
    rep["flash_migrations"] = sum(
        1 for e in ec.events
        if e.type == EventType.MIGRATION_COMPLETED
        and e.time >= spec.crowds[0].start)
    rep["mean_latency_by_origin"] = (
        ec.metrics()["per_server"]["mean_latency"])
    return rep


def measure(spec: WorkloadSpec = BENCH_SPEC, seed: int = 0) -> dict:
    """The three legs: SLO-aware, FIFO baseline, and the SLO-aware
    replay (bit-identity check) — all on the same seeded stream."""
    slo = run_leg(spec, slo_aware=True, seed=seed)
    fifo = run_leg(spec, slo_aware=False, seed=seed)
    replay = run_leg(spec, slo_aware=True, seed=seed)
    return {"slo": slo, "fifo": fifo,
            "replay_identical": int(replay == slo)}


def workload_section(results: dict, spec: WorkloadSpec) -> dict:
    """The ``metrics.workload`` section (since ``bench-serving/v7``)."""
    slo, fifo = results["slo"], results["fifo"]
    return {
        "n_servers": spec.n_origins,
        "requests": slo["requests"],
        "sheds": slo["sheds"],
        "deadline_redirects": slo["deadline_redirects"],
        "flash_migrations": slo["flash_migrations"],
        "goodput_tokens_per_s": slo["goodput_tokens_per_s"],
        "fifo_goodput_tokens_per_s": fifo["goodput_tokens_per_s"],
        "slo_attainment": slo["slo_attainment"],
        "fifo_slo_attainment": fifo["slo_attainment"],
        "ttft_s": slo["ttft"],
        "itl_s": slo["itl"],
        "phases": slo["phases"],
        "replay_identical": results["replay_identical"],
    }


def run_runtime_leg(timeout: float = 600.0) -> str:
    """The warmed multi-server runtime-backend leg: the flash-crowd
    stream against a jitted 3-server ``EdgeCluster("runtime")`` with
    AOT warmup + SLO-aware scheduling, per-phase goodput. Runs as a
    subprocess because the parent's JAX is already initialized with one
    device."""
    script = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "tests", "md_scripts", "workload_runtime_cluster.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0 or "ALL OK" not in proc.stdout:
        raise RuntimeError(
            f"runtime workload leg failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def smoke(spec: WorkloadSpec = BENCH_SPEC) -> dict:
    """CI-gate measurement: the ``metrics.workload`` document section."""
    results = measure(spec)
    slo, fifo = results["slo"], results["fifo"]
    assert slo["goodput_tokens_per_s"] > fifo["goodput_tokens_per_s"], (
        "SLO-aware scheduling should beat blind FIFO on goodput for the "
        f"flash-crowd stream (got {slo['goodput_tokens_per_s']} vs "
        f"{fifo['goodput_tokens_per_s']})")
    assert slo["sheds"] >= 1, (
        "the flash crowd should force at least one shed — the scenario "
        "no longer overloads the cluster")
    assert slo["flash_migrations"] >= 1, (
        "the crowd's task shift should complete at least one placement "
        "migration at/after its onset — Eq.-4 review is not reacting")
    assert results["replay_identical"] == 1, (
        "rerunning the SLO-aware leg on the same seeded stream must "
        "reproduce every reported number bit-for-bit")
    return workload_section(results, spec)


def main(csv: bool = False):
    spec = BENCH_SPEC
    results = measure(spec)
    slo, fifo = results["slo"], results["fifo"]
    print(f"# flash-crowd workload ({slo['requests']} requests over "
          f"{spec.duration:.0f} s; crowd x{spec.crowds[0].multiplier:.0f} "
          f"at origin {spec.crowds[0].origin}, slo={spec.slo} s)")
    print(f"{'leg':10s} {'goodput tok/s':>14s} {'attainment':>11s} "
          f"{'sheds':>6s} {'ttft p99 (s)':>13s}")
    for name, leg in (("slo-aware", slo), ("fifo", fifo)):
        print(f"{name:10s} {leg['goodput_tokens_per_s']:14.3f} "
              f"{leg['slo_attainment']:11.3f} {leg['sheds']:6d} "
              f"{leg['ttft']['p99']:13.3f}")
    for ph, d in sorted(slo["phases"].items()):
        print(f"  phase {ph:8s}: {d['requests']:4d} req, "
              f"{d['sheds']:3d} shed, attainment {d['slo_attainment']:.3f}, "
              f"ttft p99 {d['ttft']['p99']:.3f} s")
    print(f"flash migrations: {slo['flash_migrations']}, "
          f"deadline redirects: {slo['deadline_redirects']}, "
          f"replay identical: {bool(results['replay_identical'])}")
    if csv:
        print(f"workload,slo_goodput,{slo['goodput_tokens_per_s']:.5f}")
        print(f"workload,fifo_goodput,{fifo['goodput_tokens_per_s']:.5f}")
        print(f"workload,sheds,{slo['sheds']}")
    assert slo["goodput_tokens_per_s"] > fifo["goodput_tokens_per_s"], (
        "SLO-aware scheduling should beat blind FIFO on goodput")
    print("# warmed multi-server runtime-backend leg (3 fake devices, "
          "subprocess)...")
    out = run_runtime_leg()
    for line in out.strip().splitlines():
        if line.startswith(("goodput:", "  phase", "zero-stall", "ALL OK")):
            print(f"  {line}")


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Placement policies on a NON-uniform 3-server edge topology.

The paper's testbed links every server at the same 500 Mbps; real edge
deployments rarely look like that. This benchmark builds a topology with
one slow WAN-ish link (25 Mbps, 40 ms) isolating server 2 — which is also
the memory-poor box — and serves the same typed request stream through the
``EdgeCluster`` sim backend under three placement policies (dancemoe /
uniform / eplb), each with a link-aware ``CommCostModel`` controller and
bandwidth-aware staged migration. Reported per policy:

* mean request latency (modeled seconds),
* cross-server dispatch bytes from the shared ``TrafficMeter`` — the
  quantity activation-aware placement minimizes,
* staged-migration transfer totals (seconds/bytes over the modeled links).

Activation-aware placement must beat the uniform baseline on cross-server
bytes (asserted — the acceptance gate for the topology subsystem).

  PYTHONPATH=src python -m benchmarks.topology [--csv]

``smoke()`` returns the ``metrics.net`` section of ``BENCH_serving.json``
(since ``bench-serving/v3``) on a smaller stream for the CI ``bench-smoke`` job.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.api import Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.net import CommCostModel, ServerProfile, Topology

POLICIES = ("dancemoe", "uniform", "eplb")

# A mid-size MoE whose experts (3 * 512 * 1024 * 2 B ~ 3 MB) actually move
# over a 25 Mbps link within the benchmark's horizon — Eq. 4 then has a
# real tradeoff to price instead of rejecting every migration outright
# (a DeepSeek-sized expert takes ~5.5 s per WAN transfer; correct to
# refuse, useless to demo).
BENCH_PROFILE = MoEProfile(num_layers=8, num_experts=16, top_k=2,
                           d_model=512, d_ff=1024)


def wan_testbed() -> Topology:
    """3 edge servers: two LAN-linked (500 Mbps / 2 ms), one behind a slow
    WAN-ish hop (25 Mbps / 40 ms) — and that one is also memory-poor
    (half the expert budget of its peers)."""
    base = 64 * BENCH_PROFILE.expert_bytes       # ~8 expert slots per layer
    profiles = (
        ServerProfile("lan0", mem_bytes=base, kv_mem_bytes=8e9,
                      compute_speed=50e12),
        ServerProfile("lan1", mem_bytes=base, kv_mem_bytes=8e9,
                      compute_speed=50e12),
        ServerProfile("wan2", mem_bytes=base / 2, kv_mem_bytes=4e9,
                      compute_speed=50e12),
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    for a, b in ((0, 2), (1, 2)):
        bw[a, b] = bw[b, a] = 25e6 / 8
        lat[a, b] = lat[b, a] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def build_requests(n_requests: int, n_servers: int, seed: int = 0
                   ) -> list[Request]:
    """Poisson stream, one task per origin — with a workload *shift*
    halfway through (each origin switches task), so the controllers get a
    reason to stage a migration over the modeled links."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for k in range(n_requests):
        t += float(rng.exponential(4.0))
        origin = k % n_servers
        # synthetic task names: each unknown name gets its own generated
        # activation profile, so the halfway switch is a real distribution
        # shift (the BIGBENCH menu only has 3 entries)
        task = (f"task{origin}" if k < n_requests // 2
                else f"task{origin + n_servers}")
        reqs.append(Request(
            prompt=np.zeros(max(int(rng.normal(128, 32)), 8), np.int32),
            max_new_tokens=20, origin=origin, arrival=t, task=task))
    return reqs


def _historical_stats(topo: Topology, pf, seed: int):
    """Prime the controller with the first-phase task profiles (the
    paper's 'historical communication and computation' statistics), so
    the initial placement review is informed rather than degenerate."""
    from repro.core.stats import ActivationStats
    from repro.data.traces import make_task_profile
    # EMA decay: the controller tracks the *recent* mix, so the mid-stream
    # task shift actually surfaces in the reviewed frequencies instead of
    # drowning in the cumulative history
    stats = ActivationStats(pf.num_layers, topo.n, pf.num_experts,
                            decay=0.9)
    for n in range(topo.n):
        tp = make_task_profile(f"task{n}", pf.num_layers,
                               pf.num_experts, seed=seed)
        stats.update_server(n, tp.probs * 500.0 * pf.top_k)
    return stats


def run_policy(policy: str, topo: Topology, requests: list[Request],
               interval: float = 20.0, seed: int = 0) -> dict:
    pf = BENCH_PROFILE
    cm = CommCostModel(topology=topo, expert_bytes=pf.expert_bytes,
                       activation_bytes=pf.hidden_bytes_per_token,
                       tokens_per_horizon=1e5)
    ctrl = PlacementController(
        policy=get_policy(policy), cost=cm,
        cluster=ClusterView.from_topology(topo, pf),
        interval=interval, topology=topo,
        stats=_historical_stats(topo, pf, seed))
    ec = EdgeCluster("sim", topology=topo, profile=pf, controller=ctrl,
                     seed=seed)
    for r in requests:
        ec.submit(r)
    handles = ec.run()
    m = ec.metrics()
    return {
        "mean_latency_s": float(np.mean([h.metrics["latency"]
                                         for h in handles])),
        "cross_server_bytes": m["net"]["cross_server_bytes"],
        "link_bytes": m["net"]["link_bytes"],
        "local_ratio": m["per_server"]["local_ratio"],
        "migrations": m["net"]["migrations"],
        "metrics": m,
    }


def measure(n_requests: int, seed: int = 0) -> dict:
    topo = wan_testbed()
    requests = build_requests(n_requests, topo.n, seed=seed)
    return {p: run_policy(p, topo, requests, seed=seed) for p in POLICIES}


def net_section(results: dict, topo: Topology) -> dict:
    """The ``metrics.net`` section (since ``bench-serving/v3``): the dancemoe
    run's per-link/migration numbers plus the cross-policy comparison."""
    dm = results["dancemoe"]
    pf = BENCH_PROFILE
    return {
        "n_servers": topo.n,
        "link_dispatch_bytes": dm["link_bytes"],
        "cross_server_bytes": dm["cross_server_bytes"],
        "migration_transfer_seconds":
            dm["migrations"]["transfer_seconds"],
        "migration_transfer_bytes": dm["migrations"]["transfer_bytes"],
        "migrations_completed": dm["migrations"]["completed"],
        "per_server_mem_gb": [round(p.mem_bytes / 1e9, 3)
                              for p in topo.profiles],
        "per_server_expert_budget": [
            int(b) for b in topo.expert_budgets(pf.expert_bytes)],
        "cross_server_bytes_by_policy": {
            p: results[p]["cross_server_bytes"] for p in results},
    }


def smoke(n_requests: int = 40) -> dict:
    """Small CI-gate measurement: the ``metrics.net`` document section."""
    topo = wan_testbed()
    results = measure(n_requests)
    assert (results["dancemoe"]["cross_server_bytes"]
            < results["uniform"]["cross_server_bytes"]), (
        "activation-aware placement should cut modeled cross-server bytes "
        "vs the uniform baseline")
    assert results["dancemoe"]["migrations"]["completed"] >= 1, (
        "the workload shift should stage at least one migration that "
        "completes within the run — staged migration regressed")
    return net_section(results, topo)


def main(csv: bool = False):
    n_requests = 60
    topo = wan_testbed()
    results = measure(n_requests)
    print(f"# {topo.n}-server non-uniform topology "
          f"({n_requests} requests): WAN-ish 25 Mbps link to the "
          "memory-poor server, 500 Mbps LAN elsewhere")
    print(f"{'policy':10s} {'latency (s)':>12s} {'cross bytes':>12s} "
          f"{'mig xfer (s)':>12s} {'local ratio':>24s}")
    for p, r in results.items():
        lr = "/".join(f"{v:.2f}" for v in r["local_ratio"])
        print(f"{p:10s} {r['mean_latency_s']:12.4f} "
              f"{r['cross_server_bytes']:12.3e} "
              f"{r['migrations']['transfer_seconds']:12.3f} {lr:>24s}")
    dm, up = results["dancemoe"], results["uniform"]
    ratio = up["cross_server_bytes"] / max(dm["cross_server_bytes"], 1.0)
    print(f"dancemoe cuts cross-server bytes {ratio:.2f}x vs uniform; "
          f"latency {up['mean_latency_s'] / dm['mean_latency_s']:.2f}x")
    if csv:
        for p, r in results.items():
            print(f"topology,{p}_latency_s,{r['mean_latency_s']:.5f}")
            print(f"topology,{p}_cross_bytes,{r['cross_server_bytes']:.1f}")
    assert dm["cross_server_bytes"] < up["cross_server_bytes"], (
        "activation-aware placement should cut modeled cross-server bytes "
        "on the non-uniform topology")


if __name__ == "__main__":
    main(csv="--csv" in sys.argv)

"""Fig. 6: evolution of the local compute ratio over runtime for each
placement method (all non-baseline methods use DanceMoE's migration)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICY_NAMES, all_plans, make_setup
from repro.core.migration import CostModel
from repro.core.policies import (ClusterView, PlacementController,
                                 get_policy)
from repro.serving.simulator import EdgeSimulator


def run(model="deepseek-v2-lite", workload="bigbench",
        duration: float = 1800.0, seed: int = 1):
    pf, cl, wl, cap, slots = make_setup(model, workload, duration=duration)
    cm = CostModel(expert_bytes=pf.expert_bytes,
                   activation_bytes=128 * pf.hidden_bytes_per_token,
                   bandwidth=cl.bandwidth,
                   io_speed=np.array([s.io_speed for s in cl.servers]),
                   tokens_per_horizon=2e4)
    cluster = ClusterView(capacity=cap, slots_cap=slots)
    static = all_plans(pf, cl, wl, cap, slots)
    series = {}
    for name in ("Uniform", "Redundance"):
        r = EdgeSimulator(cl, pf, wl, plan=static[name], seed=seed).run()
        series[name] = r.local_ratio_t
    for name in ("SmartMoE", "EPLB", "DanceMoE"):
        ctrl = PlacementController(policy=get_policy(POLICY_NAMES[name]),
                                   cost=cm, cluster=cluster, interval=300.0)
        r = EdgeSimulator(cl, pf, wl, controller=ctrl, seed=seed).run()
        series[name] = r.local_ratio_t
    return series


def main(csv: bool = False):
    series = run()
    means = {k: float(np.mean([x[1] for x in v])) for k, v in series.items()}
    if csv:
        for k, v in means.items():
            print(f"fig6,local_ratio_{k},{round(v, 4)}")
    else:
        for k, v in series.items():
            pts = " ".join(f"{t/60:.0f}m:{r:.2f}" for t, r in v[::5])
            print(f"{k:11s} mean={means[k]:.3f}  {pts}")
    assert means["DanceMoE"] >= max(v for k, v in means.items()
                                    if k != "DanceMoE") - 0.02, means
    return series


if __name__ == "__main__":
    main()

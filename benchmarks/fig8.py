"""Fig. 8: simulator scalability — (a) average time per prompt vs GPU count
(4 -> 256) under 8s/15s Poisson arrivals; (b) bandwidth sweep 100 -> 1000
Mbps at 4 and 256 GPUs."""
from __future__ import annotations

import numpy as np

from repro.core.placement import dancemoe_placement
from repro.serving.cluster import (ClusterSpec, DEEPSEEK_V2_LITE_PROFILE,
                                   ServerSpec)
from repro.serving.simulator import EdgeSimulator


def homogeneous_cluster(n: int, bandwidth_mbps: float = 500.0):
    return ClusterSpec(
        servers=tuple(ServerSpec(f"s{i}", gpus=1, mem_bytes=12e9,
                                 compute_speed=1e12, io_speed=4e9)
                      for i in range(n)),
        bandwidth=bandwidth_mbps * 1e6 / 8, rtt=30e-3)


def _run(n_gpus: int, bandwidth_mbps: float, inter: float,
         duration: float = 600.0, seed: int = 0):
    """Fixed GLOBAL arrival rate (one Poisson stream of mean `inter`,
    requests spread over servers) — scaling the cluster then reduces
    per-server load, the paper's Fig. 8a setting."""
    from repro.data.traces import Request, Workload, make_task_profile
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = homogeneous_cluster(n_gpus, bandwidth_mbps)
    rng = np.random.default_rng(seed)
    names = [f"task{i}" for i in range(8)]
    tasks = {t: make_task_profile(t, pf.num_layers, pf.num_experts, seed)
             for t in names}
    reqs, t = [], 0.0
    i = 0
    while True:
        t += rng.exponential(inter)
        if t >= duration:
            break
        server = i % n_gpus
        reqs.append(Request(arrival=t, server=server,
                            task=names[server % 8],
                            prompt_tokens=max(8, int(rng.normal(128, 32))),
                            decode_tokens=20))
        i += 1
    wl = Workload(requests=reqs, tasks=tasks, duration=duration)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    plan = dancemoe_placement(wl.freqs_by_server(cl.n), cap, slots)
    r = EdgeSimulator(cl, pf, wl, plan=plan, seed=seed).run()
    return r.avg_latency


def run_scaling(duration: float = 600.0):
    """The paper's 8s/15s arrivals correspond to its ~10s services; our
    calibrated services are ~1s, so the queueing-equivalent interarrivals
    are scaled by the same factor (0.8s / 1.5s)."""
    rows = []
    for inter, tag in ((0.27, "poisson_8s_eq"), (0.55, "poisson_15s_eq")):
        for n in (4, 16, 64, 256):
            rows.append((tag, n, round(_run(n, 500.0, inter,
                                            duration=duration), 3)))
    return rows


def run_bandwidth(duration: float = 600.0):
    rows = []
    for n in (4, 256):
        for bw in (100, 250, 500, 1000):
            rows.append((n, bw, round(_run(n, bw, 0.3,
                                           duration=duration), 3)))
    return rows


def main(csv: bool = False, duration: float = 600.0):
    scaling = run_scaling(duration)
    bw = run_bandwidth(duration)
    for tag, n, lat in scaling:
        print(f"fig8a,{tag}/gpus={n},{lat}" if csv
              else f"(a) {tag:11s} gpus={n:3d}  avg={lat:7.3f}s")
    for n, b, lat in bw:
        print(f"fig8b,gpus={n}/bw={b}Mbps,{lat}" if csv
              else f"(b) gpus={n:3d} bw={b:5d}Mbps avg={lat:7.3f}s")
    # paper claims: more GPUs help (denser arrivals help more);
    # higher bandwidth helps, more at small scale
    s = {(t, n): l for t, n, l in scaling}
    assert s[("poisson_8s_eq", 256)] < s[("poisson_8s_eq", 4)]
    # denser arrivals benefit more from scale (paper: 19% vs 9%)
    gain_dense = 1 - s[("poisson_8s_eq", 256)] / s[("poisson_8s_eq", 4)]
    gain_sparse = 1 - s[("poisson_15s_eq", 256)] / s[("poisson_15s_eq", 4)]
    assert gain_dense > gain_sparse
    b = {(n, x): l for n, x, l in bw}
    assert b[(4, 1000)] < b[(4, 100)]
    gain4 = (b[(4, 100)] - b[(4, 1000)]) / b[(4, 100)]
    gain256 = (b[(256, 100)] - b[(256, 1000)]) / b[(256, 100)]
    assert gain4 > gain256 * 0.8   # diminishing with scale
    return scaling, bw


if __name__ == "__main__":
    main()

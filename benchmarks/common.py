"""Shared benchmark setup: the paper-calibrated testbed and workloads.

Calibration note: absolute latencies depend on the paper's exact hardware
(A100 slices, Docker-tc 500 Mbps, MoE-Infinity runtime overheads). We
calibrate the linear time model so that baseline average latencies land in
the paper's reported range (units: seconds, Table II), and evaluate the
*orderings and relative gains*, which is what the paper's claims are about.
"""
from __future__ import annotations


import numpy as np

from repro.core.policies import ClusterView, get_policy
from repro.data.traces import (BIGBENCH_TASKS, MULTIDATA_TASKS,
                               poisson_workload)
from repro.serving.cluster import (ClusterSpec, DEEPSEEK_V2_LITE_PROFILE,
                                   MIXTRAL_PROFILE, ServerSpec)

# Edge-effective FLOP rates: single-request expert GEMV is HBM-bound, so the
# effective rate is far below peak (A100 ~ 2 TB/s => ~1 TFLOP/s effective
# at bf16 GEMV); server3 has 2 GPUs.
def calibrated_testbed(mem_fraction: float) -> ClusterSpec:
    return ClusterSpec(
        servers=(
            ServerSpec("server1", gpus=1, mem_bytes=mem_fraction * 40e9,
                       compute_speed=1.0e12, io_speed=4e9),
            ServerSpec("server2", gpus=1, mem_bytes=mem_fraction * 40e9,
                       compute_speed=1.0e12, io_speed=4e9),
            ServerSpec("server3", gpus=2, mem_bytes=mem_fraction * 2 * 40e9,
                       compute_speed=2.0e12, io_speed=8e9),
        ),
        bandwidth=500e6 / 8, rtt=30e-3)


MODELS = {
    "deepseek-v2-lite": (DEEPSEEK_V2_LITE_PROFILE, 0.3),
    "mixtral-8x7b": (MIXTRAL_PROFILE, 0.7),
}

WORKLOADS = {
    "bigbench": (list(BIGBENCH_TASKS), 10.0),    # 10 s Poisson arrivals
    "multidata": (list(MULTIDATA_TASKS), 20.0),  # 20 s Poisson arrivals
}


def make_setup(model: str, workload: str, *, duration: float = 1200.0,
               seed: int = 0):
    pf, frac = MODELS[model]
    cl = calibrated_testbed(frac)
    tasks, inter = WORKLOADS[workload]
    wl = poisson_workload(tasks, num_layers=pf.num_layers,
                          num_experts=pf.num_experts,
                          mean_interarrival=inter, duration=duration,
                          prompt_tokens=128, decode_tokens=20, seed=seed)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    return pf, cl, wl, cap, slots


# paper-name -> registered policy name (repro.core.policies registry)
POLICY_NAMES = {
    "Uniform": "uniform",
    "Redundance": "redundance",
    "SmartMoE": "smartmoe",
    "EPLB": "eplb",
    "DanceMoE": "dancemoe",
}


def all_plans(pf, cl, wl, cap, slots):
    freqs = wl.freqs_by_server(cl.n)
    cluster = ClusterView(capacity=cap, slots_cap=slots)
    return {label: get_policy(name).propose(freqs, cluster)
            for label, name in POLICY_NAMES.items()}

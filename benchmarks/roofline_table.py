"""Deliverable (g) reporting: aggregate the dry-run JSONs into the roofline
table (also embedded in EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
from pathlib import Path


def load(results_dir: str = "results/dryrun", mesh: str = "16x16"):
    rows = []
    for f in sorted(glob.glob(f"{results_dir}/*__{mesh}.json")):
        r = json.loads(Path(f).read_text())
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r.get("error", "?")})
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_ms": ro["compute_s"] * 1e3,
            "memory_ms": ro["memory_s"] * 1e3,
            "collective_ms": ro["collective_s"] * 1e3,
            "dominant": ro["dominant"],
            "useful": ro["useful_flops_ratio"],
            "mfu_bound": ro["mfu_bound"],
            "args_gb": r["argument_size_in_bytes"] / 1e9,
        })
    return rows


def main(csv: bool = False, mesh: str = "16x16"):
    rows = load(mesh=mesh)
    if not rows:
        print(f"roofline,no_results_for_{mesh},0")
        return rows
    if csv:
        for r in rows:
            if "error" in r:
                print(f"roofline,{r['arch']}/{r['shape']},ERROR")
            else:
                print(f"roofline,{r['arch']}/{r['shape']}/{r['dominant']},"
                      f"{r['compute_ms']:.2f}|{r['memory_ms']:.2f}|"
                      f"{r['collective_ms']:.2f}")
    else:
        hdr = (f"{'arch':26s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
               f"{'coll_ms':>9s} {'dominant':>10s} {'useful':>7s} "
               f"{'args_GB':>8s}")
        print(hdr)
        for r in rows:
            if "error" in r:
                print(f"{r['arch']:26s} {r['shape']:12s} ERROR {r['error']}")
            else:
                print(f"{r['arch']:26s} {r['shape']:12s} "
                      f"{r['compute_ms']:9.2f} {r['memory_ms']:9.2f} "
                      f"{r['collective_ms']:9.2f} {r['dominant']:>10s} "
                      f"{r['useful']:7.2f} {r['args_gb']:8.2f}")
    return rows


if __name__ == "__main__":
    main()

"""Fig. 5: layer-wise inference latency grows with the fraction of experts
executed remotely. We construct placements with controlled local coverage
(top-x activation mass resident) and measure simulated per-layer latency."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_setup
from repro.core.placement import PlacementPlan
from repro.serving.simulator import EdgeSimulator


def coverage_plan(freqs, keep_mass: float, slots) -> PlacementPlan:
    """Per (layer, server): keep the most frequent experts covering
    `keep_mass` of the local activation mass (rest remote)."""
    L, N, E = freqs.shape
    assign = []
    for l in range(L):
        layer = []
        for n in range(N):
            order = np.argsort(-freqs[l, n], kind="stable")
            cum = np.cumsum(freqs[l, n][order])
            k = max(1, int(np.searchsorted(cum, keep_mass) + 1))
            layer.append([int(e) for e in order[:min(k, slots[n])]])
        # coverage: every expert somewhere (needed by the simulator)
        placed = set(e for a in layer for e in a)
        for e in range(E):
            if e not in placed:
                layer[int(np.argmax(slots))].append(e)
        assign.append(layer)
    counts = np.array([[len(assign[l][n]) for n in range(N)]
                       for l in range(L)])
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def run(duration: float = 600.0, seed: int = 1):
    pf, cl, wl, cap, slots = make_setup("deepseek-v2-lite", "bigbench",
                                        duration=duration)
    freqs = wl.freqs_by_server(cl.n)
    slots_full = np.full(cl.n, pf.num_experts)
    rows = []
    for keep in (0.98, 0.9, 0.75, 0.5, 0.25, 0.1):
        plan = coverage_plan(freqs, keep, slots_full)
        r = EdgeSimulator(cl, pf, wl, plan=plan, seed=seed).run()
        remote_frac = 1.0 - np.mean([x[1] for x in r.local_ratio_t])
        per_layer_ms = r.avg_latency / pf.num_layers * 1e3
        rows.append((round(remote_frac, 3), round(per_layer_ms, 2)))
    return rows


def main(csv: bool = False):
    rows = run()
    if csv:
        for rf, ms in rows:
            print(f"fig5,remote_frac={rf},{ms}")
    else:
        print(f"{'remote_frac':>12s} {'ms/layer':>10s}")
        for rf, ms in rows:
            print(f"{rf:12.3f} {ms:10.2f}")
    # paper claim: latency increases with remote fraction
    fracs = [r[0] for r in rows]
    lats = [r[1] for r in rows]
    order = np.argsort(fracs)
    lats_sorted = np.array(lats)[order]
    assert lats_sorted[-1] > lats_sorted[0] * 1.2, rows
    return rows


if __name__ == "__main__":
    main()

"""Ablation study: which parts of DanceMoE's placement matter?

Compares, on the same skewed workload:
  - full DanceMoE (entropy counts + greedy assignment + spare-slot fill),
  - flat counts (skip Algorithm 1: equal slots per layer),
  - no spare-fill (coverage only, no extra replication),
  - no activation awareness (random assignment within the same counts).

Run:  PYTHONPATH=src python examples/placement_study.py
"""
import numpy as np

from repro.core.placement import (PlacementPlan, allocate_expert_counts,
                                  assign_experts_layer, remote_cost)
from repro.core.policies import ClusterView, get_policy
from repro.core.stats import entropy
from repro.data.traces import BIGBENCH_TASKS, poisson_workload
from repro.serving.cluster import (DEEPSEEK_V2_LITE_PROFILE, EdgeCluster,
                                   paper_testbed, requests_from_workload)


def flat_counts_plan(freqs, capacity, slots):
    L, N, E = freqs.shape
    counts = np.minimum(np.broadcast_to(capacity // L, (L, N)).copy(),
                        np.minimum(slots, E))
    # raise per-layer totals to E where needed
    assign = [assign_experts_layer(counts[l], freqs[l]) for l in range(L)]
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def random_assignment_plan(freqs, capacity, slots, seed=0):
    rng = np.random.default_rng(seed)
    L, N, E = freqs.shape
    v = entropy(freqs, axis=-1)
    counts = allocate_expert_counts(np.full(L, E), capacity, v,
                                    max_per_layer=slots)
    assign = []
    for l in range(L):
        layer = []
        remaining = list(range(E))
        rng.shuffle(remaining)
        for n in range(N):
            take = [remaining.pop() for _ in range(min(counts[l, n],
                                                       len(remaining)))]
            while len(take) < counts[l, n]:
                take.append(int(rng.integers(0, E)))
            layer.append(sorted(set(take)) or [0])
        placed = set(e for a in layer for e in a)
        for e in range(E):
            if e not in placed:
                layer[int(np.argmax(counts[l]))].append(e)
        assign.append(layer)
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def main():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    wl = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                          num_experts=pf.num_experts,
                          mean_interarrival=10.0, duration=900.0)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    freqs = wl.freqs_by_server(cl.n)
    cluster = ClusterView(capacity=cap, slots_cap=slots)
    variants = {
        "DanceMoE (full)": get_policy("dancemoe").propose(freqs, cluster),
        "w/o Alg.1 (flat counts)": flat_counts_plan(freqs, cap, slots),
        "w/o spare-fill": get_policy(
            "dancemoe", fill_spare=False).propose(freqs, cluster),
        "w/o activation awareness": random_assignment_plan(freqs, cap,
                                                           slots),
    }
    # every variant rides the serving API v1 sim backend: one typed
    # request stream, one EdgeCluster per candidate placement
    reqs = requests_from_workload(wl)
    print(f"{'variant':26s} {'Eq.2 proxy':>11s} {'sim latency':>12s}")
    for name, plan in variants.items():
        ec = EdgeCluster("sim", spec=cl, profile=pf, plan=plan,
                         tasks=wl.tasks, seed=1)
        for r in reqs:
            ec.submit(r)
        handles = ec.run()
        lat = float(np.mean([h.metrics["latency"] for h in handles]))
        print(f"{name:26s} {remote_cost(plan, freqs):11.2f} "
              f"{lat:11.3f}s")


if __name__ == "__main__":
    main()

"""Train a small Mixtral-family MoE language model with the EP placement
layer active (counts/aux-loss/local-ratio reported), AdamW + cosine LR,
checkpointing every 50 steps.

Defaults train a ~25M-param model for 200 steps on CPU (about 15 min);
`--dmodel 768 --layers 8 --steps 300` reaches the ~100M scale for real runs.

Run:  PYTHONPATH=src python examples/train_moe.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.data.pipeline import train_batches
from repro.models import transformer as tr
from repro.optim.adamw import adamw, cosine_schedule
from repro.training.train_loop import make_train_step


def small_moe(d_model: int, layers: int) -> ModelConfig:
    return ModelConfig(
        name="train-moe-example", family="moe", num_layers=layers,
        d_model=d_model, num_heads=8, num_kv_heads=4, head_dim=d_model // 8,
        d_ff=d_model * 2, vocab_size=4096,
        num_experts=8, top_k=2, moe_every=1, source="example")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dmodel", type=int, default=384)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="results/train_moe/ckpt")
    args = ap.parse_args()

    cfg = small_moe(args.dmodel, args.layers)
    rt = tr.Runtime(cfg=cfg, moe_impl="dense")
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_experts} experts, top-{cfg.top_k})")

    opt = adamw(schedule=cosine_schedule(args.lr, warmup=20,
                                         total=args.steps))
    step_fn = jax.jit(make_train_step(rt, opt))
    opt_state = opt.init(params)
    losses = []
    t0 = time.time()
    for i, (tok, tgt) in enumerate(train_batches(
            cfg.vocab_size, args.batch, args.seq, args.steps)):
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(tok), jnp.asarray(tgt))
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"ce={float(m['ce_loss']):.4f} "
                  f"aux={float(m.get('aux_loss', 0)):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if i and i % 50 == 0:
            save_checkpoint(args.ckpt, params, step=i)
    save_checkpoint(args.ckpt, params, step=args.steps)
    p2, _, meta = load_checkpoint(args.ckpt)
    assert meta["step"] == args.steps
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({(1 - last/first) * 100:.1f}% reduction)")
    assert last < first, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()

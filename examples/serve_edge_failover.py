"""Failover on the 3-server serve_edge cluster: one server crashes
mid-stream and every request still completes.

Same topology and typed ``Request`` stream as ``serve_edge.py``, plus a
deterministic ``FaultSchedule``: the memory-poor WAN server (edge2) goes
down mid-run and rejoins later. With ``failover=True`` (the default) the
cluster

1. re-routes edge2's arrivals through the router to the survivors,
2. force-reviews expert placement around the lost capacity (a recovery
   migration staged over the surviving links), and
3. re-admits edge2 into routing when it rejoins.

The crash-oblivious baseline (``failover=False``) simply drops edge2's
arrivals — every token they owed is lost. The sim backend keeps this
example dependency-light and fast; the runtime backend exposes the same
``fault_schedule=``/``failover=`` knobs (see ``serving/README.md``).

Run:  PYTHONPATH=src python examples/serve_edge_failover.py
"""

import numpy as np

from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.api import Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.faults import FaultEvent, FaultSchedule
from repro.serving.net import ServerProfile, Topology

N_SERVERS, N_REQUESTS = 3, 30
CRASH_AT, REJOIN_AT, DEAD = 40.0, 90.0, 2

PROFILE = MoEProfile(num_layers=4, num_experts=8, top_k=2, d_model=256, d_ff=512)


def build_topology() -> Topology:
    """Two LAN-linked servers plus one memory-poor box behind a WAN-ish
    hop — the box that crashes. The survivors can still cover every
    expert, so recovery is feasible."""
    base = 16 * PROFILE.expert_bytes
    profiles = (
        ServerProfile("edge0", mem_bytes=base),
        ServerProfile("edge1", mem_bytes=base),
        ServerProfile("edge2", mem_bytes=base / 2),
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    for a, b in ((0, 2), (1, 2)):
        bw[a, b] = bw[b, a] = 25e6 / 8
        lat[a, b] = lat[b, a] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def build_requests() -> list:
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    for k in range(N_REQUESTS):
        t += float(rng.exponential(4.0))
        reqs.append(
            Request(
                prompt=np.zeros(64, np.int32),
                max_new_tokens=20,
                origin=k % N_SERVERS,
                arrival=t,
                task=f"task{k % N_SERVERS}",
            )
        )
    return reqs


def run(failover: bool):
    topo = build_topology()
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=None,
        cluster=ClusterView.from_topology(topo, PROFILE),
        interval=25.0,
        topology=topo,
    )
    sched = FaultSchedule(
        [
            FaultEvent(CRASH_AT, "SERVER_DOWN", server=DEAD),
            FaultEvent(REJOIN_AT, "SERVER_JOINED", server=DEAD),
        ]
    )
    ec = EdgeCluster(
        "sim",
        topology=topo,
        profile=PROFILE,
        controller=ctrl,
        seed=0,
        fault_schedule=sched,
        failover=failover,
    )
    handles = [ec.submit(r) for r in build_requests()]
    ec.run()
    return ec, handles


def main():
    print(
        f"== failover: edge{DEAD} crashes at t={CRASH_AT:.0f}s, "
        f"rejoins at t={REJOIN_AT:.0f}s =="
    )
    ec, handles = run(failover=True)
    f = ec.metrics()["faults"]
    done = sum(h.done for h in handles)
    print(
        f"  completed {done}/{len(handles)}  faults={f['injected']} "
        f"recovered={f['recovered']} tokens_lost={f['tokens_lost']} "
        f"recovery={f['recovery_seconds']:.3g}s"
    )
    for e in ec.events:
        if e.type in ("SERVER_DOWN", "SERVER_JOINED"):
            print(f"  t={e.time:7.2f}s  {e.type}  server={e.data.get('server')}")
    assert done == len(handles), "failover must complete every request"
    assert f["requests_dropped"] == 0
    assert ec.topology.state.up.all(), "edge2 should have rejoined"

    print("\n== no-failover baseline: same schedule, crash-oblivious ==")
    ecb, hb = run(failover=False)
    fb = ecb.metrics()["faults"]
    doneb = sum(h.done for h in hb)
    print(
        f"  completed {doneb}/{len(hb)}  dropped={fb['requests_dropped']} "
        f"tokens_lost={fb['tokens_lost']}"
    )
    assert fb["requests_dropped"] >= 1
    assert fb["tokens_lost"] > f["tokens_lost"]

    print(
        "\nOK: failover served the full stream through the crash; the "
        f"baseline lost {fb['tokens_lost']} tokens"
    )


if __name__ == "__main__":
    main()

"""Quickstart: the DanceMoE placement pipeline end-to-end in 60 seconds.

1. Build a task-skewed workload for 3 heterogeneous edge servers.
2. Run Algorithm 1 (entropy-based layer-wise counts) + Algorithm 2
   (greedy assignment with coverage repair).
3. Compare the Eq.-2 communication proxy and simulated latency against the
   paper's four baselines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.baselines import (eplb_plan, redundance_plan, smartmoe_plan,
                                  uniform_plan)
from repro.core.placement import dancemoe_placement, remote_cost
from repro.data.traces import BIGBENCH_TASKS, poisson_workload
from repro.serving.cluster import DEEPSEEK_V2_LITE_PROFILE, paper_testbed
from repro.serving.simulator import EdgeSimulator


def main():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cluster = paper_testbed(mem_fraction=0.3)   # the paper's 30% constraint
    workload = poisson_workload(
        list(BIGBENCH_TASKS), num_layers=pf.num_layers,
        num_experts=pf.num_experts, mean_interarrival=10.0, duration=900.0)

    capacity = cluster.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(capacity // pf.num_layers, 1),
                       pf.num_experts)
    freqs = workload.freqs_by_server(cluster.n)   # f_n^l(e)

    print(f"cluster: {cluster.n} servers, capacity={capacity} expert slots")
    print(f"model: {pf.num_experts} experts x {pf.num_layers} layers, "
          f"top-{pf.top_k}\n")

    plans = {
        "Uniform (Megatron EP)": uniform_plan(pf.num_layers, cluster.n,
                                              pf.num_experts),
        "Redundance": redundance_plan(pf.num_layers, cluster.n,
                                      pf.num_experts, capacity, slots),
        "SmartMoE": smartmoe_plan(freqs, capacity, slots),
        "EPLB (DeepSeek-V3)": eplb_plan(freqs, capacity, slots),
        "DanceMoE (ours)": dancemoe_placement(freqs, capacity, slots),
    }
    print(f"{'method':22s} {'Eq.2 proxy':>11s} {'sim latency':>12s} "
          f"{'local %':>8s}")
    for name, plan in plans.items():
        r = EdgeSimulator(cluster, pf, workload, plan=plan, seed=1).run()
        local = np.mean([x[1] for x in r.local_ratio_t]) * 100
        print(f"{name:22s} {remote_cost(plan, freqs):11.2f} "
              f"{r.avg_latency:11.3f}s {local:7.1f}%")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's kind of system): serve a small
Mixtral-family MoE as a CONTINUOUS request stream through the real JAX
engine, with the unified placement control plane collecting gating
statistics and migrating the expert placement live (zero recompile — tables
and expert slots are jit arguments).

Phases:
  1. requests stream in and share decode batches under the Uniform
     placement (cold start) — different arrival times, one KV-slot pool;
  2. the ``PlacementController`` reviews the observed f_n^l(e) and migrates
     to the DanceMoE placement (Eq.-4 adopt decision);
  3. more traffic is served — the local compute ratio rises, and generated
     tokens are bit-identical before/after migration (function preserved).

Run:  PYTHONPATH=src python examples/serve_edge.py
"""
import os

# 8 placeholder devices so the example exercises a real 2x4 edge mesh
# (standalone script — safe to set before jax initialises)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.configs import get_config
from repro.core.migration import CostModel
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime


def main(steps: int = 8):
    cfg = get_config("mixtral-8x7b").reduced()  # 4 experts, top-2, 2 layers
    mesh = make_test_mesh(2, 4)                 # 2x4 fake mesh: 4 EP ranks
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                          capacity=4096, slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    key = jax.random.PRNGKey(0)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    params_dense = tr.init_params(rt_dense, key)

    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0,
                                            n_groups)

    engine = ServingEngine(rt=rt, params=params, placement=pls0,
                           dense_master=params_dense["groups"], max_len=96)
    cm = CostModel(expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                   activation_bytes=cfg.d_model * 2, bandwidth=62.5e6,
                   tokens_per_horizon=1e5)
    controller = PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView.from_ep_spec(spec, n_groups),
        interval=2 * steps)               # review every ~2 requests' decodes
    runtime = ServingRuntime(engine, max_slots=4, controller=controller)

    src = TaskTokenSource("arithmetic", cfg.vocab_size, seed=0)
    probe = src.sample(1, 32)[0]

    print("phase 1: uniform placement, continuous batching")
    r0 = runtime.submit(probe, steps)
    for _ in range(3):                    # staggered arrivals share batches
        runtime.submit(src.sample(1, 32)[0], steps)
        runtime.step()
    gen_before = runtime.run()[r0]
    print(f"  peak decode batch: {runtime.max_concurrency} requests")

    print("phase 2: controller review -> migration")
    for _ in range(4):
        runtime.submit(src.sample(1, 32)[0], steps)
    runtime.run()
    print(f"  migrations so far: {len(runtime.migrations)}")

    print("phase 3: serve the probe again after migration")
    r1 = runtime.submit(probe, steps)
    gen_after = runtime.run()[r1]
    same = bool((gen_before == gen_after).all())
    print(f"  generations identical across migration: {same}")
    assert same, "migration must preserve the served function"
    assert runtime.max_concurrency >= 2, "decode batches were never shared"
    print("OK")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's kind of system): serve a small
Mixtral-family MoE with BATCHED requests through the real JAX engine, with
the global scheduler collecting gating statistics and migrating the expert
placement live (zero recompile — tables and expert slots are jit arguments).

Phases:
  1. serve task-skewed traffic under the Uniform placement (cold start),
  2. the scheduler reviews the observed f_n^l(e) and migrates to the
     DanceMoE placement,
  3. serve more traffic — the local compute ratio rises, and generated
     tokens are bit-identical before/after migration (function preserved).

Run:  PYTHONPATH=src python examples/serve_edge.py
"""
import os

# 8 placeholder devices so the example exercises a real 2x4 edge mesh
# (standalone script — safe to set before jax initialises)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.migration import CostModel
from repro.core.placement import build_ep_placement, dancemoe_placement
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import GlobalScheduler


def regather(dense_groups, pls, n_groups):
    out = {}
    for k, v in dense_groups.items():
        if "router" in v:
            per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v),
                                 jax.tree.map(lambda a: a[g], pls))
                   for g in range(n_groups)]
            out[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        else:
            out[k] = v
    return out


def main(steps: int = 8, batches: int = 3):
    cfg = get_config("mixtral-8x7b").reduced()  # 4 experts, top-2, 2 layers
    mesh = make_test_mesh(2, 4)                 # 2x4 fake mesh: 4 EP ranks
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                          capacity=4096, slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    key = jax.random.PRNGKey(0)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    params_dense = tr.init_params(rt_dense, key)

    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = regather(params_dense["groups"], pls0, n_groups)

    engine = ServingEngine(rt=rt, params=params, placement=pls0,
                           dense_master=params_dense["groups"], max_len=96)
    cm = CostModel(expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                   activation_bytes=cfg.d_model * 2, bandwidth=62.5e6,
                   tokens_per_horizon=1e5)
    sched = GlobalScheduler(
        engine=engine, capacity=np.full(spec.n_ep, spec.slots * n_groups),
        cost=cm, interval_batches=batches,
        placement_fn=lambda f: dancemoe_placement(
            f, np.full(spec.n_ep, spec.slots * n_groups),
            np.full(spec.n_ep, spec.slots)))

    src = TaskTokenSource("arithmetic", cfg.vocab_size, seed=0)
    prompts = src.sample(4, 32)
    print("phase 1: uniform placement")
    gen_before, info = engine.generate(prompts, steps=steps)
    print(f"  local compute ratio: {info['local_frac']:.3f}")
    migrated = sched.after_batch()
    for _ in range(batches - 1):
        engine.generate(src.sample(4, 32), steps=steps)
        migrated = sched.after_batch() or migrated
    print(f"phase 2: scheduler review -> migrated={migrated}")
    gen_after, info2 = engine.generate(prompts, steps=steps)
    print(f"  local compute ratio: {info2['local_frac']:.3f}")
    same = bool((gen_before == gen_after).all())
    print(f"  generations identical across migration: {same}")
    assert same, "migration must preserve the served function"
    print("OK")


if __name__ == "__main__":
    main()

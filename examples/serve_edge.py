"""The paper's headline scenario, end to end on serving API v1: THREE edge
servers cooperatively serve one MoE model through the ``EdgeCluster``
façade, against BOTH execution backends, from the *same* typed
``Request`` stream.

* ``backend="runtime"`` — the real jitted JAX path: one engine whose EP
  spec spans the 3 servers (mesh 1x3 over placeholder devices, one EP rank
  per server), origin-tagged continuous batching, the shared
  ``PlacementController`` reviewing live gating statistics on the tick
  clock. Outputs are token-identical to sequential ``generate()`` and the
  per-origin gating statistics land in the ``[n_ep, E]`` attribution
  matrix (Algorithm 1's f_n(e)).
* ``backend="sim"`` — the event-driven time model of the paper's testbed
  (Sec. IV), seconds clock, same request objects, same handle/event/metric
  surface.

Run:  PYTHONPATH=src python examples/serve_edge.py
"""
import os
import tempfile

# 3 placeholder devices: one EP rank per edge server
# (standalone script — safe to set before jax initialises)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=3")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.baselines import uniform_plan
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.data.traces import BIGBENCH_TASKS
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import EventType, Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.engine import ServingEngine
from repro.serving.net import CommCostModel, ServerProfile, Topology

N_SERVERS = 3
PROMPT, STEPS, N_REQUESTS = 16, 6, 6


def build_topology() -> Topology:
    """Non-uniform 3-server interconnect: two LAN-linked servers plus one
    behind a slow WAN-ish hop, and one memory-poor box."""
    profiles = (ServerProfile("edge0", mem_bytes=8e9),
                ServerProfile("edge1", mem_bytes=8e9),
                ServerProfile("edge2", mem_bytes=2e9))   # memory-poor
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    bw[0, 2] = bw[2, 0] = bw[1, 2] = bw[2, 1] = 25e6 / 8   # WAN-ish link
    lat[0, 2] = lat[2, 0] = lat[1, 2] = lat[2, 1] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()  # 4 experts, top-2, 2 layers
    mesh = make_test_mesh(1, 3)                 # one EP rank per edge server
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                          capacity=4096, slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0,
                                            n_groups)
    engine = ServingEngine(rt=rt, params=params, placement=pls0,
                           dense_master=params_dense["groups"], max_len=48)
    return cfg, spec, n_groups, engine


def build_requests(cfg) -> list:
    """One typed stream, consumed by both backends: token prompts for the
    runtime, arrival times + task profiles for the simulator."""
    reqs = []
    for k in range(N_REQUESTS):
        n = k % N_SERVERS
        prompt = TaskTokenSource(f"edge{k}", cfg.vocab_size,
                                 seed=10 + k).sample(1, PROMPT)[0]
        reqs.append(Request(prompt=prompt, max_new_tokens=STEPS, origin=n,
                            arrival=4.0 * k, task=BIGBENCH_TASKS[n]))
    return reqs


def show(m: dict) -> None:
    ps = m["per_server"]
    print(f"  [{m['backend']}] clock={m['clock']} "
          f"servers={m['n_servers']} redirected={m['redirected_total']}")
    for n in range(m["n_servers"]):
        print(f"    server{n}: submitted={ps['submitted'][n]} "
              f"served={ps['served'][n]} finished={ps['finished'][n]} "
              f"mean_latency={ps['mean_latency'][n]:.4g} "
              f"local_ratio={ps['local_ratio'][n]:.2f}")


def main():
    cfg, spec, n_groups, engine = build_engine()
    requests = build_requests(cfg)
    K = cfg.top_k
    topo = build_topology()

    print(f"== runtime backend: {N_SERVERS}-server EdgeCluster over the "
          "jitted engine ==")
    cm = CommCostModel(topology=topo,
                       expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                       activation_bytes=cfg.d_model * 2,
                       tokens_per_horizon=1e5)
    controller = PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView.from_ep_spec(spec, n_groups),
        interval=STEPS,  # one live review mid-stream
        topology=topo)   # bandwidth-aware staged migration
    # seed the incumbent with the uniform layout the engine boots with:
    # the mid-stream review then *stages* the move to the activation-aware
    # plan — expert transfers scheduled over the modeled links, the plan
    # switching only once they complete
    controller.plan = uniform_plan(n_groups, N_SERVERS, cfg.num_experts)
    # max_slots=4: the EP dispatch pads token rows to the device count
    # internally, so the chunk-prefill geometry (max_slots * block_size)
    # no longer needs to divide evenly over the 3-device mesh
    cluster = EdgeCluster("runtime", engine=engine, n_servers=N_SERVERS,
                          controller=controller, topology=topo,
                          runtime_opts=dict(max_slots=4, prefix_cache=False),
                          trace=True)      # span tracing on the tick clock
    handles = [cluster.submit(r) for r in requests]
    cluster.run()
    counts = engine.stats.counts.copy()          # [n_groups, n_ep, E]
    show(cluster.metrics())
    print(f"  migrations: {len(cluster.migrations)}")
    assert len(cluster.migrations) >= 1, "no live placement review ran"

    # staged migration: the plan went live only after its modeled
    # transfers finished (MIGRATION_STARTED strictly precedes
    # MIGRATION_COMPLETED on the tick clock)
    ev = cluster.events
    starts = [e for e in ev if e.type == EventType.MIGRATION_STARTED]
    dones = [e for e in ev if e.type == EventType.MIGRATION_COMPLETED]
    assert starts and dones and starts[0].time < dones[0].time
    net = cluster.metrics()["net"]
    print(f"  staged migrations: {len(starts)} started, {len(dones)} "
          f"completed ({net['migrations']['transfer_seconds']:.3g}s modeled "
          "transfer)")
    print(f"  cross-server dispatch: {net['cross_server_bytes']:.3g} bytes "
          f"over {net['rounds']} metered rounds")
    assert net["cross_server_bytes"] > 0

    # unified tracing: queue/prefill/decode spans + the control plane's
    # PLACEMENT_REVIEW decisions and per-link TRANSFER_TASKs, exported as
    # Chrome-trace JSON (load at https://ui.perfetto.dev)
    obs = cluster.metrics()["obs"]
    assert obs["dropped_events"] == 0
    assert obs["span_counts"].get("PLACEMENT_REVIEW", 0) >= 1
    assert obs["span_counts"].get("TRANSFER_TASK", 0) >= 1
    tpath = os.path.join(tempfile.gettempdir(), "serve_edge_trace.json")
    cluster.export_trace(tpath)
    print(f"  trace: {obs['events']} spans "
          f"({', '.join(sorted(obs['span_counts']))}) -> {tpath}")

    # 1) outputs are token-identical to sequential generate() per request
    #    (one batched reference call — rows are independent)
    ref, _ = engine.generate(np.stack([r.prompt for r in requests]),
                             steps=STEPS)
    for k, h in enumerate(handles):
        np.testing.assert_array_equal(h.result(), ref[k])
    print("  runtime outputs token-identical to sequential generate(): OK")

    # 2) per-origin gating stats match the [n_ep, E] attribution path:
    #    each origin's row carries exactly its own prompts + decodes
    per_origin = counts.sum(axis=(0, 2))         # [n_ep]
    expect = np.zeros(N_SERVERS)
    for r in requests:
        expect[r.origin] += K * n_groups * (len(r.prompt) + STEPS - 1)
    np.testing.assert_allclose(per_origin, expect, rtol=0.01)
    print(f"  per-origin gating mass {per_origin} matches the "
          "[n_ep, E] attribution path: OK")

    print("\n== sim backend: same Request stream, same topology ==")
    profile = MoEProfile.from_config(cfg)
    sim_ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=None,
        cluster=ClusterView.from_topology(topo, profile), interval=10.0,
        topology=topo)
    sim = EdgeCluster("sim", topology=topo, profile=profile,
                      controller=sim_ctrl, seed=0,
                      trace=True)          # same tracer, seconds clock
    sim_handles = [sim.submit(r) for r in requests]
    sim.run()
    show(sim.metrics())
    assert all(h.done for h in sim_handles)
    assert all(h.metrics["latency"] > 0 for h in sim_handles)
    sim_obs = sim.metrics()["obs"]
    assert sim_obs["clock"] == "seconds" and sim_obs["dropped_events"] == 0

    # one contract, two worlds: identical metric surface — including the
    # topology/net section both backends derive from the one Topology
    assert set(cluster.metrics()["per_server"]) == \
        set(sim.metrics()["per_server"])
    assert set(cluster.metrics()["net"]) == set(sim.metrics()["net"])
    assert {e.type for h in handles for e in h.events} >= \
        {"ADMITTED", "TOKEN", "FINISHED"}
    print("\nOK: both backends served the same typed stream")


if __name__ == "__main__":
    main()

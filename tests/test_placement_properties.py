"""Property-based tests (hypothesis) for the placement system's invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.baselines import eplb_plan, uniform_plan
from repro.core.placement import (allocate_expert_counts, dancemoe_placement,
                                  remote_cost)
from repro.core.stats import entropy


@st.composite
def placement_instance(draw):
    L = draw(st.integers(1, 6))
    N = draw(st.integers(2, 5))
    E = draw(st.integers(2, 16))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    freqs = rng.dirichlet(np.full(E, rng.uniform(0.2, 2.0)), size=(L, N))
    # always-feasible capacity: at least full coverage + slack
    slack = draw(st.integers(0, 3 * N))
    cap_min = int(np.ceil(L * E / N))
    cap = rng.integers(cap_min, cap_min + 2 * L, size=N) + slack
    slots = np.minimum(cap // L + E, E)
    return freqs, cap, slots


@settings(max_examples=30, deadline=None)
@given(placement_instance())
def test_dancemoe_invariants(inst):
    freqs, cap, slots = inst
    L, N, E = freqs.shape
    plan = dancemoe_placement(freqs, cap, slots)
    res = plan.residency()
    # 1) expert coverage: every expert of every layer placed somewhere
    assert (res.sum(1) > 0).all()
    # 2) per-(server, layer) slot cap respected
    for l in range(L):
        for n in range(N):
            assert len(plan.assign[l][n]) <= slots[n]
            assert len(set(plan.assign[l][n])) == len(plan.assign[l][n])
    # 3) remote cost bounded by total mass
    assert 0.0 <= remote_cost(plan, freqs) <= L * N + 1e-9


@settings(max_examples=30, deadline=None)
@given(placement_instance())
def test_dancemoe_no_worse_than_uniform(inst):
    freqs, cap, slots = inst
    L, N, E = freqs.shape
    dm = remote_cost(dancemoe_placement(freqs, cap, slots), freqs)
    up = remote_cost(uniform_plan(L, N, E), freqs)
    assert dm <= up + 1e-9


@settings(max_examples=30, deadline=None)
@given(placement_instance())
def test_eplb_coverage(inst):
    freqs, cap, slots = inst
    plan = eplb_plan(freqs, cap, slots)
    assert (plan.residency().sum(1) > 0).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6), st.integers(2, 32),
       st.integers(0, 2 ** 16))
def test_alg1_invariants(L, N, E, seed):
    rng = np.random.default_rng(seed)
    freqs = rng.dirichlet(np.full(E, 0.5), size=(L, N))
    v = entropy(freqs, axis=-1)
    cap_min = int(np.ceil(L * E / N))
    cap = rng.integers(cap_min, 2 * cap_min + 1, size=N)
    counts = allocate_expert_counts(np.full(L, E), cap, v)
    assert (counts.sum(1) >= E).all()
    assert (counts.sum(0) <= cap).all()
    assert (counts <= E).all() and (counts >= 0).all()

"""Unit tests for the DanceMoE placement algorithms and baselines."""
import numpy as np
import pytest

from repro.core.baselines import (eplb_plan, redundance_plan, smartmoe_plan,
                                  uniform_plan)
from repro.core.placement import (allocate_expert_counts,
                                  assign_experts_layer, dancemoe_placement,
                                  local_utility, remote_cost)
from repro.core.stats import (ActivationStats, coverage_count, entropy,
                              lemma1_coverage_bound)


def skewed_freqs(L, N, E, seed=0):
    rng = np.random.default_rng(seed)
    freqs = np.zeros((L, N, E))
    for n in range(N):
        perm = rng.permutation(E)
        for l in range(L):
            z = 1.0 / (np.arange(E) + 1) ** (1.5 if l % 2 == 0 else 0.5)
            freqs[l, n] = z[np.argsort(perm)] / z.sum()
    return freqs


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def test_alg1_coverage_and_memory():
    L, N, E = 6, 3, 8
    v = np.abs(np.random.default_rng(0).normal(2, 0.5, (L, N)))
    cap = np.array([14, 18, 26])
    counts = allocate_expert_counts(np.full(L, E), cap, v)
    assert counts.shape == (L, N)
    assert (counts.sum(1) >= E).all()          # expert coverage per layer
    assert (counts.sum(0) <= cap).all()        # per-server memory budget
    assert (counts >= 0).all()


def test_alg1_entropy_proportionality():
    """A layer with much higher entropy should end up with more total slots
    (after the coverage rebalancing)."""
    L, N, E = 2, 2, 8
    v = np.array([[4.0, 4.0], [1.0, 1.0]])     # layer 0 diverse, layer 1 not
    counts = allocate_expert_counts(np.full(L, E), np.array([10, 10]), v)
    assert counts[0].sum() > counts[1].sum()
    assert (counts.sum(1) >= E).all()


def test_alg1_infeasible_raises():
    with pytest.raises(RuntimeError):
        allocate_expert_counts(np.full(2, 8), np.array([4]),
                               np.ones((2, 1)))


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------

def test_alg2_coverage_and_counts():
    N, E = 3, 8
    freqs = skewed_freqs(1, N, E)[0]
    counts = np.array([3, 3, 4])
    assign = assign_experts_layer(counts, freqs)
    placed = set()
    for n, a in enumerate(assign):
        assert len(a) == counts[n]
        assert len(set(a)) == len(a)           # no dups within a server
        placed |= set(a)
    assert placed == set(range(E))             # full coverage


def test_alg2_greedy_picks_top_frequency():
    freqs = np.array([[0.5, 0.3, 0.1, 0.05, 0.05],
                      [0.05, 0.05, 0.1, 0.3, 0.5]])
    assign = assign_experts_layer(np.array([3, 2]), freqs)
    assert 0 in assign[0] and 4 in assign[1]   # each server's hottest expert
    assert set(assign[0]) | set(assign[1]) == set(range(5))


def test_alg2_infeasible_counts_raise():
    freqs = np.full((2, 5), 0.2)
    with pytest.raises(ValueError):
        assign_experts_layer(np.array([2, 2]), freqs)


# ---------------------------------------------------------------------------
# Full pipeline vs baselines (the paper's headline ordering)
# ---------------------------------------------------------------------------

def test_dancemoe_beats_baselines_on_skewed_traces():
    L, N, E = 8, 4, 16
    freqs = skewed_freqs(L, N, E, seed=3)
    cap = np.array([40, 44, 52, 60])
    slots = np.minimum(cap // L + 2, E)
    dm = remote_cost(dancemoe_placement(freqs, cap, slots), freqs)
    up = remote_cost(uniform_plan(L, N, E), freqs)
    ep = remote_cost(eplb_plan(freqs, cap, slots), freqs)
    sm = remote_cost(smartmoe_plan(freqs, cap, slots), freqs)
    rd = remote_cost(redundance_plan(L, N, E, cap, slots), freqs)
    assert dm < ep < up * 1.001
    assert dm < sm and dm < rd


def test_all_plans_satisfy_coverage():
    L, N, E = 4, 3, 8
    freqs = skewed_freqs(L, N, E)
    cap = np.array([12, 14, 16])
    slots = np.array([4, 4, 5])
    for plan in [uniform_plan(L, N, E),
                 redundance_plan(L, N, E, cap, slots),
                 smartmoe_plan(freqs, cap, slots),
                 eplb_plan(freqs, cap, slots),
                 dancemoe_placement(freqs, cap, slots)]:
        assert (plan.residency().sum(1) > 0).all()


def test_greedy_utility_near_optimal_bruteforce():
    """Theorem 1: greedy >= (1-1/e) * OPT. For the modular per-server
    utility, per-server greedy is exactly optimal pre-repair; after the
    coverage repair the bound must still hold."""
    import itertools
    rng = np.random.default_rng(7)
    N, E = 2, 6
    freqs = rng.dirichlet(np.full(E, 0.4), size=N)
    counts = np.array([3, 3])
    assign = assign_experts_layer(counts, freqs)
    got = local_utility(assign, freqs)
    best = 0.0
    for a0 in itertools.combinations(range(E), 3):
        for a1 in itertools.combinations(range(E), 3):
            if set(a0) | set(a1) == set(range(E)):  # same coverage constraint
                u = freqs[0, list(a0)].sum() + freqs[1, list(a1)].sum()
                best = max(best, u)
    assert got >= (1 - 1 / np.e) * best - 1e-9


# ---------------------------------------------------------------------------
# Entropy / Lemma 1
# ---------------------------------------------------------------------------

def test_entropy_extremes():
    p_unif = np.full(8, 1 / 8)
    p_peak = np.zeros(8)
    p_peak[0] = 1.0
    assert abs(entropy(p_unif) - 3.0) < 1e-9
    assert entropy(p_peak) < 1e-9


def test_lemma1_bound_holds_in_aep_regime():
    """Lemma 1 (k_delta > 2^{H - delta*log E}) is an AEP-style ASYMPTOTIC
    bound — we verified empirically that it can fail for small alphabets
    with high skew and large delta (e.g. E=8, Zipf-1.5, delta=0.3; ~2% of
    random Dirichlet draws). Recorded as a reproduction note in
    EXPERIMENTS.md. Here we check the regime the paper's proof sketch
    actually covers: small delta across Zipf families."""
    for E in (8, 16, 32, 64, 128):
        for a in (0.0, 0.3, 0.6, 1.0, 1.5, 2.0):
            p = 1.0 / (np.arange(E) + 1) ** a
            p /= p.sum()
            for delta in (0.05, 0.1):
                k = coverage_count(p, delta)
                bound = lemma1_coverage_bound(entropy(p), E, delta)
                assert k > bound * (1 - 1e-9), (E, a, delta, k, bound)


def test_lemma1_monotone_in_entropy():
    """The qualitative claim placement relies on: more uniform activation
    (higher entropy) needs more experts for the same coverage."""
    E = 32
    ks = []
    for a in (2.0, 1.0, 0.5, 0.0):             # increasing entropy
        p = 1.0 / (np.arange(E) + 1) ** a
        p /= p.sum()
        ks.append(coverage_count(p, 0.1))
    assert ks == sorted(ks)


def test_activation_stats_freqs_and_entropy():
    st = ActivationStats(2, 2, 4)
    assert st.entropies().shape == (2, 2)
    assert np.allclose(st.entropies(), 2.0)    # max entropy when unseen
    counts = np.zeros((2, 2, 4))
    counts[0, 0] = [10, 0, 0, 0]
    st.update(counts)
    f = st.freqs()
    assert np.allclose(f[0, 0], [1, 0, 0, 0])
    assert np.allclose(f[1, 1], 0.25)          # unseen stays uniform

"""Minimal deterministic stand-in for ``hypothesis`` (used only when the
real package is not installed — see conftest.py).

Implements just the surface this repo's property tests use: ``given``,
``settings(max_examples=, deadline=)``, ``strategies.integers /
sampled_from / composite``. Examples are drawn from a fixed-seed PRNG so
runs are reproducible; there is no shrinking — a failing example is
reported as-is by pytest.
"""
from __future__ import annotations

import functools
import random


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = min_value, max_value

    def example(self, rng):
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return rng.choice(self.options)


class _Composite(_Strategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rng):
        return self.fn(lambda s: s.example(rng), *self.args, **self.kwargs)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)

    @staticmethod
    def composite(fn):
        def builder(*args, **kwargs):
            return _Composite(fn, args, kwargs)
        return builder


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # zero-arg wrapper WITHOUT functools.wraps: pytest must not see the
        # wrapped function's parameters (it would resolve them as fixtures)
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                fn(*drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__dict__.update(fn.__dict__)
        return wrapper
    return deco

"""Regression tests for the deprecated control-plane shims: both emit
``DeprecationWarning`` on construction and delegate to the unified
``PlacementController`` with identical adopt decisions."""
import numpy as np
import pytest

from repro.core.migration import CostModel, MigrationController
from repro.core.policies import (ClusterView, PlacementController,
                                 get_policy)
from repro.serving.scheduler import GlobalScheduler

from test_paged_equivalence import _ep_engine


def _cost():
    return CostModel(expert_bytes=1e6, activation_bytes=1e3,
                     bandwidth=62.5e6, tokens_per_horizon=1e5)


def _freq_stream(n_steps, L=2, N=2, E=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.dirichlet(np.full(E, 0.5), size=(L, N))
            for _ in range(n_steps)]


def test_migration_controller_warns_and_matches_unified():
    policy = get_policy("dancemoe")
    cluster = ClusterView(capacity=np.array([16, 16]),
                          slots_cap=np.array([8, 8]))
    with pytest.warns(DeprecationWarning):
        shim = MigrationController(policy, _cost(), interval=10.0)
    shim.ctrl.cluster = cluster
    ref = PlacementController(policy=policy, cost=_cost(), cluster=cluster,
                              interval=10.0)
    for i, freqs in enumerate(_freq_stream(8)):
        now = 10.0 * (i + 1)
        plan_s, adopted_s = shim.maybe_migrate(now, freqs)
        dec_r = ref.review(now, freqs)
        assert adopted_s == dec_r.adopted
        np.testing.assert_array_equal(plan_s.residency(),
                                      dec_r.plan.residency())
    # legacy history semantics: the initial adoption is excluded
    assert all(e.get("reason") != "initial" for e in shim.history)
    assert len(shim.ctrl.events) == len(ref.events)


def test_global_scheduler_warns_and_matches_unified():
    eng, src, _ = _ep_engine(False)
    spec = eng.rt.ep_spec
    cap = np.full(spec.n_ep, 64)
    eng.stats.reset()
    placement0, params0 = eng.placement, eng.params   # shim adoptions
    try:                                              # mutate the engine
        with pytest.warns(DeprecationWarning):
            sched = GlobalScheduler(engine=eng, capacity=cap, cost=_cost(),
                                    interval_batches=2)
        ref = PlacementController(
            policy=get_policy("dancemoe"), cost=_cost(),
            cluster=ClusterView(capacity=cap,
                                slots_cap=np.full(spec.n_ep, spec.slots)),
            interval=2, stats=eng.stats)
        adopts_shim, adopts_ref = [], []
        for b in range(1, 7):
            eng.generate(src.sample(1, 8), steps=2)   # feed shared stats
            adopts_shim.append(sched.after_batch())
            # mirror the shim clock: a forced review every 2nd batch
            if b % 2 == 0:
                adopts_ref.append(ref.review(b, force=True).adopted)
        # off-cadence batches never review; on-cadence decisions match the
        # unified controller's exactly (same stats, same incumbent chain)
        assert all(not a for i, a in enumerate(adopts_shim) if (i + 1) % 2)
        assert [a for i, a in enumerate(adopts_shim)
                if (i + 1) % 2 == 0] == adopts_ref
        assert adopts_shim[1]                     # first review adopts
        np.testing.assert_array_equal(sched.current_plan.residency(),
                                      ref.plan.residency())
    finally:
        eng.placement, eng.params = placement0, params0
        eng.stats.reset()


def test_shim_decisions_follow_eq4_gate():
    """The shims' adopt decision is exactly the unified Eq.-4 gate: an
    absurdly expensive migration is rejected by both."""
    policy = get_policy("dancemoe")
    cluster = ClusterView(capacity=np.array([16, 16]),
                          slots_cap=np.array([8, 8]))
    pricey = CostModel(expert_bytes=1e18, activation_bytes=1e3,
                       bandwidth=62.5e6, io_speed=1.0,
                       tokens_per_horizon=1e5)
    with pytest.warns(DeprecationWarning):
        shim = MigrationController(policy, pricey, interval=1.0)
    shim.ctrl.cluster = cluster
    freqs = _freq_stream(2, seed=5)
    _, first = shim.maybe_migrate(1.0, freqs[0])
    assert first                                   # initial always adopts
    _, second = shim.maybe_migrate(2.0, freqs[1])
    assert not second                              # Eq. 4 rejects the move

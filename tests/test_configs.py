"""Config registry: every assigned architecture with its exact shape."""
import pytest

from repro.configs import get_config, list_configs
from repro.configs.base import INPUT_SHAPES

ASSIGNED = {
    "starcoder2-3b": dict(family="dense", num_layers=30, d_model=3072,
                          num_heads=24, num_kv_heads=2, d_ff=12288,
                          vocab_size=49152),
    "qwen2-vl-72b": dict(family="vlm", num_layers=80, d_model=8192,
                         num_heads=64, num_kv_heads=8, d_ff=29568,
                         vocab_size=152064),
    "tinyllama-1.1b": dict(family="dense", num_layers=22, d_model=2048,
                           num_heads=32, num_kv_heads=4, d_ff=5632,
                           vocab_size=32000),
    "falcon-mamba-7b": dict(family="ssm", num_layers=64, d_model=4096,
                            d_ff=0, vocab_size=65024, ssm_state=16),
    "zamba2-2.7b": dict(family="hybrid", num_layers=54, d_model=2560,
                        num_heads=32, num_kv_heads=32, d_ff=10240,
                        vocab_size=32000, ssm_state=64),
    "musicgen-large": dict(family="audio", num_layers=48, d_model=2048,
                           num_heads=32, num_kv_heads=32, d_ff=8192,
                           vocab_size=2048),
    "command-r-plus-104b": dict(family="dense", num_layers=64, d_model=12288,
                                num_heads=96, num_kv_heads=8, d_ff=33792,
                                vocab_size=256000),
    "llama4-maverick-400b-a17b": dict(family="moe", num_layers=48,
                                      d_model=5120, num_heads=40,
                                      num_kv_heads=8, d_ff=8192,
                                      vocab_size=202048, num_experts=128,
                                      top_k=1),
    "yi-6b": dict(family="dense", num_layers=32, d_model=4096, num_heads=32,
                  num_kv_heads=4, d_ff=11008, vocab_size=64000),
    "phi3.5-moe-42b-a6.6b": dict(family="moe", num_layers=32, d_model=4096,
                                 num_heads=32, num_kv_heads=8, d_ff=6400,
                                 vocab_size=32064, num_experts=16, top_k=2),
}

PARAM_RANGES = {  # billions: generous envelopes around the advertised sizes
    "starcoder2-3b": (2.5, 5.0), "qwen2-vl-72b": (65, 80),
    "tinyllama-1.1b": (0.9, 1.3), "falcon-mamba-7b": (6.5, 8.5),
    "zamba2-2.7b": (1.8, 3.2), "musicgen-large": (2.7, 3.8),
    "command-r-plus-104b": (95, 112),
    "llama4-maverick-400b-a17b": (360, 430), "yi-6b": (5.4, 6.8),
    "phi3.5-moe-42b-a6.6b": (38, 46),
}


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_config_exact(name):
    cfg = get_config(name)
    for key, val in ASSIGNED[name].items():
        assert getattr(cfg, key) == val, (name, key)
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("name", sorted(PARAM_RANGES))
def test_param_counts(name):
    lo, hi = PARAM_RANGES[name]
    p = get_config(name).param_count() / 1e9
    assert lo <= p <= hi, f"{name}: {p:.2f}B not in [{lo}, {hi}]"


def test_papers_models_registered():
    assert "mixtral-8x7b" in list_configs()
    assert "deepseek-v2-lite" in list_configs()
    dsl = get_config("deepseek-v2-lite")
    assert dsl.num_experts == 64 and dsl.top_k == 8


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_variants(name):
    r = get_config(name).reduced()
    assert r.d_model <= 512 and r.num_experts <= 4
    pat, groups = r.layer_pattern()
    assert groups * len([k for k in pat]) >= 1
    # reduced keeps the family and pattern structure
    assert r.family == get_config(name).family
    assert pat == get_config(name).layer_pattern()[0]


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_head_padding_function_preserving(name):
    cfg = get_config(name)
    if not cfg.num_heads:
        return
    hp = cfg.padded_heads(16)
    assert hp % 16 == 0 and hp >= cfg.num_heads
    assert hp % cfg.num_kv_heads == 0

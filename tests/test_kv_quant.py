"""int8 KV-cache quantization (beyond-paper serving feature)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.attention import dequantize_kv, quantize_kv


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
    q8, s = quantize_kv(x)
    assert q8.dtype == jnp.int8 and s.shape == (2, 8, 4, 1)
    back = dequantize_kv(q8, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02


@pytest.mark.parametrize("name,window", [
    ("tinyllama-1.1b", 0), ("tinyllama-1.1b", 16), ("zamba2-2.7b", 0),
])
def test_int8_decode_close_to_fp(name, window):
    cfg = get_config(name).reduced()
    rt_f = tr.Runtime(cfg=cfg, window=window)
    rt_q = tr.Runtime(cfg=cfg, window=window, kv_quant=True)
    key = jax.random.PRNGKey(1)
    params = tr.init_params(rt_f, key)
    B, T = 2, 24
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    full, _, _ = tr.prefill(rt_f, params, tokens=toks)
    _, cq, _ = tr.prefill(rt_q, params, tokens=toks[:, :T], cache_len=T + 8)
    dq, cq2, _ = tr.decode_step(rt_q, params, cq, toks[:, T:T + 1],
                                jnp.int32(T))
    rel = float(jnp.max(jnp.abs(full - dq))) / float(jnp.max(jnp.abs(full)))
    assert rel < 0.06, (name, window, rel)
    # cache stored as int8 + scales
    ab = next(k for k in cq2 if "k" in cq2[k])
    assert cq2[ab]["k"].dtype == jnp.int8
    assert "k_scale" in cq2[ab]

"""Per-request ``local_frac`` attribution: warm (zero-stall, drains one
tick late) vs sync loop on 2 EP ranks, where dispatch locality is a real
signal (a single rank reports local_frac = 1.0 trivially).

With ``max_slots >= n_requests`` nothing queues, so both loops serve
identical batch compositions round for round — tokens AND the per-request
local_frac attribution must then match exactly. The warm loop drains each
round one tick after launching it; before the launch-round-stats fix
(``_round_local_frac``), the drain read the engine's mutable
``last_local_frac``, which by then held the *next* round's value — the
attribution drifted whenever compositions changed between rounds.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime

N_REQUESTS = 6


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 2)
    spec = M.EPSpec.build(
        mesh, cfg, ep_axes=("model",), slots=2, capacity=4096, slot_capacity=8192
    )
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0, n_groups)
    engine = ServingEngine(
        rt=rt,
        params=params,
        placement=pls0,
        dense_master=params_dense["groups"],
        max_len=64,
    )
    return cfg, engine


def build_requests(cfg):
    reqs = []
    for k in range(N_REQUESTS):
        src = TaskTokenSource(f"t{k}", cfg.vocab_size, seed=20 + k)
        prompt = src.sample(1, 12)[0]
        # varying lengths: requests retire at different rounds, so the
        # batch composition (and with it the round's local_frac) changes
        # between consecutive rounds — exactly the window the stale
        # drain-time read used to misattribute across
        reqs.append(Request(prompt=prompt, max_new_tokens=3 + 2 * k, origin=k % 2))
    return reqs


def run(engine, requests, warm: bool):
    rtm = ServingRuntime(
        engine, max_slots=N_REQUESTS, block_size=8, warmup=warm, prefix_cache=False
    )
    hs = [rtm.enqueue(r) for r in requests]
    rtm.run()
    return [(h.metrics.get("local_frac"), h.result().tolist()) for h in hs]


def main():
    cfg, engine = build_engine()
    requests = build_requests(cfg)
    sync = run(engine, requests, warm=False)
    warm = run(engine, requests, warm=True)
    for k, ((lf_s, tok_s), (lf_w, tok_w)) in enumerate(zip(sync, warm)):
        assert tok_s == tok_w, f"request {k}: tokens differ warm vs sync"
        assert lf_s is not None and 0.0 <= lf_s <= 1.0, (k, lf_s)
        assert lf_s == lf_w, (
            f"request {k}: local_frac differs — sync {lf_s} vs warm "
            f"{lf_w}; the warm drain attributed another round's stats"
        )
    print("warm-vs-sync local_frac identity OK:", [round(lf, 6) for lf, _ in sync])
    print("ALL OK")


if __name__ == "__main__":
    main()

"""Fault injection + failover on the runtime EdgeCluster backend (3 fake
devices, one EP rank per edge server).

Checks, against the real jitted serving stack:
  1. a mid-run ``SERVER_DOWN`` evicts the crashed server's in-flight
     requests and — with failover — re-routes them through the router;
     every submitted request still completes, and the re-prefilled streams
     stay token-identical to sequential ``generate()`` (the crash must not
     change a single output token);
  2. the crash triggers the controller's fault review: placement is
     re-planned around the lost capacity (a migration event lands after
     the SERVER_DOWN event);
  3. reruns of the same ``FaultSchedule`` are bit-identical: event
     timelines, token streams, and the faults metrics section;
  4. the no-failover baseline drops the victims (requests_dropped > 0,
     undelivered tokens counted lost) while survivors still finish;
  5. KV bookkeeping survives the crash churn: ``check_invariants`` holds
     and every page of the evicted victims is recycled (allocator drains
     to all-free after the run).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=3")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.baselines import uniform_plan
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import EventType, Request
from repro.serving.cluster import EdgeCluster
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultSchedule
from repro.serving.net import CommCostModel, ServerProfile, Topology

N_SERVERS, PROMPT, STEPS, N_REQUESTS = 3, 16, 6, 6
CRASH_TICK = 4.0
# the memory-poor server: the survivors' 4 slots still cover the 4
# reduced experts
DEAD = 2


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 3)
    spec = M.EPSpec.build(
        mesh, cfg, ep_axes=("model",), slots=2, capacity=4096, slot_capacity=8192
    )
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0, n_groups)
    engine = ServingEngine(
        rt=rt,
        params=params,
        placement=pls0,
        dense_master=params_dense["groups"],
        max_len=48,
    )
    return cfg, spec, n_groups, engine


def build_topology():
    profiles = (
        ServerProfile("e0", mem_bytes=8e9),
        ServerProfile("e1", mem_bytes=8e9),
        ServerProfile("e2", mem_bytes=2e9),
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    bw[0, 2] = bw[2, 0] = bw[1, 2] = bw[2, 1] = 25e6 / 8
    lat[0, 2] = lat[2, 0] = lat[1, 2] = lat[2, 1] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def build_requests(cfg):
    reqs = []
    for k in range(N_REQUESTS):
        src = TaskTokenSource(f"edge{k}", cfg.vocab_size, seed=10 + k)
        prompt = src.sample(1, PROMPT)[0]
        reqs.append(Request(prompt=prompt, max_new_tokens=STEPS, origin=k % N_SERVERS))
    return reqs


def run_once(failover: bool, built=None):
    cfg, spec, n_groups, engine = built if built is not None else build_engine()
    topo = build_topology()
    cm = CommCostModel(
        topology=topo,
        expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
        activation_bytes=cfg.d_model * 2,
        tokens_per_horizon=1e5,
    )
    # interval=1000: only the fault review re-places
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_ep_spec(spec, n_groups),
        interval=1000.0,
        topology=topo,
    )
    ctrl.plan = uniform_plan(n_groups, N_SERVERS, cfg.num_experts)
    cluster = EdgeCluster(
        "runtime",
        engine=engine,
        n_servers=N_SERVERS,
        controller=ctrl,
        topology=topo,
        fault_schedule=FaultSchedule.server_crash(CRASH_TICK, DEAD),
        failover=failover,
        runtime_opts=dict(max_slots=4, prefix_cache=False),
    )
    requests = build_requests(cfg)
    handles = [cluster.submit(r) for r in requests]
    cluster.run()
    keep = (
        EventType.SERVER_DOWN,
        EventType.MIGRATION_STARTED,
        EventType.MIGRATION_COMPLETED,
        EventType.MIGRATION_ABORTED,
    )
    timeline = [
        (e.type, e.time, e.data.get("victims"), round(e.data.get("eta", 0.0), 9))
        for e in cluster.events
        if e.type in keep
    ]
    tokens = [h.result().tolist() if h.done else None for h in handles]
    return cluster, handles, timeline, tokens, cluster.metrics()


def main():
    cl1, h1, t1, tok1, m1 = run_once(failover=True)
    downs = [e for e in t1 if e[0] == EventType.SERVER_DOWN]
    assert downs and downs[0][2] >= 1, (
        f"the crash should catch in-flight victims: {t1}"
    )
    assert all(h.done for h in h1), "failover must finish every request"
    f1 = m1["faults"]
    assert f1["injected"] == 1 and f1["recovered"] == 1, f1
    assert f1["requests_dropped"] == 0, f1
    assert f1["recovery_seconds"] > 0, f1
    # the crash triggered an immediate fault review (re-placement event
    # strictly after the SERVER_DOWN tick is in the timeline, staged or not)
    reviews = [e for e in cl1.controller.events if e.get("fault_review")]
    assert reviews and reviews[0]["reason"] == "server-down", cl1.controller.events
    print("failover completes every request OK:", t1)

    # KV bookkeeping after the eviction churn
    for rtm in cl1.backend.runtimes:
        rtm.check_invariants()
        if getattr(rtm, "allocator", None) is not None:
            assert rtm.allocator.n_free == rtm.allocator.capacity_blocks, (
                "evicted victims leaked KV pages: "
                f"{rtm.allocator.n_free}/{rtm.allocator.capacity_blocks} free"
            )
    print("page recycling + invariants OK")

    _, h2, t2, tok2, m2 = run_once(failover=True)
    assert t1 == t2, f"fault timelines differ across reruns:\n{t1}\n{t2}"
    assert tok1 == tok2, "token streams differ across reruns"
    assert m1["faults"] == m2["faults"], (m1["faults"], m2["faults"])
    print("rerun determinism OK")

    # token identity: the crash + re-prefill must not change any output.
    # The reference engine is reused for the no-failover leg below (one
    # build fewer: generate() does not perturb determinism — tokens are
    # batch-composition invariant and the cluster meter seeds off the
    # engine's pre-served stats).
    built = build_engine()
    cfg, _, _, engine = built
    requests = build_requests(cfg)
    ref, _ = engine.generate(np.stack([r.prompt for r in requests]), steps=STEPS)
    for k in range(N_REQUESTS):
        np.testing.assert_array_equal(np.asarray(tok1[k], np.int32), ref[k])
    print("token identity across crash/failover OK")

    _, h3, t3, tok3, m3 = run_once(failover=False, built=built)
    f3 = m3["faults"]
    assert f3["requests_dropped"] >= 1, f3
    assert f3["tokens_lost"] >= f3["requests_dropped"] * STEPS, f3
    assert f3["recovered"] == 0, f3
    survivors = sum(h.done for h in h3)
    assert survivors == N_REQUESTS - f3["requests_dropped"], (survivors, f3)
    print("no-failover baseline drops victims OK:", f3)
    print("ALL OK")


if __name__ == "__main__":
    main()

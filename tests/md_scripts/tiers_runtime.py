"""Expert tier hierarchy on the runtime EdgeCluster backend (3 fake
devices, one EP rank per edge server).

The oversized-model scenario against the real jitted serving stack: the
plan assigns every server the full expert set, but each server's modeled
GPU tier holds only one expert per layer — the rest park in host RAM.
The engine keeps physical slots for every assigned expert (tiers are a
*modeled* residency overlay; the oversized constraint lives in the
``ServerProfile`` byte budgets, not in device memory), so tier state can
never break EP expert coverage.

Checks:
  1. serving completes every request, and the token streams are
     bit-identical to sequential ``generate()`` — tier bookkeeping and
     mid-run promotions must not change a single output token;
  2. the scenario is genuinely oversized (aggregate GPU slots < the
     expert set) and back-tier activations book on-demand fetches;
  3. the activation-aware prefetcher promotes at least one expert into
     GPU residency through the staged-transfer scheduler;
  4. reruns are bit-identical: token streams and the ``metrics.tiers``
     summary.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=3")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.placement import PlacementPlan
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.engine import ServingEngine
from repro.serving.net import CommCostModel, ServerProfile, Topology

N_SERVERS, PROMPT, STEPS, N_REQUESTS = 3, 16, 6, 6


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 3)
    # slots == num_experts: every rank can physically hold the full
    # assigned set, so the tier overlay never truncates coverage
    spec = M.EPSpec.build(
        mesh,
        cfg,
        ep_axes=("model",),
        slots=cfg.num_experts,
        capacity=4096,
        slot_capacity=8192,
    )
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0, n_groups)
    engine = ServingEngine(
        rt=rt,
        params=params,
        placement=pls0,
        dense_master=params_dense["groups"],
        max_len=48,
    )
    return cfg, spec, n_groups, engine


def build_topology(cfg, n_groups):
    # GPU tier: 1 expert slot per layer per server (aggregate 3 < 4
    # experts per layer = oversized); host tier: the full set, fast
    # PCIe-ish host links so promotions land within a tick or two
    eb = 3 * cfg.d_model * cfg.d_ff * 2
    profiles = tuple(
        ServerProfile(
            f"e{i}",
            mem_bytes=n_groups * eb,
            host_mem_bytes=cfg.num_experts * n_groups * eb,
            host_bw=1e9,
        )
        for i in range(N_SERVERS)
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def full_replication_plan(n_groups, num_experts):
    assign = [
        [list(range(num_experts)) for _ in range(N_SERVERS)]
        for _ in range(n_groups)
    ]
    counts = np.full((n_groups, N_SERVERS), num_experts)
    return PlacementPlan(assign=assign, counts=counts, num_experts=num_experts)


def build_requests(cfg):
    reqs = []
    for k in range(N_REQUESTS):
        src = TaskTokenSource(f"edge{k}", cfg.vocab_size, seed=10 + k)
        prompt = src.sample(1, PROMPT)[0]
        reqs.append(Request(prompt=prompt, max_new_tokens=STEPS, origin=k % N_SERVERS))
    return reqs


def run_once(built=None):
    cfg, spec, n_groups, engine = built if built is not None else build_engine()
    topo = build_topology(cfg, n_groups)
    pf = MoEProfile(
        num_layers=n_groups,
        num_experts=cfg.num_experts,
        top_k=cfg.top_k,
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
    )
    cm = CommCostModel(
        topology=topo,
        expert_bytes=pf.expert_bytes,
        activation_bytes=pf.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    # interval=1000: residency moves only through the tier prefetcher
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, pf, tiered=True),
        interval=1000.0,
        topology=topo,
    )
    ctrl.plan = full_replication_plan(n_groups, cfg.num_experts)
    cluster = EdgeCluster(
        "runtime",
        engine=engine,
        n_servers=N_SERVERS,
        controller=ctrl,
        topology=topo,
        runtime_opts=dict(max_slots=4, prefix_cache=False),
    )
    requests = build_requests(cfg)
    handles = [cluster.submit(r) for r in requests]
    cluster.run()
    tokens = [h.result().tolist() if h.done else None for h in handles]
    return cluster, handles, tokens, cluster.metrics()


def main():
    built = build_engine()
    cfg, _, n_groups, engine = built

    cl1, h1, tok1, m1 = run_once(built=built)
    assert all(h.done for h in h1), "oversized serving must finish every request"
    t1 = m1["tiers"]
    assert sum(t1["per_server_gpu_slots"]) < n_groups * cfg.num_experts, t1
    assert all(
        r <= c
        for r, c in zip(t1["per_server_gpu_resident"], t1["per_server_gpu_slots"])
    ), t1
    assert sum(t1["per_server_host_resident"]) > 0, t1
    assert t1["on_demand_fetches"] > 0, t1
    assert 0.0 <= t1["prefetch_hit_ratio"] <= 1.0, t1
    print("oversized tier accounting OK:", t1)

    assert t1["promotions"] >= 1, (
        f"the prefetcher never promoted an expert on the runtime backend: {t1}"
    )
    print("prefetch promotions on runtime backend OK")

    # tiers are a modeled overlay: promotions re-apply the plan under the
    # new slot priority mid-run, which must not change any output token
    requests = build_requests(cfg)
    ref, _ = engine.generate(np.stack([r.prompt for r in requests]), steps=STEPS)
    for k in range(N_REQUESTS):
        np.testing.assert_array_equal(np.asarray(tok1[k], np.int32), ref[k])
    print("token identity under tier promotions OK")

    _, h2, tok2, m2 = run_once()
    assert tok1 == tok2, "token streams differ across reruns"
    assert m1["tiers"] == m2["tiers"], (m1["tiers"], m2["tiers"])
    print("rerun determinism OK")
    print("ALL OK")


if __name__ == "__main__":
    main()

"""SLO-aware scheduling + temperature sampling on the real jitted runtime.

Checks, against the jitted serving stack (dense-MoE reduced engine):

  1. ``ServingRuntime(slo_aware=True)`` sheds requests whose deadline
     became unmeetable (SHED event, terminal FINISHED with ``tokens=0``,
     ``shed=True``, ``slo_met=False``) while the FIFO baseline burns
     decode rounds finishing them late;
  2. goodput (SLO-attained tokens per tick, via
     ``repro.serving.workload.goodput_report``) is **strictly** higher
     under SLO-aware scheduling than under FIFO on the same request set;
  3. admission is deadline-ordered (EDF): a tight-deadline request
     enqueued behind a loose one is admitted first;
  4. temperature sampling is deterministic end-to-end: a full rerun of
     the SLO-aware leg (temperature > 0, per-request seeds) reproduces
     every token stream and every goodput number bit-for-bit, and
     temperature-0 requests still match greedy ``generate()`` exactly.
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as tr
from repro.serving.api import EventType, Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime
from repro.serving.workload import goodput_report

BLOCK_SIZE = 8
# max_slots=2 serves the 8 requests in 4 waves of ~5 ticks each: with a
# 12-tick SLO the last two waves (latency 15 / 20) are doomed from the
# queue — FIFO finishes them late, SLO-aware sheds them
N_REQUESTS, STEPS, PROMPT, SLO = 8, 6, 8, 12.0


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    eng = ServingEngine(rt=rt, params=params, placement=None, max_len=48)
    src = TaskTokenSource("arith", cfg.vocab_size, seed=3)
    return eng, src


def build_requests(src):
    prompts = src.sample(N_REQUESTS, PROMPT)
    return [Request(prompt=prompts[k], max_new_tokens=STEPS, origin=None,
                    temperature=0.7 if k % 2 else 0.0, seed=100 + k,
                    slo=SLO)
            for k in range(N_REQUESTS)]


def run_leg(eng, requests, slo_aware):
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                         slo_aware=slo_aware)
    handles = [rtm.enqueue(r) for r in requests]
    rtm.run()
    rep = goodput_report(handles)
    toks = [h.result().tolist() for h in handles]
    return rtm, handles, rep, toks


def main():
    eng, src = build_engine()
    requests = build_requests(src)

    rt_slo, h_slo, rep_slo, tok_slo = run_leg(eng, requests, slo_aware=True)
    rt_fifo, h_fifo, rep_fifo, tok_fifo = run_leg(eng, requests,
                                                  slo_aware=False)

    # 1. the SLO-aware leg sheds the doomed tail; FIFO serves it late
    assert rt_slo.sheds >= 1, f"no sheds: {rep_slo}"
    assert rt_fifo.sheds == 0
    for h, toks in zip(h_slo, tok_slo):
        if h.metrics.get("shed"):
            assert toks == [] and h.metrics["slo_met"] is False
            assert any(e.type == EventType.SHED for e in h.events)
    late = [h for h in h_fifo if h.metrics["slo_met"] is False]
    assert late, "FIFO leg should finish some requests past their SLO"
    assert all(len(t) == STEPS for t in tok_fifo)
    print(f"shedding OK: {rt_slo.sheds} shed, {len(late)} late under FIFO")

    # 2. strict goodput win on the same request set
    g_slo = rep_slo["goodput_tokens_per_s"]
    g_fifo = rep_fifo["goodput_tokens_per_s"]
    assert g_slo > g_fifo, (g_slo, g_fifo)
    print(f"goodput OK: slo-aware {g_slo:.3f} > fifo {g_fifo:.3f} tok/tick")

    # 3. EDF: a tight-deadline request enqueued behind a loose one is
    # admitted first once a slot frees up
    rtm = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE,
                         slo_aware=True)
    blocker = rtm.enqueue(Request(prompt=requests[0].prompt,
                                  max_new_tokens=2))
    loose = rtm.enqueue(Request(prompt=requests[1].prompt,
                                max_new_tokens=2, slo=200.0))
    tight = rtm.enqueue(Request(prompt=requests[2].prompt,
                                max_new_tokens=2, slo=50.0))
    rtm.run()
    assert blocker.done and loose.done and tight.done
    assert tight.admitted_at < loose.admitted_at, (
        tight.admitted_at, loose.admitted_at)
    print("EDF admission order OK")

    # 4. bit-identical rerun (temperature sampling + shed decisions),
    # and temperature-0 rows equal greedy generate()
    _, _, rep2, tok2 = run_leg(eng, requests, slo_aware=True)
    assert rep2 == rep_slo, (rep2, rep_slo)
    assert tok2 == tok_slo
    ref, _ = eng.generate(np.stack([r.prompt for r in requests]),
                          steps=STEPS)
    for k, (r, toks) in enumerate(zip(requests, tok_fifo)):
        if r.temperature == 0.0:
            np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                          ref[k])
    # the sampled rows actually sample: at least one diverges from greedy
    assert any(tok_fifo[k] != ref[k].tolist()
               for k, r in enumerate(requests) if r.temperature > 0.0), (
        "temperature 0.7 never diverged from greedy — sampling inert?")
    print("determinism + greedy identity OK")
    print("ALL OK")


if __name__ == "__main__":
    main()

"""Staged migration on the runtime EdgeCluster backend (3 fake devices,
one EP rank per edge server).

Checks, against the real jitted serving stack:
  1. the staged lifecycle is ordered on the tick clock — a plan adopted by
     the mid-stream review goes live (MIGRATION_COMPLETED, engine tables
     swapped) only at a strictly later tick than MIGRATION_STARTED;
  2. reruns are deterministic: the full migration timeline (ticks, etas,
     modeled transfer seconds) and every generated token are identical;
  3. outputs stay token-identical to sequential ``generate()`` across the
     staged placement switch — with ``max_slots=4`` over 3 devices, so the
     chunk-prefill geometry (4 x 16 rows) is NOT device-count divisible
     and the EP dispatch row padding is exercised end to end.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=3")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.baselines import uniform_plan
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import EventType, Request
from repro.serving.cluster import EdgeCluster
from repro.serving.engine import ServingEngine
from repro.serving.net import CommCostModel, ServerProfile, Topology

N_SERVERS, PROMPT, STEPS, N_REQUESTS = 3, 16, 6, 6


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 3)
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                          capacity=4096, slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0,
                                            n_groups)
    engine = ServingEngine(rt=rt, params=params, placement=pls0,
                           dense_master=params_dense["groups"], max_len=48)
    return cfg, spec, n_groups, engine


def build_topology():
    profiles = (ServerProfile("e0", mem_bytes=8e9),
                ServerProfile("e1", mem_bytes=8e9),
                ServerProfile("e2", mem_bytes=2e9))
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    bw[0, 2] = bw[2, 0] = bw[1, 2] = bw[2, 1] = 25e6 / 8
    lat[0, 2] = lat[2, 0] = lat[1, 2] = lat[2, 1] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def build_requests(cfg):
    reqs = []
    for k in range(N_REQUESTS):
        prompt = TaskTokenSource(f"edge{k}", cfg.vocab_size,
                                 seed=10 + k).sample(1, PROMPT)[0]
        reqs.append(Request(prompt=prompt, max_new_tokens=STEPS,
                            origin=k % N_SERVERS))
    return reqs


def run_once():
    cfg, spec, n_groups, engine = build_engine()
    topo = build_topology()
    cm = CommCostModel(topology=topo,
                       expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                       activation_bytes=cfg.d_model * 2,
                       tokens_per_horizon=1e5)
    ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView.from_ep_spec(spec, n_groups),
        interval=STEPS, topology=topo)
    # uniform incumbent (what the engine boots with): the first review
    # then stages the move to the activation-aware plan
    ctrl.plan = uniform_plan(n_groups, N_SERVERS, cfg.num_experts)
    cluster = EdgeCluster("runtime", engine=engine, n_servers=N_SERVERS,
                          controller=ctrl, topology=topo,
                          runtime_opts=dict(max_slots=4, prefix_cache=False))
    requests = build_requests(cfg)
    handles = [cluster.submit(r) for r in requests]
    cluster.run()
    timeline = [(e.type, e.time, round(e.data.get("eta", 0.0), 9),
                 round(e.data.get("transfer_seconds", 0.0), 9))
                for e in cluster.events]
    tokens = [h.result().tolist() for h in handles]
    return timeline, tokens, cluster.metrics()


def main():
    t1, tok1, m1 = run_once()
    starts = [e for e in t1 if e[0] == EventType.MIGRATION_STARTED]
    dones = [e for e in t1 if e[0] == EventType.MIGRATION_COMPLETED]
    assert starts and dones, f"no staged migration ran: {t1}"
    assert starts[0][1] < dones[0][1], \
        f"plan went live at adoption tick, not after transfers: {t1}"
    assert dones[0][3] > 0, "completed migration models zero transfer time"
    assert m1["net"]["migrations"]["completed"] >= 1
    assert m1["net"]["cross_server_bytes"] > 0
    print("ordered staged lifecycle OK:", t1)

    t2, tok2, m2 = run_once()
    assert t1 == t2, f"migration timelines differ across reruns:\n{t1}\n{t2}"
    assert tok1 == tok2, "generated tokens differ across reruns"
    np.testing.assert_allclose(m1["net"]["link_bytes"],
                               m2["net"]["link_bytes"])
    print("rerun determinism OK")

    # token identity vs sequential generate() on a fresh engine (the
    # staged placement switch must not change any output)
    cfg, _, _, engine = build_engine()
    requests = build_requests(cfg)
    ref, _ = engine.generate(np.stack([r.prompt for r in requests]),
                             steps=STEPS)
    for k in range(N_REQUESTS):
        np.testing.assert_array_equal(np.asarray(tok1[k], np.int32), ref[k])
    print("token identity across staged migration OK")
    print("ALL OK")


if __name__ == "__main__":
    main()

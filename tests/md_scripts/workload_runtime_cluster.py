"""Flash-crowd stream against the WARMED multi-server runtime backend.

The full-bench-mode leg behind ``benchmarks.workload.run_runtime_leg``
(and the PR-9 carryover): the seeded flash-crowd ``WorkloadStream`` is
served end-to-end by a real 3-server ``EdgeCluster("runtime")`` — one
jitted EP engine spanning 3 fake CPU devices, AOT bucket-ladder warmup,
SLO-aware scheduling on the tick clock, unified span tracing on — not
just the reduced single-server engine of ``workload_runtime.py``.

Checks:

  1. the warmed zero-stall contract holds under the crowd: the AOT
     ladder compiled at least one executable and the serving loop never
     retraced past warmup;
  2. the crowd overloads the cluster enough that SLO-aware admission
     sheds at least one request, while everything submitted resolves;
  3. goodput is reported **per scenario phase** (offpeak/peak/flash)
     from the same ``goodput_report`` the sim leg uses;
  4. tracing rode along without dropping events.

Runs as a subprocess (the parent bench process cannot re-configure the
JAX device count once initialized).
"""

import os

# one EP rank per edge server (standalone script — safe before jax init)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=3")

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.cluster import EdgeCluster
from repro.serving.engine import ServingEngine
from repro.serving.workload import (FlashCrowd, WorkloadSpec,
                                    WorkloadStream, drive, goodput_report)

N_SERVERS = 3

# tick-clock scenario: arrivals land in submission order (the runtime
# backend queues at the submit tick); a serving wave takes a handful of
# ticks, so slo=26 ticks dooms the flash-crowd backlog tail
SPEC = WorkloadSpec(
    duration=60.0, base_rate=0.30, n_origins=N_SERVERS, origin_skew=0.8,
    diurnal_period=40.0, diurnal_amplitude=0.4,
    crowds=(FlashCrowd(start=20.0, duration=15.0, multiplier=5.0,
                       origin=2, fraction=0.9, task="flashtask"),),
    prompt_len=(12.0, 0.4, 8, 16), output_len=(6.0, 0.3, 4, 8),
    slo=26.0, seed=0)


def build_engine():
    cfg = get_config("mixtral-8x7b").reduced()  # 4 experts, top-2, 2 layers
    mesh = make_test_mesh(1, N_SERVERS)
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                          capacity=4096, slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl0 = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls0 = tr.stack_placement(pl0, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls0,
                                            n_groups)
    return ServingEngine(rt=rt, params=params, placement=pls0,
                         dense_master=params_dense["groups"], max_len=48)


def main():
    engine = build_engine()
    cluster = EdgeCluster(
        "runtime", engine=engine, n_servers=N_SERVERS, slo_aware=True,
        trace=True,
        runtime_opts=dict(max_slots=4, block_size=8, prefix_cache=False,
                          warmup=True, warmup_origins="tagged"))
    perf0 = cluster.backend.perf()
    print(f"warmup: {perf0['executables_compiled']} executables in "
          f"{perf0['warmup_seconds']:.1f}s")

    handles = drive(cluster, WorkloadStream(SPEC), max_pending=32)
    rep = goodput_report(handles)

    # 1. warmed zero-stall contract under the crowd
    perf = cluster.backend.perf()
    assert perf["executables_compiled"] >= 1
    assert perf["traces_after_warmup"] == 0, (
        f"the warmed loop retraced {perf['traces_after_warmup']} times")
    print(f"zero-stall OK: retraces={perf['traces_after_warmup']} "
          f"host_syncs={perf['host_syncs']}")

    # 2. the crowd forces sheds; every submission still resolves
    assert all(h.done for h in handles)
    assert rep["sheds"] >= 1, (
        f"flash crowd never forced a shed ({rep['requests']} requests) — "
        "the scenario no longer overloads the cluster")

    # 3. per-phase goodput from the shared accounting. The runtime
    # backend serves on the tick clock, so scenario phases are keyed on
    # each request's *stream arrival* (spec seconds), not its submit
    # tick — the sim leg's phase_of(submit) shortcut only works there
    # because sim submits land on the arrival timeline.
    by_phase: dict = {}
    for h in handles:
        by_phase.setdefault(SPEC.phase_of(h.request.arrival), []).append(h)
    assert len(by_phase) >= 2, (
        f"phase breakdown degenerate: {sorted(by_phase)}")
    print(f"goodput: {rep['goodput_tokens_per_s']:.3f} tok/tick "
          f"attainment={rep['slo_attainment']:.3f} sheds={rep['sheds']} "
          f"({rep['requests']} requests)")
    for ph, hs in sorted(by_phase.items()):
        d = goodput_report(hs)
        print(f"  phase {ph:8s}: {d['requests']:3d} req, "
              f"{d['sheds']:2d} shed, attainment {d['slo_attainment']:.3f}, "
              f"ttft p99 {d['ttft']['p99']:.1f} ticks")

    # 4. tracing rode the run without drops
    obs = cluster.metrics()["obs"]
    assert obs["dropped_events"] == 0
    assert obs["span_counts"].get("SHED", 0) >= 1
    print(f"trace OK: {obs['events']} spans, "
          f"sheds traced={obs['span_counts']['SHED']}")
    print("ALL OK")


if __name__ == "__main__":
    main()

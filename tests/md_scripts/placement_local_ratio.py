"""The system-level claim on-device: a DanceMoE activation-aware placement
achieves a higher local compute ratio than Uniform on skewed traffic (the
JAX analogue of the paper's Fig. 6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.placement import build_ep_placement, dancemoe_placement
from repro.models import moe as M
from repro.models import transformer as tr

cfg = get_config("mixtral-8x7b").reduced()   # 4 experts, top-2
from repro.launch.mesh import make_test_mesh, set_mesh
mesh = make_test_mesh(2, 4)
spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                      capacity=512, slot_capacity=2048)
_, n_groups = cfg.layer_pattern()
rt_d = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
key = jax.random.PRNGKey(0)
params_dense = tr.init_params(rt_d, key)


def regather(pls):
    groups = dict(params_dense["groups"])
    for k, v in params_dense["groups"].items():
        if "router" in v:
            per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v),
                                 jax.tree.map(lambda a: a[g], pls))
                   for g in range(n_groups)]
            groups[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    out = dict(params_dense)
    out["groups"] = groups
    return out


B, T = 8, 32
toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)

pl_u = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
pls_u = tr.stack_placement(pl_u, n_groups)
with set_mesh(mesh):
    _, _, st = jax.jit(lambda p, t, q: tr.prefill(
        rt, p, tokens=t, placement=q))(regather(pls_u), toks, pls_u)
counts = np.asarray(st["counts_per_rank"], np.float64)   # [G, n_ep, E]
lf_uniform = float(st["local_frac"].mean())

freqs = counts / np.maximum(counts.sum(-1, keepdims=True), 1e-9)
plan = dancemoe_placement(freqs, np.full(spec.n_ep, spec.slots * n_groups),
                          np.full(spec.n_ep, spec.slots))
pls_d = build_ep_placement(plan, spec.slots)
with set_mesh(mesh):
    lg_d, _, st2 = jax.jit(lambda p, t, q: tr.prefill(
        rt, p, tokens=t, placement=q))(regather(pls_d), toks, pls_d)
lf_dance = float(st2["local_frac"].mean())
assert lf_dance > lf_uniform, (lf_dance, lf_uniform)
print(f"local ratio uniform={lf_uniform:.3f} dancemoe={lf_dance:.3f}")
print("ALL OK")

"""Sharding layouts (tp / sp / cp / fsdp) must compute the SAME function:
loss and prefill logits agree across layouts on a 2x4 fake mesh."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as M
from repro.models import transformer as tr

from repro.launch.mesh import make_test_mesh, set_mesh
mesh = make_test_mesh(2, 4)
key = jax.random.PRNGKey(0)

# dense arch across all layouts
cfg = get_config("tinyllama-1.1b").reduced()
rt0 = tr.Runtime(cfg=cfg, mesh=mesh, layout="tp")
params = tr.init_params(rt0, key)
B, T = 4, 32
toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
ref_loss = ref_lg = None
for layout in ("tp", "sp", "cp", "fsdp"):
    rt = tr.Runtime(cfg=cfg, mesh=mesh, layout=layout,
                    remat_policy="dots+kv" if layout != "tp" else "none")
    with set_mesh(mesh):
        loss, _ = jax.jit(lambda p, t: tr.loss_fn(rt, p, t,
                                                  jnp.roll(t, -1, 1)))(params, toks)
        lg, _, _ = jax.jit(lambda p, t: tr.prefill(rt, p, tokens=t))(params, toks)
    if ref_loss is None:
        ref_loss, ref_lg = float(loss), lg
    else:
        assert abs(float(loss) - ref_loss) < 2e-3, (layout, float(loss), ref_loss)
        err = float(jnp.max(jnp.abs(lg - ref_lg)))
        assert err < 5e-4, (layout, err)
    print(f"dense {layout}: loss={float(loss):.4f} OK")

# MoE arch: tp vs sp/fsdp EP row path
cfg = get_config("mixtral-8x7b").reduced()
spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                      capacity=512, slot_capacity=2048)
pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
_, n_groups = cfg.layer_pattern()
pls = tr.stack_placement(pl, n_groups)
rt_d = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
params_d = tr.init_params(rt_d, key)
ge = dict(params_d["groups"])
for k, v in params_d["groups"].items():
    if "router" in v:
        per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v), pl)
               for g in range(n_groups)]
        ge[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
params_e = dict(params_d)
params_e["groups"] = ge
with set_mesh(mesh):
    lg_ref, _, _ = jax.jit(lambda p, t: tr.prefill(rt_d, p, tokens=t))(params_d, toks)
    for layout in ("tp", "sp", "fsdp"):
        rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec,
                        layout=layout)
        lg, _, st = jax.jit(lambda p, t, q: tr.prefill(
            rt, p, tokens=t, placement=q))(params_e, toks, pls)
        err = float(jnp.max(jnp.abs(lg - lg_ref)))
        assert err < 5e-4, (layout, err)
        print(f"moe {layout}: prefill err={err:.2e} OK")
print("ALL OK")

"""Full transformer with EP MoE == dense impl, on a 2x4 fake mesh:
train loss (ce), prefill logits, decode logits; plus a migration swap."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.placement import build_ep_placement, dancemoe_placement
from repro.models import moe as M
from repro.models import transformer as tr

cfg = get_config("mixtral-8x7b").reduced()
from repro.launch.mesh import make_test_mesh, set_mesh
mesh = make_test_mesh(2, 4)
spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",), slots=2,
                      capacity=512, slot_capacity=2048)
pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
_, n_groups = cfg.layer_pattern()
pls = tr.stack_placement(pl, n_groups)
key = jax.random.PRNGKey(0)
rt_d = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
rt_e = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
params_d = tr.init_params(rt_d, key)
gd = params_d["groups"]
ge = dict(gd)
for k, v in gd.items():
    if "router" in v:
        per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v), pl)
               for g in range(n_groups)]
        ge[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
params_e = dict(params_d)
params_e["groups"] = ge
B, T = 4, 16
toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)

with set_mesh(mesh):
    (_, md) = jax.jit(lambda p, t: tr.loss_fn(rt_d, p, t,
                                              jnp.roll(t, -1, 1)))(params_d,
                                                                   toks)
    (_, me) = jax.jit(lambda p, t, q: tr.loss_fn(
        rt_e, p, t, jnp.roll(t, -1, 1), placement=q))(params_e, toks, pls)
    assert abs(float(md["ce_loss"]) - float(me["ce_loss"])) < 1e-3
    lg_d, cd, _ = jax.jit(lambda p, t: tr.prefill(rt_d, p, tokens=t))(
        params_d, toks)
    lg_e, ce, _ = jax.jit(lambda p, t, q: tr.prefill(
        rt_e, p, tokens=t, placement=q))(params_e, toks, pls)
    assert float(jnp.max(jnp.abs(lg_d - lg_e))) < 5e-5
    d_d, _, _ = jax.jit(lambda p, c, t: tr.decode_step(
        rt_d, p, c, t, jnp.int32(T)))(params_d, cd, toks[:, :1])
    d_e, _, _ = jax.jit(lambda p, c, t, q: tr.decode_step(
        rt_e, p, c, t, jnp.int32(T), placement=q))(params_e, ce,
                                                   toks[:, :1], pls)
    assert float(jnp.max(jnp.abs(d_d - d_e))) < 5e-5

    # migration: a DanceMoE placement (with replication) must compute the
    # SAME function once weights are re-gathered (zero-recompile swap)
    freqs = np.random.default_rng(0).dirichlet(
        np.full(cfg.num_experts, 0.5), size=(n_groups, spec.n_ep))
    plan = dancemoe_placement(freqs, np.full(spec.n_ep, spec.slots * n_groups),
                              np.full(spec.n_ep, spec.slots))
    pls2 = build_ep_placement(plan, spec.slots)
    ge2 = dict(gd)
    for k, v in gd.items():
        if "router" in v:
            per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v),
                                 jax.tree.map(lambda a: a[g], pls2))
                   for g in range(n_groups)]
            ge2[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params_e2 = dict(params_d)
    params_e2["groups"] = ge2
    lg_m, _, sm = jax.jit(lambda p, t, q: tr.prefill(
        rt_e, p, tokens=t, placement=q))(params_e2, toks, pls2)
    assert float(jnp.max(jnp.abs(lg_d - lg_m))) < 5e-5
print("ALL OK")

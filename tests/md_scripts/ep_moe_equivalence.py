"""EP MoE layer == dense oracle, on a 2x4 fake mesh, all modes/EP layouts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as M

cfg = get_config("mixtral-8x7b").reduced()
from repro.launch.mesh import make_test_mesh, set_mesh
mesh = make_test_mesh(2, 4)
key = jax.random.PRNGKey(0)
dense_p = M.moe_params_dense(key, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
ref, ref_stats = M.moe_apply_dense(dense_p, cfg, x)

for ep_axes in [("model",), ("data", "model")]:
    n_ep = int(np.prod([mesh.shape[a] for a in ep_axes]))
    spec = M.EPSpec.build(mesh, cfg, ep_axes=ep_axes,
                          slots=max(2, -(-cfg.num_experts // n_ep) + 1),
                          capacity=8 * 16 * 2, slot_capacity=8 * 16 * 2 * n_ep)
    for pl_name, pl in [
        ("uniform", M.uniform_placement(n_ep, spec.slots, cfg.num_experts)),
    ]:
        ep_p = M.dense_to_ep(dense_p, pl)
        with set_mesh(mesh):
            for mode in ["prefill", "decode"]:
                xx = x if mode != "decode" else x[:, :1]
                rr = ref if mode != "decode" else \
                    M.moe_apply_dense(dense_p, cfg, xx)[0]
                out, stats = jax.jit(
                    lambda p, xi, q, m=mode: M.moe_apply_ep(
                        p, cfg, xi, mesh=mesh, spec=spec, placement=q,
                        mode=m))(ep_p, xx, pl)
                err = float(jnp.max(jnp.abs(out - rr)))
                assert err < 5e-5, (ep_axes, pl_name, mode, err)
                c = float(stats["counts"].sum())
                expect = xx.shape[0] * xx.shape[1] * cfg.top_k
                assert abs(c - expect) < 1e-3, (mode, c, expect)
                lf = float(stats["local_frac"])
                assert 0.0 <= lf <= 1.0
print("ALL OK")

"""Fault injection + failover: schedule/link-state units, controller
fault reviews (abort + re-plan, infeasible-coverage degradation), and the
sim-backend crash/failover lifecycle — including bit-identical reruns of
a fixed ``FaultSchedule`` (event timelines, latencies, link-byte
matrices)."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.core.stats import ActivationStats
from repro.serving.api import EventType, Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.faults import (
    LINK_DEGRADED,
    LINK_RESTORED,
    SERVER_DOWN,
    SERVER_JOINED,
    FaultEvent,
    FaultSchedule,
    apply_fault,
)
from repro.serving.net import CommCostModel, ServerProfile, Topology

PROFILE = MoEProfile(num_layers=4, num_experts=8, top_k=2, d_model=256, d_ff=512)


def make_topology() -> Topology:
    """3 servers, server 2 memory-poor behind a WAN-ish link. Crashing
    server 2 leaves 8 slots/layer for 8 experts — recovery feasible but
    only just: the survivors must transfer in the experts they lack, so
    a crash recovery actually stages work over the links."""
    base = 16 * PROFILE.expert_bytes  # 4 expert slots per layer
    profiles = (
        ServerProfile("lan0", mem_bytes=base, compute_speed=50e12),
        ServerProfile("lan1", mem_bytes=base, compute_speed=50e12),
        ServerProfile("wan2", mem_bytes=base / 2, compute_speed=50e12),
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    for a, b in ((0, 2), (1, 2)):
        bw[a, b] = bw[b, a] = 25e6 / 8
        lat[a, b] = lat[b, a] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def make_requests(n: int = 30, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for k in range(n):
        t += float(rng.exponential(4.0))
        reqs.append(
            Request(
                prompt=np.zeros(64, np.int32),
                max_new_tokens=20,
                origin=k % 3,
                arrival=t,
                task=f"task{k % 3}",
            )
        )
    return reqs


def make_controller(
    topo: Topology, interval: float = 20.0, seed: int = 0, tiered: bool = False
) -> PlacementController:
    from repro.data.traces import make_task_profile

    cm = CommCostModel(
        topology=topo,
        expert_bytes=PROFILE.expert_bytes,
        activation_bytes=PROFILE.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    stats = ActivationStats(PROFILE.num_layers, topo.n, PROFILE.num_experts, decay=0.9)
    for n in range(topo.n):
        tp = make_task_profile(
            f"task{n}", PROFILE.num_layers, PROFILE.num_experts, seed=seed
        )
        stats.update_server(n, tp.probs * 500.0 * PROFILE.top_k)
    return PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, PROFILE, tiered=tiered),
        interval=interval,
        topology=topo,
        stats=stats,
    )


def make_cluster(topo, schedule=None, failover=True, seed=0):
    return EdgeCluster(
        "sim",
        topology=topo,
        profile=PROFILE,
        controller=make_controller(topo),
        seed=seed,
        fault_schedule=schedule,
        failover=failover,
    )


def run_cluster(schedule=None, failover=True, n=30):
    topo = make_topology()
    ec = make_cluster(topo, schedule, failover)
    for r in make_requests(n):
        ec.submit(r)
    handles = ec.run()
    return topo, ec, handles


# -- FaultEvent / FaultSchedule units -----------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(1.0, "EARTHQUAKE")
    with pytest.raises(ValueError, match="time must be >= 0"):
        FaultEvent(-1.0, SERVER_DOWN, server=0)
    with pytest.raises(ValueError, match="requires server"):
        FaultEvent(1.0, SERVER_DOWN)
    with pytest.raises(ValueError, match="distinct src/dst"):
        FaultEvent(1.0, LINK_DEGRADED, src=1, dst=1, factor=0.5)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(1.0, LINK_DEGRADED, src=0, dst=1, factor=1.5)
    # link events don't need a factor when restoring
    FaultEvent(1.0, LINK_RESTORED, src=0, dst=1)


def test_schedule_orders_pops_and_replays():
    a = FaultEvent(5.0, SERVER_DOWN, server=1)
    b = FaultEvent(2.0, LINK_DEGRADED, src=0, dst=1, factor=0.5)
    c = FaultEvent(5.0, SERVER_JOINED, server=2)  # tie with a: stable
    s = FaultSchedule([a, b, c])
    assert [e.time for e in s] == [2.0, 5.0, 5.0]
    assert s.peek() is b and s.remaining == 3
    assert s.due(1.0) == []
    assert s.due(2.0) == [b]
    assert s.due(10.0) == [a, c]  # insertion order kept on the tie
    assert s.due(99.0) == [] and s.peek() is None and s.remaining == 0
    # replay: reset rewinds in place, copy is fresh and independent
    assert s.reset().due(10.0) == [b, a, c]
    fresh = s.copy()
    assert fresh.remaining == 3 and s.remaining == 0
    with pytest.raises(TypeError, match="not a FaultEvent"):
        FaultSchedule([(1.0, SERVER_DOWN)])


def test_schedule_constructors_validate_recovery_times():
    s = FaultSchedule.server_crash(10.0, 1, rejoin_at=20.0)
    assert [e.kind for e in s] == [SERVER_DOWN, SERVER_JOINED]
    with pytest.raises(ValueError, match="rejoin_at"):
        FaultSchedule.server_crash(10.0, 1, rejoin_at=10.0)
    s = FaultSchedule.link_brownout(5.0, 0, 2, 0.25, restore_at=9.0)
    assert [e.kind for e in s] == [LINK_DEGRADED, LINK_RESTORED]
    with pytest.raises(ValueError, match="restore_at"):
        FaultSchedule.link_brownout(5.0, 0, 2, 0.25, restore_at=1.0)


def test_apply_fault_flips_shared_link_state():
    topo = make_topology()
    st = topo.state
    assert st.up.all() and (st.bw_factor == 1.0).all()
    apply_fault(FaultEvent(1.0, SERVER_DOWN, server=2), topo)
    assert not st.up[2] and st.up[[0, 1]].all()
    apply_fault(FaultEvent(2.0, LINK_DEGRADED, src=0, dst=1, factor=0.25), topo)
    assert st.bw_factor[0, 1] == 0.25 and st.bw_factor[1, 0] == 1.0
    apply_fault(FaultEvent(3.0, LINK_RESTORED, src=0, dst=1), topo)
    assert st.bw_factor[0, 1] == 1.0
    apply_fault(FaultEvent(4.0, SERVER_JOINED, server=2), topo)
    assert st.up.all()


def test_cluster_requires_topology_for_faults():
    from repro.core.baselines import uniform_plan

    with pytest.raises(ValueError, match="needs a topology"):
        EdgeCluster(
            "sim",
            spec=make_topology().to_cluster_spec(),
            profile=PROFILE,
            plan=uniform_plan(PROFILE.num_layers, 3, PROFILE.num_experts),
            topology=None,
            fault_schedule=FaultSchedule.server_crash(1.0, 0),
        )


# -- controller fault reviews -------------------------------------------


def _staged_controller(topo):
    """A controller with a staged migration in flight (uniform incumbent,
    skewed stats -> the forced review stages a move)."""
    from repro.core.baselines import uniform_plan

    ctrl = make_controller(topo, interval=1.0)
    ctrl.plan = uniform_plan(PROFILE.num_layers, topo.n, PROFILE.num_experts)
    ctrl.last_review = 0.0
    dec = ctrl.review(10.0, force=True)
    assert dec.staged and ctrl.pending is not None
    return ctrl


def test_fault_review_aborts_pending_and_replans():
    topo = make_topology()
    ctrl = _staged_controller(topo)
    # kill the WAN server (2) while a staged transfer sources from it:
    # the survivors' 8 slots still cover the 8 experts, so the re-plan
    # stays feasible (killing a 4-slot LAN server would not be — that
    # path is test_fault_review_infeasible_coverage_keeps_incumbent)
    task = next(t for t in ctrl.pending.tasks if t.src == 2)
    apply_fault(FaultEvent(11.0, SERVER_DOWN, server=task.src), topo)
    assert ctrl.pending_affected()
    dec = ctrl.fault_review(11.0, cause="server-down")
    aborted = [e for e in ctrl.events if e.get("reason") == "migration-aborted"]
    assert len(aborted) == 1 and aborted[0]["abort_cause"] == "server-down"
    assert dec.adopted
    # if the re-plan staged fresh transfers, none of them may source from
    # (or land on) the dead server
    if dec.staged:
        for t in ctrl.pending.tasks:
            assert t.src != task.src and t.dst != task.src


def test_pending_unaffected_by_unrelated_link():
    topo = make_topology()
    ctrl = _staged_controller(topo)
    # pin the in-flight transfers to the 0->1 link (plus local loads) so
    # the un-used links are known, not luck-of-the-stats
    pinned = [
        t for t in ctrl.pending.tasks if (t.src, t.dst) == (0, 1) or t.src == t.dst
    ]
    assert any(t.src != t.dst for t in pinned), "need one 0->1 transfer"
    ctrl.pending.tasks = pinned
    apply_fault(FaultEvent(11.0, LINK_DEGRADED, src=1, dst=2, factor=0.1), topo)
    assert not ctrl.pending_affected()
    # ... and the used link still trips the predicate
    apply_fault(FaultEvent(12.0, LINK_DEGRADED, src=0, dst=1, factor=0.1), topo)
    assert ctrl.pending_affected()


def test_fault_review_degraded_link_reprices_pending():
    topo = make_topology()
    ctrl = _staged_controller(topo)
    inter = [t for t in ctrl.pending.tasks if t.src != t.dst]
    if not inter:
        pytest.skip("staged plan is all-local")
    t0 = inter[0]
    apply_fault(
        FaultEvent(11.0, LINK_DEGRADED, src=t0.src, dst=t0.dst, factor=0.01), topo
    )
    assert ctrl.pending_affected()
    old_eta = ctrl.pending.eta
    dec = ctrl.fault_review(11.0, cause="link-degraded")
    assert dec.adopted
    if dec.staged and any(
        t.src == t0.src and t.dst == t0.dst for t in ctrl.pending.tasks
    ):
        # still using the degraded link: the new schedule must price the
        # 100x slower bandwidth, not replay the stale eta
        assert ctrl.pending.eta > old_eta


def test_fault_review_infeasible_coverage_keeps_incumbent():
    """Survivors that cannot hold every expert must not crash the control
    plane: the review reports infeasible and keeps the incumbent plan."""
    base = 16 * PROFILE.expert_bytes  # 4 slots/layer per server
    profiles = (ServerProfile("a", mem_bytes=base), ServerProfile("b", mem_bytes=base))
    bw = np.full((2, 2), 500e6 / 8)
    lat = np.full((2, 2), 2e-3)
    np.fill_diagonal(lat, 0.0)
    topo = Topology(profiles, bw, lat)
    from repro.core.baselines import uniform_plan
    from repro.data.traces import make_task_profile

    cm = CommCostModel(
        topology=topo,
        expert_bytes=PROFILE.expert_bytes,
        activation_bytes=PROFILE.hidden_bytes_per_token,
        tokens_per_horizon=1e5,
    )
    stats = ActivationStats(PROFILE.num_layers, 2, PROFILE.num_experts)
    for n in range(2):
        tp = make_task_profile(
            f"task{n}", PROFILE.num_layers, PROFILE.num_experts, seed=0
        )
        stats.update_server(n, tp.probs * 500.0)
    ctrl = PlacementController(
        policy=get_policy("dancemoe"),
        cost=cm,
        cluster=ClusterView.from_topology(topo, PROFILE),
        interval=20.0,
        topology=topo,
        stats=stats,
    )
    incumbent = uniform_plan(PROFILE.num_layers, 2, PROFILE.num_experts)
    ctrl.plan = incumbent
    apply_fault(FaultEvent(5.0, SERVER_DOWN, server=1), topo)
    dec = ctrl.fault_review(5.0, cause="server-down")  # 4 slots < 8 experts
    assert not dec.adopted and not dec.staged
    assert "infeasible" in dec.diag
    assert ctrl.plan is incumbent


# -- sim-backend crash / failover lifecycle -----------------------------


def test_failover_completes_every_request():
    sched = FaultSchedule.server_crash(60.0, 2)
    topo, ec, handles = run_cluster(sched)
    assert all(h.done for h in handles)
    f = ec.metrics()["faults"]
    assert f == {
        "injected": 1,
        "recovered": 1,
        "tokens_lost": 0,
        "recovery_seconds": f["recovery_seconds"],
        "requests_dropped": 0,
        "failover": True,
    }
    assert f["recovery_seconds"] > 0  # the recovery migration's eta
    downs = [e for e in ec.events if e.type == EventType.SERVER_DOWN]
    assert len(downs) == 1 and downs[0].data["server"] == 2
    assert not topo.state.up[2]


def test_no_failover_baseline_drops_dead_origin():
    sched = FaultSchedule.server_crash(60.0, 2)
    topo, ec, handles = run_cluster(sched, failover=False)
    f = ec.metrics()["faults"]
    # every post-crash arrival homed on server 2 is abandoned
    lost = [h for h in handles if h.request.origin == 2 and h.request.arrival > 60.0]
    assert f["requests_dropped"] == len(lost) >= 1
    assert f["tokens_lost"] == 20 * len(lost)
    assert f["recovered"] == 0
    assert all(not h.done for h in lost)
    survivors = [h for h in handles if h not in lost]
    assert all(h.done for h in survivors)


def test_failover_beats_baseline_on_tokens_lost():
    sched = FaultSchedule.server_crash(60.0, 2)
    _, ec_f, _ = run_cluster(sched.copy())
    _, ec_b, _ = run_cluster(sched.copy(), failover=False)
    lost_f = ec_f.metrics()["faults"]["tokens_lost"]
    lost_b = ec_b.metrics()["faults"]["tokens_lost"]
    assert lost_f < lost_b


def test_fault_rerun_is_bit_identical():
    """The acceptance gate: two runs of the same schedule produce
    bit-identical latencies, event timelines and link-byte matrices."""
    sched = FaultSchedule(
        [
            FaultEvent(40.0, LINK_DEGRADED, src=0, dst=1, factor=0.5),
            FaultEvent(60.0, SERVER_DOWN, server=2),
            FaultEvent(80.0, LINK_RESTORED, src=0, dst=1),
        ]
    )

    def run():
        _, ec, handles = run_cluster(sched.copy())
        lat = [h.metrics.get("latency") for h in handles]
        timeline = [(e.type, e.rid, e.time) for e in ec.events]
        return lat, timeline, ec.metrics()

    lat1, t1, m1 = run()
    lat2, t2, m2 = run()
    assert lat1 == lat2  # ==, not allclose: bit-identical
    assert t1 == t2
    assert m1["faults"] == m2["faults"]
    assert m1["net"]["link_bytes"] == m2["net"]["link_bytes"]


def test_fault_free_run_unchanged_by_fault_plumbing():
    """An empty schedule (and no schedule at all) must serve identically:
    the liveness masks are inert while every server is up."""
    _, ec0, h0 = run_cluster(None)
    _, ec1, h1 = run_cluster(FaultSchedule())
    lat0 = [h.metrics.get("latency") for h in h0]
    lat1 = [h.metrics.get("latency") for h in h1]
    assert lat0 == lat1
    assert "faults" not in ec0.metrics()
    assert ec1.metrics()["faults"]["injected"] == 0


def test_crash_with_rejoin_restores_capacity():
    sched = FaultSchedule.server_crash(60.0, 2, rejoin_at=90.0)
    topo, ec, handles = run_cluster(sched)
    assert all(h.done for h in handles)
    kinds = [
        e.type
        for e in ec.events
        if e.type in (EventType.SERVER_DOWN, EventType.SERVER_JOINED)
    ]
    assert kinds == [EventType.SERVER_DOWN, EventType.SERVER_JOINED]
    assert topo.state.up.all()
    assert ec.metrics()["faults"]["injected"] == 2


# -- runtime backend (jitted stack, 3 fake devices, subprocess) ---------


def test_runtime_backend_failover_subprocess():
    """Crash/failover against the real jitted serving stack: victims are
    evicted and re-routed, every request completes token-identical to
    sequential generate(), reruns are bit-identical, evicted pages are
    recycled, and the no-failover baseline drops the victims. Subprocess
    keeps the fake device count out of this process (the tier-1
    convention, see test_multidevice)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    script = Path(__file__).parent / "md_scripts" / "failover_runtime.py"
    r = subprocess.run(
        [sys.executable, str(script)],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert r.returncode == 0, f"failover_runtime.py failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL OK" in r.stdout


# -- expert tiers under faults ------------------------------------------


def make_tiered_topology() -> Topology:
    """The fault testbed with host-RAM expert tiers: each server's GPU
    holds 2 slots/layer (6 aggregate < 8 experts/layer — oversized, so
    Algorithm 1 is only feasible through the tiered budgets) while host
    tiers hold the full set."""
    eb, L = PROFILE.expert_bytes, PROFILE.num_layers
    profiles = tuple(
        ServerProfile(
            name,
            mem_bytes=2 * L * eb,
            host_mem_bytes=8 * L * eb,
            host_bw=2e9,
            compute_speed=50e12,
        )
        for name in ("lan0", "lan1", "wan2")
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    for a, b in ((0, 2), (1, 2)):
        bw[a, b] = bw[b, a] = 25e6 / 8
        lat[a, b] = lat[b, a] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


def run_tiered_cluster(schedule=None, failover=True, n=30):
    topo = make_tiered_topology()
    ec = EdgeCluster(
        "sim",
        topology=topo,
        profile=PROFILE,
        controller=make_controller(topo, tiered=True),
        seed=0,
        fault_schedule=schedule,
        failover=failover,
    )
    for r in make_requests(n):
        ec.submit(r)
    handles = ec.run()
    return topo, ec, handles


def test_tiered_crash_demotes_residency_and_completes():
    """A mid-run crash on a tiered cluster: the dead server's entire tier
    table is wiped (host RAM dies with the box), the fault review
    re-plans tiered residency onto the survivors, and failover still
    finishes every request."""
    sched = FaultSchedule.server_crash(60.0, 2)
    topo, ec, handles = run_tiered_cluster(sched)
    assert all(h.done for h in handles)
    m = ec.metrics()
    t = m["tiers"]
    assert (
        sum(t["per_server_gpu_slots"]) < PROFILE.num_layers * PROFILE.num_experts
    ), "testbed must be oversized for the tier path to matter"
    assert t["per_server_gpu_resident"][2] == 0
    assert t["per_server_host_resident"][2] == 0
    assert t["per_server_gpu_resident"][0] > 0
    assert m["faults"]["injected"] == 1
    assert m["faults"]["recovered"] == 1


def test_tiered_crash_rerun_bit_identical():
    """The fault-determinism contract extends to tiers: reruns of the
    same schedule on the tiered cluster reproduce latencies, event
    timelines, link bytes and the whole ``metrics.tiers`` section
    bit-identically."""
    sched = FaultSchedule(
        [
            FaultEvent(40.0, LINK_DEGRADED, src=0, dst=1, factor=0.5),
            FaultEvent(60.0, SERVER_DOWN, server=2),
            FaultEvent(80.0, LINK_RESTORED, src=0, dst=1),
        ]
    )

    def run():
        _, ec, handles = run_tiered_cluster(sched.copy())
        lat = [h.metrics.get("latency") for h in handles]
        timeline = [(e.type, e.rid, e.time) for e in ec.events]
        return lat, timeline, ec.metrics()

    lat1, t1, m1 = run()
    lat2, t2, m2 = run()
    assert lat1 == lat2  # ==, not allclose: bit-identical
    assert t1 == t2
    assert m1["tiers"] == m2["tiers"]
    assert m1["faults"] == m2["faults"]
    assert m1["net"]["link_bytes"] == m2["net"]["link_bytes"]

"""End-to-end system behaviour: the full observe -> place -> serve ->
migrate loop on the JAX engine (single device; the multi-rank version runs
in test_multidevice)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.migration import CostModel
from repro.core.placement import dancemoe_placement
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import GlobalScheduler


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                          slots=cfg.num_experts, capacity=4096,
                          slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    key = jax.random.PRNGKey(0)
    params_dense = tr.init_params(rt_dense, key)
    pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls = tr.stack_placement(pl, n_groups)
    groups = dict(params_dense["groups"])
    for k, v in params_dense["groups"].items():
        if "router" in v:
            per = [M.dense_to_ep(jax.tree.map(lambda a: a[g], v), pl)
                   for g in range(n_groups)]
            groups[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params = dict(params_dense)
    params["groups"] = groups
    eng = ServingEngine(rt=rt, params=params, placement=pls,
                        dense_master=params_dense["groups"], max_len=64)
    return cfg, spec, n_groups, eng


def test_generate_and_stats_collection(engine_setup):
    cfg, spec, n_groups, eng = engine_setup
    src = TaskTokenSource("arith", cfg.vocab_size, seed=0)
    gen, info = eng.generate(src.sample(2, 16), steps=4)
    assert gen.shape == (2, 4)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    # gating statistics flowed to the scheduler-side tracker
    assert eng.stats.counts.sum() > 0
    assert eng.stats.counts.shape == (n_groups, spec.n_ep, cfg.num_experts)


def test_scheduler_migration_preserves_function(engine_setup):
    cfg, spec, n_groups, eng = engine_setup
    src = TaskTokenSource("arith", cfg.vocab_size, seed=0)
    prompts = src.sample(2, 16)
    before, _ = eng.generate(prompts, steps=4)
    cm = CostModel(expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                   activation_bytes=cfg.d_model * 2, bandwidth=62.5e6,
                   tokens_per_horizon=1e6)
    sched = GlobalScheduler(
        engine=eng, capacity=np.full(spec.n_ep, spec.slots * n_groups),
        cost=cm, interval_batches=1,
        placement_fn=lambda f: dancemoe_placement(
            f, np.full(spec.n_ep, spec.slots * n_groups),
            np.full(spec.n_ep, spec.slots)))
    assert sched.after_batch()                   # initial adoption
    after, _ = eng.generate(prompts, steps=4)
    np.testing.assert_array_equal(before, after)  # function preserved

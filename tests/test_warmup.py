"""AOT bucket-ladder warmup + zero-stall decode loop: ladder size, the
zero-retrace guarantee over varying occupancy, token identity vs the
synchronous loop, and the warmup-off path staying unchanged."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    spec = M.EPSpec.build(
        mesh,
        cfg,
        ep_axes=("model",),
        slots=cfg.num_experts,
        capacity=4096,
        slot_capacity=8192,
    )
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls = tr.stack_placement(pl, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls, n_groups)
    eng = ServingEngine(
        rt=rt,
        params=params,
        placement=pls,
        dense_master=params_dense["groups"],
        max_len=64,
    )
    src = TaskTokenSource("warm", cfg.vocab_size, seed=0)
    return eng, src


def _rtm(eng, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("block_size", 8)
    return ServingRuntime(eng, **kw)


def test_warmup_ladder_size_and_cache_reuse(engine_setup):
    eng, src = engine_setup
    rtm = _rtm(eng, warmup=True, warmup_origins="untagged")
    # max_slots=4 untagged: widths {1, 2, 4} x {chunk, dec} + copy-block
    assert rtm.executables_compiled == 7
    assert rtm.warmup_seconds > 0
    # a second runtime with the same geometry reuses the engine-level
    # executable cache — its warmup is (near) free
    rtm2 = _rtm(eng, warmup=True, warmup_origins="untagged")
    assert rtm2.executables_compiled == 7
    assert rtm2.warmup_seconds < rtm.warmup_seconds


def test_zero_retraces_across_varying_occupancy(engine_setup):
    """Mixed admit/decode/retire stream that shrinks and grows occupancy
    through every compaction bucket — zero jit traces after warmup."""
    eng, src = engine_setup
    rtm = _rtm(eng, warmup=True, warmup_origins="untagged")
    floor = rtm.traces_after_warmup  # 0 unless another test retraced first
    # wave 1: fill all 4 slots (buckets 1 -> 2 -> 4), staggered arrivals
    handles = []
    for k in range(4):
        req = Request(
            prompt=src.sample(1, 8 + 8 * (k % 2))[0], max_new_tokens=3 + 2 * k
        )
        handles.append(rtm.enqueue(req))
        rtm.step()
    # drain to a single slot (bucket 4 -> 2 -> 1), then refill (1 -> 4)
    while rtm.active > 1:
        rtm.step()
    for _ in range(3):
        req = Request(prompt=src.sample(1, 16)[0], max_new_tokens=4)
        handles.append(rtm.enqueue(req))
    while rtm.queue or rtm.active or rtm._pending:
        rtm.step()
    rtm.flush()
    rtm.check_invariants()
    assert all(h.done for h in handles)
    assert rtm.traces_after_warmup == floor == 0
    assert rtm.perf_metrics()["traces_after_warmup"] == 0
    assert rtm.perf_metrics()["rounds_timed"] > 0


def test_warm_tokens_match_sync_loop(engine_setup):
    eng, src = engine_setup
    prompts = [src.sample(1, n)[0] for n in (16, 12, 16)]
    needs = [6, 4, 5]
    out = {}
    for warm in (False, True):
        rtm = _rtm(eng, warmup=warm, warmup_origins="untagged")
        hs = [
            rtm.enqueue(Request(prompt=p, max_new_tokens=s))
            for p, s in zip(prompts, needs)
        ]
        res = rtm.run()
        out[warm] = [res[h.rid] for h in hs]
        if warm:
            assert rtm.traces_after_warmup == 0
            # host_syncs counts drains that actually had to wait on a
            # device fetch — 0 on an idle machine, but copy readiness is
            # timing-dependent, so only assert the loop never degenerates
            # to the sync loop's one mandatory fetch per round
            assert rtm.host_syncs < rtm.rounds
    for a, b in zip(out[False], out[True]):
        assert np.array_equal(a, b)


def test_warmup_off_unchanged(engine_setup):
    """warmup=False keeps the lazy-jit synchronous loop: traces happen,
    no backlog forms, every round pays one host sync."""
    eng, src = engine_setup
    rtm = _rtm(eng)
    assert rtm.warmup is False and rtm.executables_compiled == 0
    h = rtm.enqueue(Request(prompt=src.sample(1, 16)[0], max_new_tokens=4))
    rtm.run()
    assert h.done and len(h.tokens) == 4
    assert not rtm._pending
    assert rtm.host_syncs >= rtm.rounds > 0


def test_warmup_requires_paged_pool(engine_setup):
    eng, _ = engine_setup
    with pytest.raises(ValueError, match="paged"):
        ServingRuntime(eng, max_slots=2, paged=False, warmup=True)

"""Unit tests for the paged KV pool's free-list ``BlockAllocator``
(pure Python — no JAX, no engine)."""
import pytest

from repro.serving.runtime import BlockAllocator


def test_null_block_reserved_and_capacity():
    a = BlockAllocator(9)
    assert a.capacity_blocks == 8
    got = a.alloc(8, owner=0)
    assert 0 not in got                       # block 0 never handed out
    assert sorted(got) == list(range(1, 9))
    assert a.n_free == 0


def test_exhaustion_is_a_clean_refusal():
    """``can_alloc`` lets callers defer; a forced over-allocation raises
    without corrupting state."""
    a = BlockAllocator(5)
    a.alloc(3, owner=0)
    assert not a.can_alloc(2)
    with pytest.raises(RuntimeError):
        a.alloc(2, owner=1)
    assert a.n_free == 1                      # nothing leaked by the refusal
    assert set(a.owners().values()) == {0}
    got = a.alloc(1, owner=1)                 # what fits still allocates
    assert len(got) == 1


def test_freed_blocks_are_reused():
    a = BlockAllocator(4)
    first = a.alloc(3, owner=0)
    a.release(first, owner=0)
    second = a.alloc(3, owner=1)
    assert set(second) == set(first)          # free-list reuse, no growth
    assert all(o == 1 for o in a.owners().values())


def test_no_block_owned_by_two_requests():
    a = BlockAllocator(6)
    x = a.alloc(2, owner=0)
    y = a.alloc(2, owner=1)
    assert not set(x) & set(y)
    owners = a.owners()
    assert {owners[b] for b in x} == {0}
    assert {owners[b] for b in y} == {1}


def test_release_returns_all_pages():
    a = BlockAllocator(6)
    x = a.alloc(4, owner=7)
    a.release(x, owner=7)
    assert a.n_free == a.capacity_blocks
    assert a.owners() == {}


def test_foreign_and_double_free_raise():
    a = BlockAllocator(6)
    x = a.alloc(2, owner=0)
    with pytest.raises(RuntimeError):
        a.release(x, owner=1)                 # foreign free
    a.release(x, owner=0)
    with pytest.raises(RuntimeError):
        a.release(x, owner=0)                 # double free
    assert a.n_free == a.capacity_blocks


def test_min_size_validated():
    with pytest.raises(ValueError):
        BlockAllocator(1)

"""Unit tests for the paged KV pool's reference-counted ``BlockAllocator``
(pure Python — no JAX, no engine). Since the radix prefix cache landed,
blocks are shared: ``alloc`` hands out fresh blocks at refcount 1,
``acquire`` adds a reference (a sharing slot or the cache), and ``release``
recycles a block only when the last reference drops."""
import pytest

from repro.serving.runtime import BlockAllocator


def test_null_block_reserved_and_capacity():
    a = BlockAllocator(9)
    assert a.capacity_blocks == 8
    got = a.alloc(8)
    assert 0 not in got                       # block 0 never handed out
    assert sorted(got) == list(range(1, 9))
    assert a.n_free == 0
    assert all(a.refcount(b) == 1 for b in got)


def test_exhaustion_is_a_clean_refusal():
    """``can_alloc`` lets callers defer; a forced over-allocation raises
    without corrupting state."""
    a = BlockAllocator(5)
    a.alloc(3)
    assert not a.can_alloc(2)
    with pytest.raises(RuntimeError):
        a.alloc(2)
    assert a.n_free == 1                      # nothing leaked by the refusal
    got = a.alloc(1)                          # what fits still allocates
    assert len(got) == 1


def test_freed_blocks_are_reused():
    a = BlockAllocator(4)
    first = a.alloc(3)
    assert a.release(first) == 3
    second = a.alloc(3)
    assert set(second) == set(first)          # free-list reuse, no growth


def test_refcounted_release_recycles_only_at_zero():
    """A shared block survives its first release and is recycled — and
    only then reusable — when the last holder lets go."""
    a = BlockAllocator(4)
    (b,) = a.alloc(1)
    a.acquire([b])                            # second holder (e.g. cache)
    a.acquire([b])                            # third holder
    assert a.refcount(b) == 3
    assert a.release([b]) == 0                # still held twice
    assert a.release([b]) == 0
    assert a.refcount(b) == 1
    assert b not in a.alloc(2)                # live block never re-issued
    assert a.release([b]) == 1                # last ref: recycled
    assert a.refcount(b) == 0
    assert b in a.alloc(1)


def test_acquire_requires_live_block():
    a = BlockAllocator(4)
    with pytest.raises(RuntimeError):
        a.acquire([2])                        # never allocated
    x = a.alloc(1)
    a.release(x)
    with pytest.raises(RuntimeError):
        a.acquire(x)                          # already recycled


def test_release_returns_all_pages():
    a = BlockAllocator(6)
    x = a.alloc(4)
    a.release(x)
    assert a.n_free == a.capacity_blocks
    assert a.live() == {}


def test_double_free_raises():
    a = BlockAllocator(6)
    x = a.alloc(2)
    a.release(x)
    with pytest.raises(RuntimeError):
        a.release(x)                          # refcount already hit zero
    assert a.n_free == a.capacity_blocks


def test_min_size_validated():
    with pytest.raises(ValueError):
        BlockAllocator(1)

"""Event-driven simulator tests (paper Sec. IV semantics)."""
import numpy as np
import pytest

from repro.core.baselines import redundance_plan, uniform_plan
from repro.core.migration import CostModel, MigrationController
from repro.core.placement import dancemoe_placement
from repro.data.traces import (BIGBENCH_TASKS, make_task_profile,
                               poisson_workload)
from repro.serving.cluster import (DEEPSEEK_V2_LITE_PROFILE, MIXTRAL_PROFILE,
                                   paper_testbed)
from repro.serving.simulator import EdgeSimulator


@pytest.fixture(scope="module")
def setup():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    wl = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                          num_experts=pf.num_experts, mean_interarrival=10.0,
                          duration=600.0, seed=0)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    return pf, cl, wl, cap, slots


def test_task_profiles_are_skewed_and_layer_dependent():
    tp = make_task_profile("arithmetic", 8, 16, seed=0)
    assert tp.probs.shape == (8, 16)
    assert np.allclose(tp.probs.sum(-1), 1.0)
    # different tasks prefer different experts (Fig. 2): the dominant
    # experts must differ in at least one layer
    tp2 = make_task_profile("ascii_recognition", 8, 16, seed=0)
    assert any(np.argmax(tp.probs[l]) != np.argmax(tp2.probs[l])
               for l in range(8))
    # and within a task, skew varies across layers (Fig. 3)
    tops = tp.probs.max(-1)
    assert tops.max() / tops.min() > 1.5


def test_workload_poisson_and_per_server_tasks():
    wl = poisson_workload(["a", "b", "c"], num_layers=4, num_experts=8,
                          mean_interarrival=5.0, duration=300.0, seed=1)
    assert all(r.arrival < 300.0 for r in wl.requests)
    by_server = {n: {r.task for r in wl.requests if r.server == n}
                 for n in range(3)}
    assert by_server[0] == {"a"} and by_server[2] == {"c"}
    f = wl.freqs_by_server(3)
    assert np.allclose(f.sum(-1), 1.0)


def test_simulator_determinism(setup):
    pf, cl, wl, cap, slots = setup
    plan = uniform_plan(pf.num_layers, cl.n, pf.num_experts)
    r1 = EdgeSimulator(cl, pf, wl, plan=plan, seed=3).run()
    r2 = EdgeSimulator(cl, pf, wl, plan=plan, seed=3).run()
    assert np.allclose(r1.latencies, r2.latencies)


def test_simulator_run_is_reentrant(setup):
    """run() on the same instance starts from a fresh timeline each time
    (the incremental start/serve_request core must not leak state into a
    second full pass; note the RNG stream continues, so only shapes and
    freshness are checked)."""
    pf, cl, wl, cap, slots = setup
    plan = uniform_plan(pf.num_layers, cl.n, pf.num_experts)
    sim = EdgeSimulator(cl, pf, wl, plan=plan, seed=3)
    r1 = sim.run()
    r2 = sim.run()
    assert len(r2.latencies) == len(r1.latencies) == len(wl.requests)
    # no phantom backlog from run 1: the second pass is not inflated
    assert r2.latencies.mean() < 2 * r1.latencies.mean()


def test_paper_ordering_dancemoe_beats_uniform(setup):
    pf, cl, wl, cap, slots = setup
    freqs = wl.freqs_by_server(cl.n)
    dm = EdgeSimulator(cl, pf, wl,
                       plan=dancemoe_placement(freqs, cap, slots),
                       seed=1).run()
    up = EdgeSimulator(cl, pf, wl,
                       plan=uniform_plan(pf.num_layers, cl.n,
                                         pf.num_experts), seed=1).run()
    assert dm.avg_latency < up.avg_latency
    dm_ratio = np.mean([x[1] for x in dm.local_ratio_t])
    up_ratio = np.mean([x[1] for x in up.local_ratio_t])
    assert dm_ratio > up_ratio
    assert 0.0 <= up_ratio <= 1.0


def test_offload_baseline_slowest_for_large_experts():
    """Table I: for Mixtral-sized experts, single-server offloading loses to
    naive collaboration."""
    pf = MIXTRAL_PROFILE
    cl = paper_testbed(0.7)
    wl = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                          num_experts=pf.num_experts, mean_interarrival=10.0,
                          duration=600.0, seed=0)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    off = EdgeSimulator(cl, pf, wl, mode="offload", seed=1).run()
    off_lb = EdgeSimulator(cl, pf, wl, mode="offload", redirect=True,
                           seed=1).run()
    collab = EdgeSimulator(
        cl, pf, wl, plan=redundance_plan(pf.num_layers, cl.n,
                                         pf.num_experts, cap, slots),
        seed=1).run()
    assert collab.avg_latency < off.avg_latency
    assert off_lb.avg_latency <= off.avg_latency * 1.05   # LB helps a bit


def test_migration_recovers_after_workload_shift(setup):
    pf, cl, wl, cap, slots = setup
    from repro.data.traces import Request, Workload
    wl2 = poisson_workload(["x_task", "y_task", "z_task"],
                           num_layers=pf.num_layers,
                           num_experts=pf.num_experts,
                           mean_interarrival=10.0, duration=600.0, seed=5)
    reqs = wl.requests + [Request(r.arrival + 600.0, r.server, r.task,
                                  r.prompt_tokens, r.decode_tokens)
                          for r in wl2.requests]
    merged = Workload(requests=reqs, tasks={**wl.tasks, **wl2.tasks},
                      duration=1200.0)
    cm = CostModel(expert_bytes=pf.expert_bytes,
                   activation_bytes=128 * pf.hidden_bytes_per_token,
                   bandwidth=cl.bandwidth,
                   io_speed=np.array([s.io_speed for s in cl.servers]),
                   tokens_per_horizon=2e4)
    static = EdgeSimulator(
        cl, pf, merged,
        plan=dancemoe_placement(wl.freqs_by_server(cl.n), cap, slots),
        seed=1).run()
    ctrl = MigrationController(
        placement_fn=lambda f: dancemoe_placement(f, cap, slots),
        cost=cm, interval=300.0)
    dyn = EdgeSimulator(cl, pf, merged, controller=ctrl, seed=1).run()
    assert len(dyn.migrations) >= 1
    arr = np.array([q.arrival for q in merged.requests])
    assert dyn.latencies[arr >= 600].mean() < \
        static.latencies[arr >= 600].mean()

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,C,D,F", [
    (1, 128, 64, 256), (2, 128, 128, 512), (4, 256, 64, 256),
    (3, 128, 96, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(S, C, D, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S * 1000 + C), 4)
    x = _rand(ks[0], (S, C, D), dtype, 0.5)
    w1 = _rand(ks[1], (S, D, F), dtype, D ** -0.5)
    w3 = _rand(ks[2], (S, D, F), dtype, D ** -0.5)
    w2 = _rand(ks[3], (S, F, D), dtype, F ** -0.5)
    y = ops.moe_gmm(x, w1, w3, w2, bc=128, bf=128)
    yr = ref.moe_gmm_ref(x, w1, w3, w2)
    tol = 5e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([128, 256]),
       st.sampled_from([64, 128]), st.sampled_from([256, 512]),
       st.integers(0, 100))
def test_moe_gmm_property(S, C, D, F, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (S, C, D), jnp.float32, 0.5)
    w1 = _rand(ks[1], (S, D, F), jnp.float32, D ** -0.5)
    w3 = _rand(ks[2], (S, D, F), jnp.float32, D ** -0.5)
    w2 = _rand(ks[3], (S, F, D), jnp.float32, F ** -0.5)
    y = ops.moe_gmm(x, w1, w3, w2, bc=128, bf=256)
    yr = ref.moe_gmm_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Tq,Tk,H,kvh,hd,causal,window", [
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 256, 256, 4, 4, 64, True, 64),
    (2, 128, 128, 2, 1, 128, True, 0),
    (1, 128, 128, 4, 2, 32, False, 0),
    (1, 512, 512, 2, 2, 64, True, 128),
])
def test_flash_attention_sweep(B, Tq, Tk, H, kvh, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(Tq + H), 3)
    q = _rand(ks[0], (B, Tq, H, hd), jnp.float32)
    k = _rand(ks[1], (B, Tk, kvh, hd), jnp.float32)
    v = _rand(ks[2], (B, Tk, kvh, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            bq=64, bk=64)
    grp = H // kvh
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = jnp.repeat(k, grp, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, hd)
    vf = jnp.repeat(v, grp, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, hd)
    orf = ref.flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    orf = orf.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (1, 128, 4, 64), jnp.bfloat16)
    k = _rand(ks[1], (1, 128, 2, 64), jnp.bfloat16)
    v = _rand(ks[2], (1, 128, 2, 64), jnp.bfloat16)
    o = ops.flash_attention(q, k, v, bq=64, bk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(4, 128, 64)
    kf = jnp.repeat(k, 2, 2).transpose(0, 2, 1, 3).reshape(4, 128, 64)
    vf = jnp.repeat(v, 2, 2).transpose(0, 2, 1, 3).reshape(4, 128, 64)
    orf = ref.flash_attention_ref(qf, kf, vf).reshape(1, 4, 128, 64)
    np.testing.assert_allclose(np.asarray(o.transpose(0, 2, 1, 3), np.float32),
                               np.asarray(orf, np.float32), atol=5e-2)


def test_flash_matches_model_chunked_attention():
    """Kernel agrees with the model-side pure-jnp chunked attention."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (2, 256, 4, 64), jnp.float32)
    k = _rand(ks[1], (2, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (2, 256, 2, 64), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, window=64, chunk=128)
    b = ops.flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSM selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,d,N,dtype", [
    (2, 64, 256, 16, jnp.float32),
    (1, 128, 128, 8, jnp.float32),
    (2, 32, 384, 16, jnp.float32),
    (1, 64, 128, 16, jnp.bfloat16),
])
def test_ssm_scan_sweep(B, T, d, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(T + d), 6)
    x = _rand(ks[0], (B, T, d), dtype, 0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, T, d), jnp.float32) - 1).astype(dtype)
    Bs = _rand(ks[2], (B, T, N), dtype, 0.3)
    Cs = _rand(ks[3], (B, T, N), dtype, 0.3)
    A = -jnp.exp(_rand(ks[4], (d, N), jnp.float32, 0.3))
    D = jnp.ones((d,), jnp.float32)
    y = ops.ssm_scan(x, dt, Bs, Cs, A, D, bd=128, bt=32)
    yr = ref.ssm_scan_ref(x, dt, Bs, Cs, A, D)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)


def test_ssm_scan_matches_model_linear_scan():
    """Kernel agrees with the model-side chunked associative scan."""
    from repro.models.ssm import linear_scan
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    B, T, d, N = 1, 64, 128, 8
    x = _rand(ks[0], (B, T, d), jnp.float32, 0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, T, d), jnp.float32) - 1)
    Bs = _rand(ks[2], (B, T, N), jnp.float32, 0.3)
    Cs = _rand(ks[3], (B, T, N), jnp.float32, 0.3)
    A = -jnp.exp(_rand(ks[4], (d, N), jnp.float32, 0.3))
    a = jnp.exp(dt[..., None] * A)
    b = (dt * x)[..., None] * Bs[:, :, None, :]
    hs, _ = linear_scan(a, b, jnp.zeros((B, d, N)), chunk=16)
    y_model = jnp.einsum("btdn,btn->btd", hs, Cs) + x
    y_kernel = ops.ssm_scan(x, dt, Bs, Cs, A, jnp.ones((d,)), bd=128, bt=16)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=1e-5, rtol=1e-4)

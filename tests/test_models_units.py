"""Model-substrate unit/property tests: attention math, linear scan,
EP geometry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import chunked_attention, decode_attention
from repro.models.ssm import linear_scan


def naive_attention(q, k, v, causal=True, window=0):
    B, Tq, H, hd = q.shape
    kvh = k.shape[2]
    grp = H // kvh
    kx = jnp.repeat(k, grp, 2)
    vx = jnp.repeat(v, grp, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * hd ** -0.5
    Tk = k.shape[1]
    mask = jnp.ones((Tq, Tk), bool)
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32))


@pytest.mark.parametrize("Tq,kvh,H,window,chunk", [
    (64, 2, 4, 0, 16), (96, 1, 4, 24, 32), (64, 4, 4, 16, 64),
])
def test_chunked_attention_matches_naive(Tq, kvh, H, window, chunk):
    ks = jax.random.split(jax.random.PRNGKey(Tq), 3)
    q = jax.random.normal(ks[0], (2, Tq, H, 32))
    k = jax.random.normal(ks[1], (2, Tq, kvh, 32))
    v = jax.random.normal(ks[2], (2, Tq, kvh, 32))
    got = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_decode_attention_ring_equals_full_window():
    """Ring-buffer attention over window W == full attention restricted to
    the last W positions."""
    W, S, kvh, hd = 16, 48, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, hd))
    k_full = jax.random.normal(ks[1], (1, S, kvh, hd))
    v_full = jax.random.normal(ks[2], (1, S, kvh, hd))
    pos = S - 1
    # ring cache holds positions pos-W+1 .. pos at slots p % W
    ring_k = jnp.zeros((1, W, kvh, hd))
    ring_v = jnp.zeros((1, W, kvh, hd))
    for p in range(pos - W + 1, pos + 1):
        ring_k = ring_k.at[:, p % W].set(k_full[:, p])
        ring_v = ring_v.at[:, p % W].set(v_full[:, p])
    got = decode_attention(q, ring_k, ring_v, jnp.int32(pos), ring=True)
    want = naive_attention(q, k_full, v_full, causal=False)[
        ...] * 0  # placeholder
    # reference: softmax over exactly the last W positions
    kx = jnp.repeat(k_full[:, pos - W + 1:], 2, 2)
    vx = jnp.repeat(v_full[:, pos - W + 1:], 2, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * hd ** -0.5, kx)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 40), st.integers(1, 8),
       st.integers(0, 1000))
def test_linear_scan_property(B, T, chunk, seed):
    """Chunked associative scan == sequential recurrence, any chunking."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.uniform(ks[0], (B, T, 4), minval=0.1, maxval=0.99)
    b = jax.random.normal(ks[1], (B, T, 4))
    h0 = jnp.zeros((B, 4))
    hs, hT = linear_scan(a, b, h0, chunk=chunk)
    h = h0
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h),
                                   atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h), atol=1e-5,
                               rtol=1e-4)


def test_epspec_build_geometry():
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import EPSpec
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b")
    mesh = make_test_mesh(1, 1)
    spec = EPSpec.build(mesh, cfg, ep_axes=("model",))
    assert spec.n_ep == 1 and spec.slots >= cfg.num_experts
    assert spec.dispatch_row_axes == ("data", "model")
    assert spec.batch_axes == ("data",)

import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real single
# CPU device. Multi-device SPMD tests run in subprocesses (test_multidevice).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # property tests prefer real hypothesis; fall back to the local
    # deterministic mini-implementation when it isn't installed
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback

import jax

jax.config.update("jax_enable_x64", False)

import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real single
# CPU device. Multi-device SPMD tests run in subprocesses (test_multidevice).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

"""Training loop, optimizers, checkpointing, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource, train_batches
from repro.models import transformer as tr
from repro.optim.adamw import (adafactor, adamw, clip_by_global_norm,
                               cosine_schedule)
from repro.training.train_loop import make_train_step


def test_loss_decreases_tiny_model(tmp_path):
    cfg = get_config("tinyllama-1.1b").reduced()
    rt = tr.Runtime(cfg=cfg)
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3)
    step = jax.jit(make_train_step(rt, opt))
    opt_state = opt.init(params)
    losses = []
    for tok, tgt in train_batches(cfg.vocab_size, 4, 64, 12, seed=0):
        params, opt_state, m = step(params, opt_state, jnp.asarray(tok),
                                    jnp.asarray(tgt))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_adafactor_steps_and_memory_shape():
    cfg = get_config("mixtral-8x7b").reduced()
    rt = tr.Runtime(cfg=cfg)
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    opt = adafactor(lr=1e-2)
    state = opt.init(params)
    # factored states are O(rows + cols), not O(rows * cols)
    p_elems = sum(p.size for p in jax.tree.leaves(params))
    s_elems = sum(p.size for p in jax.tree.leaves(state))
    assert s_elems < 0.2 * p_elems
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    p2, state2 = opt.update(g, state, params)
    assert int(state2["step"]) == 1
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


def test_grad_clip_and_schedule():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 1.0
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("yi-6b").reduced()
    rt = tr.Runtime(cfg=cfg)
    params = tr.init_params(rt, jax.random.PRNGKey(1))
    opt = adamw()
    state = opt.init(params)
    path = tmp_path / "ckpt"
    save_checkpoint(path, params, step=7, opt_state=state,
                    extra={"arch": cfg.name})
    p2, s2, meta = load_checkpoint(path)
    assert meta["step"] == 7 and meta["extra"]["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert jax.tree.structure(state).num_leaves == \
        jax.tree.structure(s2).num_leaves


def test_data_pipeline_task_conditioned():
    a = TaskTokenSource("code", 512, seed=0).sample(4, 64)
    b = TaskTokenSource("math", 512, seed=0).sample(4, 64)
    assert a.shape == (4, 64) and a.dtype == np.int32
    assert (a >= 0).all() and (a < 512).all()
    # different tasks -> different unigram profiles
    ha = np.bincount(a.reshape(-1), minlength=512)
    hb = np.bincount(b.reshape(-1), minlength=512)
    assert np.argmax(ha) != np.argmax(hb) or \
        np.corrcoef(ha, hb)[0, 1] < 0.9


def test_train_batches_shapes():
    it = train_batches(256, 4, 32, 3)
    for tok, tgt in it:
        assert tok.shape == (4, 32) and tgt.shape == (4, 32)
        np.testing.assert_array_equal(tok[:, 1:], tgt[:, :-1])

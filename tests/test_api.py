"""Serving API v1: typed Request/Event contract, RequestHandle lifecycle,
the deprecated ``submit`` shim, decode compaction, the ``EdgeCluster``
façade on both backends, and cross-origin admission fairness.

This file must stay clean under ``-W error::DeprecationWarning`` (the CI
``strict-deprecations`` leg): every deliberate shim call is wrapped in
``pytest.warns``.
"""
import numpy as np
import pytest

from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.api import (Event, EventType, HomeRouter,
                               LeastLoadedRouter, Request, RequestHandle,
                               as_router)
from repro.serving.cluster import (DEEPSEEK_V2_LITE_PROFILE, EdgeCluster,
                                   paper_testbed, requests_from_workload)
from repro.serving.runtime import ServingRuntime

from test_paged_equivalence import BLOCK_SIZE, _engine, _reference


# ---------------------------------------------------------------------------
# Contract
# ---------------------------------------------------------------------------

def test_request_validation():
    p = [1, 2, 3]
    r = Request(prompt=p, max_new_tokens=2)
    assert r.prompt.dtype == np.int32 and r.prompt.shape == (3,)
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=0)
    with pytest.raises(ValueError):
        Request(prompt=[], max_new_tokens=2)
    # temperature sampling is a first-class path now: > 0 is accepted,
    # only negative temperatures (and out-of-range seeds) are rejected
    assert Request(prompt=p, max_new_tokens=2, temperature=0.7,
                   seed=123).temperature == 0.7
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=2, temperature=-0.1)
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=2, seed=-1)
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=2, seed=2 ** 31)
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=2, slo=-1.0)
    with pytest.raises(ValueError):
        Request(prompt=p, max_new_tokens=2, origin=-1)


def test_handle_lifecycle_and_result_guard():
    h = RequestHandle(7, Request(prompt=[1], max_new_tokens=1))
    assert not h.done and h.tokens.size == 0 and h.metrics == {}
    with pytest.raises(RuntimeError):
        h.result()
    h._emit(EventType.ADMITTED, 3.0, server=1)
    assert h.admitted_at == 3.0 and h.server == 1
    # first writer wins: a cluster router's routing decision must not be
    # clobbered by the runtime's ADMITTED event (which reports the origin)
    h2 = RequestHandle(8, Request(prompt=[1], max_new_tokens=1))
    h2.server = 2                                  # router picked server 2
    h2._emit(EventType.ADMITTED, 0.0, server=0)    # runtime reports origin
    assert h2.server == 2
    h._emit(EventType.TOKEN, 4.0, token=42)
    h._emit(EventType.FINISHED, 5.0, latency=5.0, tokens=1)
    assert h.done and h.metrics["latency"] == 5.0
    np.testing.assert_array_equal(h.result(), [42])
    assert [e.type for e in h.events] == ["ADMITTED", "TOKEN", "FINISHED"]
    assert isinstance(h.events[0], Event)


def test_routers():
    loads = np.array([3.0, 1.0, 2.0])
    assert HomeRouter().route(2, loads) == 2
    assert HomeRouter().route(None, loads) == 1
    assert LeastLoadedRouter().route(2, loads) == 1
    assert isinstance(as_router("least-loaded"), LeastLoadedRouter)
    assert isinstance(as_router(None), HomeRouter)
    with pytest.raises(KeyError):
        as_router("nope")


# ---------------------------------------------------------------------------
# Runtime events + the deprecated submit shim
# ---------------------------------------------------------------------------

def test_event_stream_and_finished_metrics():
    eng, src, refs = _engine(False)
    p = src.sample(1, 12)[0]
    ref = _reference(eng, refs, p, 4)
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                         n_blocks=17)
    h = rtm.enqueue(Request(prompt=p, max_new_tokens=4, slo=100.0))
    rtm.run()
    np.testing.assert_array_equal(h.result(), ref)
    types = [e.type for e in h.events]
    assert types[0] == EventType.ADMITTED
    assert types[-1] == EventType.FINISHED
    assert types.count(EventType.TOKEN) == 4
    m = h.metrics
    assert m["tokens"] == 4 and m["latency"] >= 1 and m["wait"] >= 0
    assert m["slo_met"] is True and m["deferred_ticks"] == 0


def test_deferred_and_prefix_hit_events():
    eng, src, refs = _engine(False)
    prompt = src.sample(1, 24)[0]
    # pool fits one request at a time -> the second defers, then hits the
    # cached prefix of the first when admitted
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE, n_blocks=5)
    h1 = rtm.enqueue(Request(prompt=prompt, max_new_tokens=3))
    h2 = rtm.enqueue(Request(prompt=prompt, max_new_tokens=3))
    rtm.run()
    assert h1.done and h2.done
    np.testing.assert_array_equal(h1.result(), h2.result())
    t2 = [e.type for e in h2.events]
    assert t2[0] == EventType.DEFERRED          # exactly one DEFERRED event
    assert t2.count(EventType.DEFERRED) == 1
    assert h2.deferred_ticks >= 1
    assert EventType.PREFIX_HIT in t2
    hit = next(e for e in h2.events if e.type == EventType.PREFIX_HIT)
    assert hit.data["tokens_skipped"] > 0
    assert h2.metrics["deferred_ticks"] == h2.deferred_ticks


def test_submit_shim_warns_and_is_token_identical():
    """The legacy positional surface is a DeprecationWarning shim over
    enqueue(): same admission, token-identical output."""
    eng, src, refs = _engine(False)
    p = src.sample(1, 16)[0]
    new = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE)
    h = new.enqueue(Request(prompt=p, max_new_tokens=5))
    new.run()
    old = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE)
    with pytest.warns(DeprecationWarning, match="enqueue"):
        rid = old.submit(p, 5)
    out = old.run()
    np.testing.assert_array_equal(out[rid], h.result())
    np.testing.assert_array_equal(out[rid],
                                  _reference(eng, refs, p, 5))
    # the shim still produces a live handle (one surface underneath)
    assert old.handles[rid].done


def test_simulator_router_shim_warns():
    from repro.serving.simulator import Router
    with pytest.warns(DeprecationWarning, match="HomeRouter"):
        Router(redirect=False)


# ---------------------------------------------------------------------------
# Decode compaction (satellite): bucketed active-slot batches
# ---------------------------------------------------------------------------

def test_compaction_token_identity_and_row_savings():
    """compact_decode on vs off: identical tokens, strictly fewer decode
    rows on a partially-occupied pool, invariants hold every tick."""
    eng, src, refs = _engine(False)
    jobs = [(src.sample(1, 12 + 4 * (k % 2))[0], 2 + k % 4, k)
            for k in range(5)]
    outs, rows, rounds = [], [], []
    for compact in (True, False):
        rtm = ServingRuntime(eng, max_slots=4, block_size=BLOCK_SIZE,
                             n_blocks=33, compact_decode=compact)
        handles = {}
        pending = list(jobs)
        t = 0
        while pending or rtm.queue or rtm.active:
            while pending and pending[0][2] <= t:
                p, s, _ = pending.pop(0)
                handles[len(handles)] = rtm.enqueue(
                    Request(prompt=p, max_new_tokens=s))
            rtm.step()
            rtm.check_invariants()
            t += 1
        outs.append([h.result() for h in handles.values()])
        rows.append(rtm.decode_rows)
        rounds.append(rtm.rounds)
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)
    assert rounds[0] == rounds[1]               # same schedule
    assert rows[1] == 4 * rounds[1]             # off: full width every round
    assert rows[0] < rows[1]                    # on: strictly fewer rows
    np.testing.assert_array_equal(outs[0][0], _reference(
        eng, refs, jobs[0][0], jobs[0][1]))


# ---------------------------------------------------------------------------
# EdgeCluster: runtime backend
# ---------------------------------------------------------------------------

def test_cluster_runtime_per_server_token_identity():
    """Per-server runtimes (own pools/batches) serve a routed stream
    token-identically to sequential generate()."""
    eng, src, refs = _engine(False)
    ec = EdgeCluster("runtime", engine=eng, n_servers=3,
                     shared_runtime=False,
                     runtime_opts=dict(max_slots=2, block_size=BLOCK_SIZE))
    with pytest.raises(ValueError, match="origin"):
        ec.submit(Request(prompt=src.sample(1, 8)[0], max_new_tokens=1,
                          origin=7))
    jobs = [(src.sample(1, 12)[0], 3, k % 3) for k in range(6)]
    handles = [ec.submit(Request(prompt=p, max_new_tokens=s, origin=n))
               for p, s, n in jobs]
    ec.run()
    for (p, s, n), h in zip(jobs, handles):
        np.testing.assert_array_equal(h.result(),
                                      _reference(eng, refs, p, s))
        assert h.server == n                   # home routing
    m = ec.metrics()
    assert m["per_server"]["submitted"] == [2, 2, 2]
    assert m["per_server"]["finished"] == [2, 2, 2]
    assert m["redirected_total"] == 0
    assert m["clock"] == "ticks"


def test_cluster_least_loaded_router_spreads_load():
    eng, src, refs = _engine(False)
    ec = EdgeCluster("runtime", engine=eng, n_servers=2,
                     shared_runtime=False, router="least-loaded",
                     runtime_opts=dict(max_slots=2, block_size=BLOCK_SIZE))
    p = src.sample(1, 12)[0]
    hs = [ec.submit(Request(prompt=p, max_new_tokens=2, origin=0))
          for _ in range(4)]
    ec.run()
    m = ec.metrics()
    assert sum(m["per_server"]["served"]) == 4
    assert m["per_server"]["served"][1] > 0    # traffic left its origin
    assert m["redirected_total"] > 0
    for h in hs:
        np.testing.assert_array_equal(h.result(),
                                      _reference(eng, refs, p, 2))


def test_cluster_shared_runtime_mode():
    eng, src, refs = _engine(False)
    ec = EdgeCluster("runtime", engine=eng, n_servers=3,
                     runtime_opts=dict(max_slots=3, block_size=BLOCK_SIZE))
    jobs = [(src.sample(1, 8)[0], 2, k % 3) for k in range(3)]
    handles = [ec.submit(Request(prompt=p, max_new_tokens=s, origin=n))
               for p, s, n in jobs]
    ec.run()
    for (p, s, n), h in zip(jobs, handles):
        np.testing.assert_array_equal(h.result(),
                                      _reference(eng, refs, p, s))
        assert h.request.origin == n           # caller's origin preserved
    # dense-MoE engine (n_ep=1) cannot attribute 3 origins: the cluster
    # serves untagged instead of mis-crediting
    assert not ec.backend.tag_origins


# ---------------------------------------------------------------------------
# Cross-origin admission fairness (satellite): FIFO deferral must not
# starve any origin when one server's stream is long-prompt-heavy
# ---------------------------------------------------------------------------

def test_fifo_deferral_does_not_starve_origins():
    eng, src, refs = _engine(False)
    # a pool tight enough that the long-prompt origin keeps deferring
    ec = EdgeCluster("runtime", engine=eng, n_servers=3,
                     runtime_opts=dict(max_slots=3, block_size=BLOCK_SIZE,
                                       n_blocks=13))
    handles: dict[int, list] = {0: [], 1: [], 2: []}
    # origin 0: long-prompt-heavy; origins 1, 2: short interactive
    for k in range(4):
        handles[0].append(ec.submit(Request(
            prompt=src.sample(1, 40)[0], max_new_tokens=6, origin=0)))
        handles[1].append(ec.submit(Request(
            prompt=src.sample(1, 8)[0], max_new_tokens=3, origin=1)))
        handles[2].append(ec.submit(Request(
            prompt=src.sample(1, 8)[0], max_new_tokens=3, origin=2)))
    ec.run()
    # pool pressure was real...
    assert sum(h.deferred_ticks for hs in handles.values() for h in hs) > 0
    # ...yet every origin's every request finished
    for hs in handles.values():
        assert all(h.done for h in hs)
    fin = {o: [h.metrics["latency"] for h in hs]
           for o, hs in handles.items()}
    # no starvation: the short origins complete ahead of the long one on
    # average, and symmetrically with each other (FIFO never lets the
    # long-prompt stream fence the pool off)
    assert np.mean(fin[1]) <= np.mean(fin[0])
    assert np.mean(fin[2]) <= np.mean(fin[0])
    sym = abs(np.mean(fin[1]) - np.mean(fin[2]))
    assert sym <= 0.5 * max(np.mean(fin[1]), np.mean(fin[2]))
    # and short requests interleave with the long stream rather than
    # queueing behind all of it
    assert min(min(fin[1]), min(fin[2])) < max(fin[0])


# ---------------------------------------------------------------------------
# EdgeCluster: sim backend
# ---------------------------------------------------------------------------

def test_cluster_sim_backend_matches_edge_simulator():
    """The sim backend is the same event-driven core: latencies from the
    typed API stream equal EdgeSimulator.run() on the source workload."""
    from repro.core.placement import dancemoe_placement
    from repro.data.traces import BIGBENCH_TASKS, poisson_workload
    from repro.serving.simulator import EdgeSimulator
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    wl = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                          num_experts=pf.num_experts,
                          mean_interarrival=20.0, duration=240.0, seed=0)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    plan = dancemoe_placement(wl.freqs_by_server(cl.n), cap, slots)
    ref = EdgeSimulator(cl, pf, wl, plan=plan, seed=1).run()

    ec = EdgeCluster("sim", spec=cl, profile=pf, plan=plan, tasks=wl.tasks,
                     seed=1)
    for r in requests_from_workload(wl):
        ec.submit(r)
    handles = ec.run()
    lat = np.array([h.metrics["latency"] for h in handles])
    np.testing.assert_allclose(lat, ref.latencies)
    assert all(h.done for h in handles)
    assert all(e.type in (EventType.ADMITTED, EventType.FINISHED)
               for h in handles for e in h.events)   # sim: no TOKEN events
    m = ec.metrics()
    assert len(m["per_server"]["local_ratio"]) == cl.n
    assert all(0.0 <= x <= 1.0 for x in m["per_server"]["local_ratio"])
    assert m["clock"] == "seconds"
    # routed/served bookkeeping agrees with the simulator's record
    served = np.bincount(ref.routed, minlength=cl.n)
    assert m["per_server"]["served"] == served.tolist()


def test_cluster_sim_origin_validation_and_fallback_routing():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    from repro.core.placement import dancemoe_placement
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    rng = np.random.default_rng(0)
    plan = dancemoe_placement(
        rng.dirichlet(np.ones(pf.num_experts),
                      size=(pf.num_layers, cl.n)), cap, slots)
    ec = EdgeCluster("sim", spec=cl, profile=pf, plan=plan)
    # out-of-range origin fails at the submit site, not mid-simulation
    with pytest.raises(ValueError, match="origin"):
        ec.submit(Request(prompt=np.zeros(8, np.int32), max_new_tokens=1,
                          origin=7))
    # origin-less requests fall back to the least-loaded server: saturate
    # server 0, then an unattributed request must land elsewhere
    for _ in range(4):
        ec.submit(Request(prompt=np.zeros(512, np.int32),
                          max_new_tokens=64, origin=0, arrival=0.0))
    h = ec.submit(Request(prompt=np.zeros(8, np.int32), max_new_tokens=1,
                          arrival=1.0))
    ec.run()
    assert h.metrics["server"] != 0


def test_cluster_shared_mode_metrics_not_pinned_to_server0():
    """Shared-runtime mode has no routing decision: requests are recorded
    at their origin (round-robin when origin-less), never 'redirected' to
    a degenerate argmin(zeros) == server 0."""
    eng, src, refs = _engine(False)
    ec = EdgeCluster("runtime", engine=eng, n_servers=3,
                     router="least-loaded",
                     runtime_opts=dict(max_slots=3, block_size=BLOCK_SIZE))
    p = src.sample(1, 8)[0]
    for k in range(3):
        ec.submit(Request(prompt=p, max_new_tokens=2, origin=k))
    for _ in range(3):
        ec.submit(Request(prompt=p, max_new_tokens=2))   # origin-less
    ec.run()
    m = ec.metrics()
    assert m["per_server"]["served"] == [2, 2, 2]        # not [6, 0, 0]
    assert m["redirected_total"] == 0


def test_cluster_sim_slo_and_step():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    ctrl = PlacementController(policy=get_policy("dancemoe"), cost=None,
                               cluster=ClusterView.from_cluster(cl, pf),
                               interval=1e9)
    ec = EdgeCluster("sim", spec=cl, profile=pf, controller=ctrl)
    h1 = ec.submit(Request(prompt=np.zeros(64, np.int32), max_new_tokens=8,
                           origin=0, arrival=0.0, slo=1e9))
    h2 = ec.submit(Request(prompt=np.zeros(64, np.int32), max_new_tokens=8,
                           origin=1, arrival=1.0, slo=1e-12))
    assert ec.step() and h1.done and not h2.done    # event-by-event
    ec.run()
    assert h1.metrics["slo_met"] is True
    assert h2.metrics["slo_met"] is False


# ---------------------------------------------------------------------------
# bench-serving/v7 schema (satellite): cluster + net + perf + faults +
# tiers + workload
# ---------------------------------------------------------------------------

def _v7_doc():
    pair = {"cache": 2, "nocache": 1}
    return {
        "schema": "bench-serving/v7", "mode": "smoke",
        "metrics": {
            "admitted_concurrency": dict(pair),
            "prefill_chunks_executed": dict(pair),
            "prefill_chunk_reduction": 2.0, "prefix_hits": 1,
            "prefill_tokens_skipped": 8, "cow_copies": 1,
            "deferrals": dict(pair),
            "decode_round_latency_s": {"mean": 0.1, "p95": 0.2},
            "mean_latency_ticks": dict(pair),
            "cluster": {
                "n_servers": 3,
                "per_server_admitted": [3, 4, 5],
                "per_server_routed": [3, 4, 5],
                "per_server_local_ratio": [0.5, 0.75, 1.0],
                "redirected_total": 0,
                "per_server_mem_gb": [12.0, 12.0, 24.0],
            },
            "net": {
                "n_servers": 3,
                "link_dispatch_bytes": [[0, 10, 20], [10, 0, 5],
                                        [20, 5, 0]],
                "cross_server_bytes": 70.0,
                "migration_transfer_seconds": 1.5,
                "migration_transfer_bytes": 3e6,
                "migrations_completed": 1,
                "per_server_mem_gb": [0.2, 0.2, 0.1],
                "per_server_expert_budget": [64, 64, 32],
            },
            "perf": {
                "warmup_seconds": 12.5,
                "executables_compiled": 7,
                "traces_after_warmup": 0,
                "host_syncs": 0,
                "rounds_timed": 40,
                "decode_round_ms": {"p50": 3.5, "p99": 9.0},
                "ttft_ms": {"p50": 120.0, "p99": 250.0},
            },
            "faults": {
                "injected": 1,
                "recovered": 1,
                "tokens_lost": 0,
                "recovery_seconds": 0.25,
                "requests_dropped": 0,
                "baseline_tokens_lost": 200,
                "baseline_requests_dropped": 10,
                "replay_identical": 1,
            },
            "tiers": {
                "n_servers": 3,
                "per_server_gpu_slots": [48, 40, 24],
                "per_server_host_slots": [128, 112, 96],
                "per_server_gpu_resident": [48, 40, 24],
                "per_server_host_resident": [80, 72, 72],
                "promotions": 12,
                "demotions": 14,
                "prefetch_hit_ratio": 0.7,
                "on_demand_fetches": 200,
                "on_demand_stall_seconds": 4.2,
                "mean_latency_s": 0.29,
                "prefetch_off_mean_latency_s": 0.31,
                "prefetch_off_fetches": 240,
                "prefetch_off_stall_seconds": 4.9,
            },
            "workload": {
                "n_servers": 3,
                "requests": 480,
                "sheds": 140,
                "deadline_redirects": 90,
                "flash_migrations": 2,
                "goodput_tokens_per_s": 36.5,
                "fifo_goodput_tokens_per_s": 14.8,
                "slo_attainment": 0.49,
                "fifo_slo_attainment": 0.43,
                "ttft_s": {"p50": 1.2, "p99": 7.1},
                "itl_s": {"p50": 0.01, "p99": 0.05},
                "phases": {"flash": {"requests": 270, "sheds": 140}},
                "replay_identical": 1,
            },
        },
    }


def test_schema_v7_accepts_and_rejects():
    import sys
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.schema import BenchSchemaError, validate_bench_serving
    assert validate_bench_serving(_v7_doc())
    for mutate in (
        lambda d: d["metrics"].pop("cluster"),
        lambda d: d["metrics"]["cluster"].pop("per_server_local_ratio"),
        lambda d: d["metrics"]["cluster"].update(n_servers=2),   # len != n
        lambda d: d["metrics"]["cluster"].update(
            per_server_local_ratio=[0.5, 0.75, 1.5]),            # ratio > 1
        lambda d: d["metrics"]["cluster"].update(
            per_server_admitted=[0, 0, 0]),                      # empty run
        lambda d: d["metrics"]["cluster"].pop("per_server_mem_gb"),  # v3
        lambda d: d["metrics"].pop("net"),                       # v3
        lambda d: d["metrics"]["net"].pop("link_dispatch_bytes"),
        lambda d: d["metrics"]["net"].update(
            link_dispatch_bytes=[[0, 1], [1, 0]]),               # not n x n
        lambda d: d["metrics"]["net"].update(
            link_dispatch_bytes=[[0, 1, -2], [1, 0, 1],
                                 [1, 1, 0]]),                    # negative
        lambda d: d["metrics"]["net"].update(cross_server_bytes=0),  # empty
        lambda d: d["metrics"]["net"].pop("migration_transfer_seconds"),
        lambda d: d.update(schema="bench-serving/v6"),           # stale tag
        lambda d: d["metrics"].pop("perf"),                      # v4
        lambda d: d["metrics"]["perf"].pop("decode_round_ms"),
        lambda d: d["metrics"]["perf"]["decode_round_ms"].pop("p99"),
        lambda d: d["metrics"]["perf"].update(
            executables_compiled=0),                             # no warmup
        lambda d: d["metrics"]["perf"].update(
            decode_round_ms={"p50": 0.0, "p99": 0.0}),           # untimed
        lambda d: d["metrics"]["perf"].update(warmup_seconds=-1),
        lambda d: d["metrics"].pop("faults"),                    # v5
        lambda d: d["metrics"]["faults"].pop("recovery_seconds"),
        lambda d: d["metrics"]["faults"].update(injected=0),     # no fault
        lambda d: d["metrics"]["faults"].update(
            replay_identical=0),                                 # not bit-id
        lambda d: d["metrics"]["faults"].update(tokens_lost=-1),
        lambda d: d["metrics"].pop("tiers"),                     # v6
        lambda d: d["metrics"]["tiers"].pop("on_demand_stall_seconds"),
        lambda d: d["metrics"]["tiers"].update(promotions=0),    # no prefetch
        lambda d: d["metrics"]["tiers"].update(
            prefetch_hit_ratio=1.2),                             # ratio > 1
        lambda d: d["metrics"]["tiers"].update(
            per_server_gpu_slots=[48, 40]),                      # len != n
        lambda d: d["metrics"]["tiers"].update(on_demand_fetches=-1),
        lambda d: d["metrics"].pop("workload"),                  # v7
        lambda d: d["metrics"]["workload"].pop("goodput_tokens_per_s"),
        lambda d: d["metrics"]["workload"].pop("phases"),
        lambda d: d["metrics"]["workload"].update(phases={}),    # empty
        lambda d: d["metrics"]["workload"].update(requests=0),   # empty run
        lambda d: d["metrics"]["workload"].update(
            replay_identical=0),                                 # not bit-id
        lambda d: d["metrics"]["workload"].update(
            slo_attainment=1.2),                                 # ratio > 1
        lambda d: d["metrics"]["workload"].update(
            goodput_tokens_per_s=10.0),            # lost to FIFO: gate fails
        lambda d: d["metrics"]["workload"]["ttft_s"].pop("p99"),
        lambda d: d["metrics"]["workload"].update(sheds=-1),
    ):
        doc = _v7_doc()
        mutate(doc)
        with pytest.raises(BenchSchemaError):
            validate_bench_serving(doc)

"""Validate the dry-run deliverable: every (arch x shape x mesh) combination
compiled, and the roofline records are complete and sane. Skips when the
sweep has not been run (results/ is generated, not committed state)."""
import glob
import json
from pathlib import Path

import pytest

from repro.launch.dryrun import ASSIGNED_ARCHS

RESULTS = Path(__file__).parent.parent / "results" / "dryrun"
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MESHES = ["16x16", "2x16x16"]

have = sorted(glob.glob(str(RESULTS / "*.json")))
pytestmark = pytest.mark.skipif(
    len(have) < 10, reason="dry-run sweep not run (python -m "
    "repro.launch.dryrun --all --both-meshes)")


@pytest.mark.parametrize("mesh", MESHES)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_pair_compiled(arch, shape, mesh):
    f = RESULTS / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        pytest.skip(f"{f.name} not generated yet")
    r = json.loads(f.read_text())
    assert r.get("ok"), r.get("error")
    assert r["chips"] == (512 if mesh == "2x16x16" else 256)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_roofline_terms_sane(arch):
    for shape in SHAPES:
        f = RESULTS / f"{arch}__{shape}__16x16.json"
        if not f.exists():
            continue
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        ro = r["roofline"]
        assert ro["compute_s"] >= 0 and ro["memory_s"] > 0
        assert ro["dominant"] in ("compute", "memory", "collective")
        assert 0 < ro["useful_flops_ratio"] < 20
        # decode shapes must not be compute-dominated on this hardware
        if shape in ("decode_32k", "long_500k"):
            assert ro["dominant"] != "compute", (arch, shape)

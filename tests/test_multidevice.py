"""SPMD correctness on 8 fake CPU devices — run in subprocesses so the fake
device count never leaks into the rest of the suite (per the assignment,
XLA_FLAGS must not be set globally)."""
import os
import subprocess
import sys
from pathlib import Path


SCRIPTS = Path(__file__).parent / "md_scripts"


def run_script(name: str, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    r = subprocess.run([sys.executable, str(SCRIPTS / name)], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_ep_moe_matches_dense_oracle():
    out = run_script("ep_moe_equivalence.py")
    assert "ALL OK" in out


def test_transformer_ep_end_to_end():
    out = run_script("transformer_ep.py")
    assert "ALL OK" in out


def test_placement_quality_affects_local_ratio():
    out = run_script("placement_local_ratio.py")
    assert "ALL OK" in out


def test_layout_equivalence():
    out = run_script("layout_equivalence.py")
    assert "ALL OK" in out

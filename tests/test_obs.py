"""Unified observability: span tracer, metrics registry, trace export.

Covers the ``repro.serving.obs`` contracts end to end:

* tracer primitives — recording, the max-events drop cap, the
  ``as_tracer`` normalization, the zero-allocation NULL_TRACER;
* registry primitives — provider collection, None-omission,
  ``snapshot_diff``, the deterministic Histogram subsample;
* trace determinism — a faulted + migrating + tiered sim scenario
  rerun exports **byte-identical** Chrome-trace JSON, and the cluster
  event stream keeps a seq-stamped stable total order (the
  ``EdgeCluster.events`` merge-ordering regression);
* span-tree well-formedness — per-request phase spans never overlap
  and every finished request closes its spans;
* the zero-host-sync contract — tracing on vs off over the warmed
  runtime: identical token streams, identical ``host_syncs``;
* the export surface — ``validate_trace_doc`` and
  ``tools/trace_view.py`` on a real exported file.
"""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.api import EventType, Request
from repro.serving.obs import (NULL_TRACER, Counter, Gauge, Histogram,
                               Registry, SpanKind, Tracer, as_tracer,
                               snapshot_diff)

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------

def test_tracer_records_spans_and_summary():
    tr = Tracer(clock="seconds")
    s = tr.span(SpanKind.QUEUE_WAIT, 1.0, 2.5, rid=3, server=1, shed=False)
    tr.instant(SpanKind.SHED, 2.5, rid=3, server=1)
    assert s.duration == 1.5 and s.seq == 0
    assert tr.by_kind(SpanKind.SHED)[0].start == tr.by_kind(SpanKind.SHED)[0].end
    assert [sp.kind for sp in tr.request_spans(3)] == [
        SpanKind.QUEUE_WAIT, SpanKind.SHED]
    out = tr.summary()
    assert out["enabled"] == 1 and out["clock"] == "seconds"
    assert out["events"] == 2 and out["dropped_events"] == 0
    assert out["span_counts"] == {"QUEUE_WAIT": 1, "SHED": 1}
    assert out["overhead_ms"] >= 0.0


def test_tracer_drop_cap():
    tr = Tracer(max_events=2)
    assert tr.span("A", 0, 1) is not None
    assert tr.span("A", 1, 2) is not None
    assert tr.span("A", 2, 3) is None          # over the cap: dropped
    assert len(tr.spans) == 2 and tr.dropped == 1
    assert tr.summary()["dropped_events"] == 1
    # dropped spans never consume sequence numbers (reruns with a larger
    # cap must not shift the retained seq stamps)
    assert [s.seq for s in tr.spans] == [0, 1]


def test_as_tracer_normalization():
    assert as_tracer(False, "ticks") is NULL_TRACER
    assert as_tracer(None, "seconds") is NULL_TRACER
    t = as_tracer(True, "seconds")
    assert isinstance(t, Tracer) and t.enabled and t.clock == "seconds"
    assert as_tracer(t, "seconds") is t
    with pytest.raises(ValueError, match="clock"):
        as_tracer(Tracer(clock="ticks"), "seconds")
    with pytest.raises(ValueError, match="clock"):
        Tracer(clock="wallclock")


def test_null_tracer_is_inert_and_refuses_export(tmp_path):
    assert not NULL_TRACER.enabled
    assert NULL_TRACER.span("A", 0, 1, rid=1) is None
    assert NULL_TRACER.instant("B", 0) is None
    assert NULL_TRACER.spans == [] and NULL_TRACER.summary()["events"] == 0
    with pytest.raises(RuntimeError, match="disabled"):
        NULL_TRACER.export(str(tmp_path / "t.json"))


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------

def test_registry_collects_in_order_and_omits_none():
    reg = Registry()
    reg.register("b", lambda: {"x": 1})
    reg.register("a", lambda: None)            # omitted this collection
    reg.register("c", lambda: {"y": 2})
    assert reg.namespaces == ("b", "a", "c")
    assert list(reg.collect().items()) == [("b", {"x": 1}), ("c", {"y": 2})]
    reg.register("b", lambda: {"x": 9})        # replace keeps the slot
    assert reg.collect()["b"] == {"x": 9}
    with pytest.raises(TypeError, match="callable"):
        reg.register("d", {"not": "callable"})


def test_counter_gauge_and_snapshot_diff():
    c, g = Counter(), Gauge()
    c.inc()
    c.inc(4)
    g.set(2.5)
    assert c.value == 5 and g.value == 2.5
    before = {"a": {"n": 5, "flag": True, "name": "x"}, "t": 1.0}
    after = {"a": {"n": 9, "flag": False, "name": "y"}, "t": 3.5, "new": 7}
    d = snapshot_diff(before, after)
    assert d["a"]["n"] == 4 and d["t"] == 2.5
    assert d["a"]["flag"] is False and d["a"]["name"] == "y"  # pass-through
    assert d["new"] == 7                       # newly-appeared leaf
    assert before["a"]["n"] == 5               # inputs untouched


def test_histogram_deterministic_subsample():
    def fill(n):
        h = Histogram(max_items=64)
        for i in range(n):
            h.observe(float(i % 97))
        return h

    a, b = fill(1000), fill(1000)
    assert a.count == b.count == 1000
    assert list(a) == list(b)                  # no RNG: identical retained
    assert len(list(a)) <= 64
    p = a.percentiles((50, 99))
    assert 0.0 <= p["p50"] <= p["p99"] <= 96.0


# ---------------------------------------------------------------------------
# Export determinism (property over random span batches)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_trace_doc_deterministic_and_ordered(seed):
    def build():
        rng = np.random.default_rng(seed)
        tr = Tracer(clock="seconds")
        for _ in range(30):
            t0 = round(float(rng.uniform(0, 10)), 3)
            tr.span(str(rng.choice(SpanKind.ALL)), t0,
                    t0 + round(float(rng.uniform(0, 2)), 3),
                    rid=int(rng.integers(-1, 5)),
                    server=int(rng.integers(-1, 3)))
        return tr.to_trace_doc()

    doc, doc2 = build(), build()
    assert json.dumps(doc, sort_keys=True) == json.dumps(doc2, sort_keys=True)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    keys = [(e["ts"], e["args"]["seq"]) for e in xs]
    assert keys == sorted(keys)                # stable (ts, seq) order
    assert doc["otherData"]["spans"] == len(xs) == 30


# ---------------------------------------------------------------------------
# The faulted + migrating + tiered sim scenario
# ---------------------------------------------------------------------------

def _traced_sim_run(seed=0, n_requests=40):
    """One traced sim run with every span source active: the tiered WAN
    testbed, the dancemoe controller (staged migration), a timed link
    brownout, and tier prefetch (the ``benchmarks.obs`` scenario)."""
    from benchmarks.tiers import (_primed_stats, _sharp_task_profile,
                                  tiered_testbed)
    from benchmarks.topology import BENCH_PROFILE, build_requests
    from repro.core.policies import (ClusterView, PlacementController,
                                     get_policy)
    from repro.serving.cluster import EdgeCluster
    from repro.serving.faults import FaultSchedule
    from repro.serving.net import CommCostModel

    pf = BENCH_PROFILE
    topo = tiered_testbed()
    cm = CommCostModel(topology=topo, expert_bytes=pf.expert_bytes,
                       activation_bytes=pf.hidden_bytes_per_token,
                       tokens_per_horizon=1e5)
    ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=cm,
        cluster=ClusterView.from_topology(topo, pf, tiered=True),
        interval=20.0, topology=topo, stats=_primed_stats(topo, pf, seed))
    ec = EdgeCluster(
        "sim", topology=topo, profile=pf, controller=ctrl, seed=seed,
        fault_schedule=FaultSchedule.link_brownout(8.0, 0, 2, 0.3,
                                                   restore_at=30.0),
        trace=True)
    for t in range(2 * topo.n):
        name = f"task{t}"
        ec.backend.workload.tasks[name] = _sharp_task_profile(
            name, t, pf, seed)
    for r in build_requests(n_requests, 3, seed=seed):
        ec.submit(r)
    handles = ec.run()
    return ec, handles


@pytest.fixture(scope="module")
def traced_runs():
    """The scenario and its independent rerun (determinism witnesses)."""
    return _traced_sim_run(), _traced_sim_run()


def test_trace_rerun_byte_identical(traced_runs, tmp_path):
    (ec1, _), (ec2, _) = traced_runs
    p1 = ec1.export_trace(str(tmp_path / "a.json"))
    p2 = ec2.export_trace(str(tmp_path / "b.json"))
    b1, b2 = Path(p1).read_bytes(), Path(p2).read_bytes()
    assert b1 == b2
    assert len(b1) > 0


def test_all_span_sources_fired(traced_runs):
    (ec, _), _ = traced_runs
    counts = ec.metrics()["obs"]["span_counts"]
    for kind in (SpanKind.QUEUE_WAIT, SpanKind.PREFILL_CHUNK,
                 SpanKind.DECODE_ROUND, SpanKind.PLACEMENT_REVIEW,
                 SpanKind.TRANSFER_TASK, SpanKind.FAULT, SpanKind.PREFETCH,
                 SpanKind.COLD_FETCH_STALL):
        assert counts.get(kind, 0) >= 1, f"no {kind} spans"
    assert ec.metrics()["obs"]["dropped_events"] == 0


def test_span_trees_well_formed(traced_runs):
    """Per-request phase spans partition the request's service time:
    no strict overlaps, and every finished request closes its spans at
    or before its terminal event."""
    (ec, handles), _ = traced_runs
    eps = 1e-9
    by_rid: dict = {}
    for sp in ec.tracer.spans:
        if sp.rid >= 0:
            assert sp.end >= sp.start - eps    # no negative durations
            by_rid.setdefault(sp.rid, []).append(sp)
    assert by_rid, "no request spans recorded"
    for rid, spans in by_rid.items():
        spans = sorted(spans, key=lambda s: (s.start, s.end))
        for a, b in zip(spans, spans[1:]):
            assert b.start >= a.end - eps, (
                f"rid {rid}: {a.kind} [{a.start}, {a.end}] overlaps "
                f"{b.kind} [{b.start}, {b.end}]")
    for h in handles:
        assert h.done
        fin = [e for e in h.events
               if e.type in (EventType.FINISHED, EventType.SHED)]
        assert fin, f"rid {h.rid}: no terminal event"
        end = max(e.time for e in fin)
        spans = by_rid.get(h.rid, [])
        assert spans, f"rid {h.rid}: finished with no spans"
        kinds = {s.kind for s in spans}
        assert SpanKind.QUEUE_WAIT in kinds
        for s in spans:
            assert s.end <= end + eps, (
                f"rid {h.rid}: {s.kind} open past the terminal event "
                f"({s.end} > {end})")


def test_cluster_events_seq_total_order(traced_runs):
    """The ``EdgeCluster.events`` merge regression: every event carries
    a monotonic seq stamp, the merged list is sorted by (time, seq),
    and a rerun reproduces the exact total order — including events
    that coincide in time."""
    (ec1, _), (ec2, _) = traced_runs
    for ec in (ec1, ec2):
        ev = ec.events
        assert ev, "scenario produced no cluster events"
        seqs = [e.seq for e in ev]
        assert all(s >= 0 for s in seqs), "an event missed its seq stamp"
        assert len(set(seqs)) == len(seqs), "duplicate seq stamps"
        keys = [(e.time, e.seq) for e in ev]
        assert keys == sorted(keys)
        types = {e.type for e in ev}
        assert EventType.MIGRATION_STARTED in types
        assert EventType.LINK_DEGRADED in types
    order1 = [(e.type, round(e.time, 9), e.seq) for e in ec1.events]
    order2 = [(e.type, round(e.time, 9), e.seq) for e in ec2.events]
    assert order1 == order2


def test_metrics_registry_shape(traced_runs):
    """metrics() is registry-assembled but keeps the legacy shape; the
    obs section appears only when tracing is on."""
    (ec, _), _ = traced_runs
    m = ec.metrics()
    for key in ("backend", "clock", "n_servers", "per_server",
                "redirected_total", "sheds", "net", "tiers", "faults",
                "obs"):
        assert key in m, f"metrics() lost the {key!r} section"
    assert ec.registry.namespaces == ("cluster", "perf", "net", "tiers",
                                      "faults", "obs")
    assert m["obs"]["enabled"] == 1 and m["obs"]["clock"] == "seconds"


def test_untraced_cluster_has_no_obs_section():
    from repro.serving.cluster import (DEEPSEEK_V2_LITE_PROFILE,
                                       EdgeCluster, paper_testbed,
                                       requests_from_workload)
    from repro.core.placement import dancemoe_placement
    from repro.data.traces import BIGBENCH_TASKS, poisson_workload

    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    wl = poisson_workload(list(BIGBENCH_TASKS), num_layers=pf.num_layers,
                          num_experts=pf.num_experts,
                          mean_interarrival=30.0, duration=120.0, seed=0)
    cap = cl.expert_capacity(pf.expert_bytes)
    slots = np.minimum(np.maximum(cap // pf.num_layers, 1), pf.num_experts)
    plan = dancemoe_placement(wl.freqs_by_server(cl.n), cap, slots)
    ec = EdgeCluster("sim", spec=cl, profile=pf, plan=plan, tasks=wl.tasks)
    for r in requests_from_workload(wl):
        ec.submit(r)
    ec.run()
    m = ec.metrics()
    assert "obs" not in m                      # NULL_TRACER: no section
    assert ec.tracer is NULL_TRACER
    with pytest.raises(RuntimeError, match="disabled"):
        ec.export_trace("/dev/null")


# ---------------------------------------------------------------------------
# Export surface: schema validation + the textual viewer
# ---------------------------------------------------------------------------

def test_validate_trace_doc_accepts_real_export_rejects_tampered(
        traced_runs, tmp_path):
    from benchmarks.schema import BenchSchemaError, validate_trace_doc

    (ec, _), _ = traced_runs
    doc = json.loads(Path(ec.export_trace(
        str(tmp_path / "t.json"))).read_text())
    assert validate_trace_doc(doc) is doc
    for tamper in (
        lambda d: d.pop("otherData"),
        lambda d: d["otherData"].__setitem__("dropped", 3),
        lambda d: d["otherData"].__setitem__("spans", 1),
        lambda d: d.__setitem__("traceEvents", []),
        lambda d: d["traceEvents"][-1].pop("ts"),
        lambda d: d["traceEvents"][-1]["args"].pop("seq"),
    ):
        bad = json.loads(json.dumps(doc))
        tamper(bad)
        with pytest.raises(BenchSchemaError):
            validate_trace_doc(bad)


def test_trace_view_renders_breakdown(traced_runs, tmp_path):
    (ec, _), _ = traced_runs
    path = ec.export_trace(str(tmp_path / "t.json"))
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "trace_view.py"), path,
         "--top", "3"],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stderr
    for token in ("phase", "QUEUE_WAIT", "DECODE_ROUND", "server0",
                  "control-plane", "slowest"):
        assert token in r.stdout, f"viewer output missing {token!r}"


# ---------------------------------------------------------------------------
# Zero-host-sync contract on the warmed runtime
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def warm_engine():
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import TaskTokenSource
    from repro.launch.mesh import make_test_mesh
    from repro.models import transformer as tr
    from repro.serving.engine import ServingEngine

    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params = tr.init_params(rt, jax.random.PRNGKey(0))
    eng = ServingEngine(rt=rt, params=params, placement=None, max_len=48)
    src = TaskTokenSource("obs", cfg.vocab_size, seed=7)
    return eng, src


def _serve_warmed(eng, requests, tracer):
    from repro.serving.runtime import ServingRuntime

    rtm = ServingRuntime(eng, max_slots=2, block_size=8, prefix_cache=False,
                         warmup=True, warmup_origins="untagged",
                         tracer=tracer)
    handles = [rtm.enqueue(Request(prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens))
               for r in requests]
    rtm.run()
    return rtm, [h.result().tolist() for h in handles]


def test_tracing_adds_no_host_syncs_and_keeps_tokens(warm_engine):
    """The acceptance gate: tracing on vs off over the warmed zero-stall
    loop — token streams identical, ``host_syncs`` unchanged (batch
    spans are recorded from launch-side metadata only)."""
    eng, src = warm_engine
    requests = [Request(prompt=src.sample(1, 8 + 4 * (k % 2))[0],
                        max_new_tokens=3 + k)
                for k in range(4)]
    tracer = Tracer(clock="ticks")
    rtm_on, toks_on = _serve_warmed(eng, requests, tracer)
    rtm_off, toks_off = _serve_warmed(eng, requests, None)
    assert toks_on == toks_off
    p_on, p_off = rtm_on.perf_metrics(), rtm_off.perf_metrics()
    assert p_on["host_syncs"] == p_off["host_syncs"]
    assert p_on["traces_after_warmup"] == p_off["traces_after_warmup"] == 0
    # the traced leg actually recorded the batch-level phases
    counts = tracer.summary()["span_counts"]
    assert counts.get(SpanKind.QUEUE_WAIT, 0) == len(requests)
    assert counts.get(SpanKind.PREFILL_CHUNK, 0) >= 1
    assert counts.get(SpanKind.DECODE_ROUND, 0) >= 1
    # batch spans carry no per-request payloads (rid = -1): completion
    # data rides the async drain, never a fresh device sync
    assert all(s.rid == -1 for s in tracer.by_kind(SpanKind.DECODE_ROUND))

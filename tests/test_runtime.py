"""Continuous-batching runtime: token-identity vs sequential generate(),
shared decode batches, staggered arrivals, and control-plane integration."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.migration import CostModel
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                          slots=cfg.num_experts, capacity=4096,
                          slot_capacity=8192)
    _, n_groups = cfg.layer_pattern()
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec)
    rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
    pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
    pls = tr.stack_placement(pl, n_groups)
    params = dict(params_dense)
    params["groups"] = M.regather_ep_groups(params_dense["groups"], pls,
                                            n_groups)
    eng = ServingEngine(rt=rt, params=params, placement=pls,
                        dense_master=params_dense["groups"], max_len=64)
    src = TaskTokenSource("arith", cfg.vocab_size, seed=0)
    return cfg, spec, n_groups, eng, src


def _reference(eng, prompt, steps):
    gen, _ = eng.generate(prompt[None], steps=steps)
    return gen[0]


def test_concurrent_requests_share_batch_and_match_sequential(engine_setup):
    cfg, spec, n_groups, eng, src = engine_setup
    p1 = src.sample(1, 16)[0]
    p2 = src.sample(1, 12)[0]
    p3 = src.sample(1, 16)[0]
    refs = [_reference(eng, p, s) for p, s in
            [(p1, 6), (p2, 4), (p3, 5)]]

    rtm = ServingRuntime(eng, max_slots=4)
    rids = [rtm.enqueue(Request(prompt=p, max_new_tokens=s)).rid
            for p, s in [(p1, 6), (p2, 4), (p3, 5)]]
    out = rtm.run()
    # >= 2 concurrently arriving requests advanced in one decode batch
    assert rtm.max_concurrency >= 2
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)


def test_staggered_arrivals_match_sequential(engine_setup):
    """A request admitted mid-stream (rows at different cache positions)
    still decodes token-identically."""
    cfg, spec, n_groups, eng, src = engine_setup
    p1 = src.sample(1, 16)[0]
    p2 = src.sample(1, 12)[0]
    ref1 = _reference(eng, p1, 8)
    ref2 = _reference(eng, p2, 4)

    rtm = ServingRuntime(eng, max_slots=4)
    a = rtm.enqueue(Request(prompt=p1, max_new_tokens=8)).rid
    rtm.step()
    rtm.step()                       # p1 is several tokens ahead...
    b = rtm.enqueue(Request(prompt=p2,
                            max_new_tokens=4)).rid   # ...p2 joins mid-batch
    out = rtm.run()
    assert rtm.max_concurrency >= 2
    np.testing.assert_array_equal(out[a], ref1)
    np.testing.assert_array_equal(out[b], ref2)


def test_more_requests_than_slots(engine_setup):
    """Queueing: requests beyond the pool size wait and are admitted as
    slots free up; every output still matches sequential serving."""
    cfg, spec, n_groups, eng, src = engine_setup
    prompts = [src.sample(1, 12)[0] for _ in range(5)]
    refs = [_reference(eng, p, 3) for p in prompts]
    rtm = ServingRuntime(eng, max_slots=2)
    rids = [rtm.enqueue(Request(prompt=p, max_new_tokens=3)).rid
            for p in prompts]
    out = rtm.run()
    assert len(out) == 5
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(out[rid], ref)


def test_prefill_only_request(engine_setup):
    cfg, spec, n_groups, eng, src = engine_setup
    p = src.sample(1, 16)[0]
    ref = _reference(eng, p, 1)
    rtm = ServingRuntime(eng, max_slots=2)
    rid = rtm.enqueue(Request(prompt=p, max_new_tokens=1)).rid
    out = rtm.run()
    np.testing.assert_array_equal(out[rid], ref)


def test_runtime_applies_adopted_plans_and_preserves_function(engine_setup):
    cfg, spec, n_groups, eng, src = engine_setup
    cm = CostModel(expert_bytes=3 * cfg.d_model * cfg.d_ff * 2,
                   activation_bytes=cfg.d_model * 2, bandwidth=62.5e6,
                   tokens_per_horizon=1e6)
    ctrl = PlacementController(policy=get_policy("dancemoe"), cost=cm,
                               cluster=ClusterView.from_ep_spec(spec,
                                                                n_groups),
                               interval=2)
    rtm = ServingRuntime(eng, max_slots=2, controller=ctrl)
    assert ctrl.stats is eng.stats   # controller owns the engine's stats
    p = src.sample(1, 16)[0]
    before = _reference(eng, p, 6)
    rid = rtm.enqueue(Request(prompt=p, max_new_tokens=6)).rid
    out = rtm.run()
    np.testing.assert_array_equal(out[rid], before)
    assert ctrl.plan is not None     # at least the initial review ran
    after = _reference(eng, p, 6)
    np.testing.assert_array_equal(after, before)   # migration preserved fn


def test_submit_rejects_overlong_request(engine_setup):
    """Admission validation (satellite fix): the dense pool keeps the
    legacy per-row ``max_len`` bound; the paged pool validates against the
    *total pool capacity* instead, so a request longer than ``max_len`` is
    admissible whenever its pages fit."""
    cfg, spec, n_groups, eng, src = engine_setup
    rtm = ServingRuntime(eng, max_slots=2, paged=False)
    with pytest.raises(ValueError):
        rtm.enqueue(Request(prompt=src.sample(1, 60)[0],
                    max_new_tokens=10))       # 70 > max_len=64
    with pytest.raises(ValueError):
        Request(prompt=src.sample(1, 8)[0], max_new_tokens=0)
    # paged: 2 slots x 64 positions -> 8 blocks of 16 = 128 total
    rtm = ServingRuntime(eng, max_slots=2, block_size=16)
    assert rtm.paged
    rtm.enqueue(Request(prompt=src.sample(1, 60)[0],
                max_new_tokens=10))           # 70 <= 128: admissible
    with pytest.raises(ValueError):
        rtm.enqueue(Request(prompt=src.sample(1, 120)[0],
                    max_new_tokens=10))       # 130 > 128: rejected
    with pytest.raises(ValueError):
        Request(prompt=src.sample(1, 8)[0], max_new_tokens=0)


def test_vacant_slots_excluded_from_stats(engine_setup):
    """A 1-request stream in a 4-slot pool must ingest only the real
    request's activations — the 3 vacant rows' garbage routing is masked
    out of the gating statistics."""
    cfg, spec, n_groups, eng, src = engine_setup
    K = cfg.top_k
    eng.stats.reset()
    rtm = ServingRuntime(eng, max_slots=4)
    rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=4))
    rtm.run()
    # prefill: 8 tokens x K; 3 decode rounds x 1 active row x K — per group
    expected = (8 * K + 3 * K) * n_groups
    assert eng.stats.counts.sum() == pytest.approx(expected, rel=0.01)
    eng.stats.reset()


def test_first_review_waits_a_full_interval(engine_setup):
    """The controller's initial adoption must respect the review interval
    (not fire on decode round 1 with near-empty stats)."""
    cfg, spec, n_groups, eng, src = engine_setup
    ctrl = PlacementController(policy=get_policy("dancemoe"), cost=None,
                               cluster=ClusterView.from_ep_spec(spec,
                                                                n_groups),
                               interval=1000)
    rtm = ServingRuntime(eng, max_slots=2, controller=ctrl)
    rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=4))
    rtm.run()
    assert ctrl.plan is None and rtm.migrations == []   # interval not hit


def test_ingest_weight_scales_stats(engine_setup):
    """Satellite fix: ``_ingest`` must honor its weight argument."""
    cfg, spec, n_groups, eng, src = engine_setup
    mstats = {"counts_per_rank": np.ones((n_groups, spec.n_ep,
                                          cfg.num_experts))}
    eng.stats.reset()
    eng._ingest(mstats, weight=1.0)
    one = eng.stats.counts.copy()
    assert one.sum() > 0
    eng.stats.reset()
    eng._ingest(mstats, weight=2.5)
    np.testing.assert_allclose(eng.stats.counts, 2.5 * one)
    eng.stats.reset()


def test_prefill_stats_weighted_by_tokens(engine_setup):
    """A T-token prefill must contribute exactly T x the activation mass of
    one decode step (raw counts, no double weighting)."""
    cfg, spec, n_groups, eng, src = engine_setup
    T = 16
    eng.stats.reset()
    eng.generate(src.sample(1, T), steps=1)    # prefill + 1 decode
    mass1 = eng.stats.counts.sum()
    eng.stats.reset()
    eng.generate(src.sample(1, T), steps=3)    # prefill + 3 decodes
    mass3 = eng.stats.counts.sum()
    eng.stats.reset()
    decode_step_mass = (mass3 - mass1) / 2
    prefill_mass = mass1 - decode_step_mass
    assert decode_step_mass > 0
    assert prefill_mass / decode_step_mass == pytest.approx(T, rel=0.05)


# ---------------------------------------------------------------------------
# PR 7 satellites: bounded metrics reservoirs, pop_finished, failover
# eviction/re-admission, launch-round local_frac attribution, metering
# that never fails silently
# ---------------------------------------------------------------------------

from repro.serving.cluster import EdgeCluster  # noqa: E402
from repro.serving.net import ServerProfile, Topology  # noqa: E402
from repro.serving.runtime import Reservoir, _Pending  # noqa: E402


def test_reservoir_decimation_bounded_and_deterministic():
    r = Reservoir(cap=64)
    for k in range(10_000):
        r.append(float(k))
    assert r.count == 10_000            # true observation count survives
    assert 2 <= len(r) <= 64            # kept samples stay bounded
    kept = list(r)
    # systematic decimation: survivors are exactly the consecutive
    # multiples of the final stride (evenly spaced over the full stream)
    assert kept == [float(k * r.stride) for k in range(len(kept))]
    # percentiles stay representative of the full history
    assert np.percentile(kept, 50) == pytest.approx(
        float(np.percentile(np.arange(10_000), 50)), rel=0.05)
    # no RNG: an identical stream decimates identically (fault-schedule
    # reruns must stay bit-identical)
    r2 = Reservoir(cap=64)
    for k in range(10_000):
        r2.append(float(k))
    assert list(r2) == kept and r2.stride == r.stride
    with pytest.raises(ValueError, match="cap"):
        Reservoir(cap=1)


def test_perf_metrics_bounded_by_reservoir(engine_setup):
    """decode_round_s / ttft_s previously grew one entry per round/request
    forever; they are reservoirs now, and the perf section reports the
    true round count, not the kept-sample count."""
    cfg, spec, n_groups, eng, src = engine_setup
    rtm = ServingRuntime(eng, max_slots=2)
    assert rtm.decode_round_s.cap >= 2 and rtm.ttft_s.cap >= 2
    rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=4))
    rtm.run()
    pm = rtm.perf_metrics()
    assert pm["rounds_timed"] == rtm.decode_round_s.count > 0
    assert pm["decode_round_ms"]["p50"] > 0


def test_pop_finished_releases_bookkeeping(engine_setup):
    cfg, spec, n_groups, eng, src = engine_setup
    rtm = ServingRuntime(eng, max_slots=4)
    h1 = rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=4))
    h2 = rtm.enqueue(Request(prompt=src.sample(1, 12)[0], max_new_tokens=3))
    out = rtm.run()
    hit_rate = rtm.prefix_hit_rate
    popped = rtm.pop_finished()
    assert set(popped) == {h1.rid, h2.rid}
    np.testing.assert_array_equal(popped[h1.rid], out[h1.rid])
    # the per-request bookkeeping is released...
    assert not rtm.finished and not rtm.finished_at and not rtm.handles
    # ...but the rate denominators survive the pop
    assert rtm.prefix_hit_rate == hit_rate
    # a later pop returns only the newer results
    h3 = rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=2))
    rtm.run()
    assert set(rtm.pop_finished()) == {h3.rid}
    assert rtm.pop_finished() == {}


def test_evict_and_readmit_under_same_handle(engine_setup):
    """The cluster failover path: evict an in-flight request (pages
    recycled, invariants hold), then re-admit it under its original
    handle — the regenerated stream matches sequential generate()."""
    cfg, spec, n_groups, eng, src = engine_setup
    p1 = src.sample(1, 16)[0]
    ref1 = _reference(eng, p1, 6)
    rtm = ServingRuntime(eng, max_slots=2, prefix_cache=False)
    h1 = rtm.enqueue(Request(prompt=p1, max_new_tokens=6))
    h2 = rtm.enqueue(Request(prompt=src.sample(1, 12)[0], max_new_tokens=6))
    h3 = rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=2))
    for _ in range(3):                 # h1/h2 in flight, h3 still queued
        rtm.step()
    assert not h1.done
    emitted = rtm.evict(h1.rid)
    assert emitted == len(h1._tokens)  # tokens the victim must regenerate
    assert h1.rid not in rtm.handles
    assert rtm.evict(h3.rid) == 0      # queued victim: nothing emitted yet
    assert rtm.evict(999_999) == 0     # unknown rid: no-op
    rtm.check_invariants()
    old_rid = h1.rid
    h1._tokens.clear()                 # the stream restarts from scratch
    rtm.enqueue(Request(prompt=p1, max_new_tokens=6), handle=h1)
    assert h1.rid != old_rid           # re-bound to a fresh internal rid
    out = rtm.run()
    assert h1.done
    np.testing.assert_array_equal(out[h1.rid], ref1)
    np.testing.assert_array_equal(h1.result(), ref1)
    rtm.check_invariants()
    if rtm.paged:
        assert rtm.allocator.n_free == rtm.allocator.capacity_blocks


def test_drain_attributes_launch_round_local_frac(engine_setup):
    """Regression (pre-PR bug): ``_drain_tokens`` read the engine's
    mutable ``last_local_frac`` at *drain* time, so a round whose gating
    stats carried no local_frac — or any engine sharer ingesting between
    launch and drain — mis-credited a stale value to the draining slots.
    The round's own stats, captured at launch, are authoritative."""
    cfg, spec, n_groups, eng, src = engine_setup
    rtm = ServingRuntime(eng, max_slots=2, prefix_cache=False)
    h = rtm.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=6))
    rtm.step()                          # prefill (+ first decode rounds)
    rtm.step()
    i, slot = next((i, s) for i, s in enumerate(rtm.slots)
                   if s is not None and s.rid == h.rid)
    assert not slot.prefilling and len(slot.tokens) < slot.need
    zero_counts = np.zeros((n_groups, spec.n_ep, cfg.num_experts))
    eng.last_local_frac = 0.25          # a sharer's stale value
    # a legal round record whose stats carry no local_frac: nothing may
    # be attributed (pre-PR code credited the stale 0.25 here)
    before = (slot.lf_sum, slot.lf_rounds)
    rtm._drain_one(_Pending(
        kind="decode", tick=rtm.ticks, rows=[(0, i, h.rid)],
        nxt=np.array([3], np.int32),
        mstats={"counts_per_rank": zero_counts}))
    assert (slot.lf_sum, slot.lf_rounds) == before
    assert slot.tokens[-1] == 3         # the token itself still lands
    # a round that does carry local_frac attributes its own value
    eng.last_local_frac = 0.25
    rtm._drain_one(_Pending(
        kind="decode", tick=rtm.ticks, rows=[(0, i, h.rid)],
        nxt=np.array([4], np.int32),
        mstats={"counts_per_rank": zero_counts,
                "local_frac": np.array([0.5])}))
    assert slot.lf_rounds == before[1] + 1
    assert slot.lf_sum == pytest.approx(before[0] + 0.5)
    eng.stats.reset()


def _solo_topology() -> Topology:
    return Topology((ServerProfile("solo", mem_bytes=8e9),),
                    np.array([[500e6 / 8]]), np.array([[0.0]]))


def test_meter_mismatch_raises_when_it_never_succeeded(engine_setup):
    """Regression (pre-PR bug): a persistently mismatched residency view
    made ``step()`` skip metering silently forever — ``metrics()['net']``
    read zero dispatch bytes with no hint anything was wrong."""
    cfg, spec, n_groups, eng, src = engine_setup
    ec = EdgeCluster("runtime", engine=eng, n_servers=1,
                     topology=_solo_topology(),
                     runtime_opts=dict(max_slots=2))
    ec.backend._residency = lambda: np.zeros((1, 1, 1))   # wrong shape
    with pytest.raises(RuntimeError, match="metering"):
        for _ in range(40):
            ec.step()
    assert ec.backend.meter_skips >= 32
    assert ec.metrics()["net"]["meter_skips"] >= 32


def test_meter_transient_mismatch_is_tolerated(engine_setup):
    """A mismatch window after metering has worked (e.g. plan granularity
    churn mid-migration) is counted and surfaced, never fatal."""
    cfg, spec, n_groups, eng, src = engine_setup
    ec = EdgeCluster("runtime", engine=eng, n_servers=1,
                     topology=_solo_topology(),
                     runtime_opts=dict(max_slots=2))
    ec.submit(Request(prompt=src.sample(1, 8)[0], max_new_tokens=2))
    ec.run()
    assert ec.backend._meter_ok > 0 and ec.backend.meter_skips == 0
    ec.backend._residency = lambda: np.zeros((1, 1, 1))
    for _ in range(40):                 # far past the streak threshold
        ec.step()
    assert ec.backend.meter_skips == 40
    assert ec.metrics()["net"]["meter_skips"] == 40


def test_local_frac_warm_vs_sync_subprocess():
    """Satellite regression: per-request local_frac attribution must be
    identical between the sync and warm (zero-stall) loops when nothing
    queues. Runs on 2 fake EP ranks in a subprocess (locality on a single
    rank is trivially 1.0; the fake device count must not leak into this
    process — the tier-1 convention, see test_multidevice)."""
    import os
    import subprocess
    import sys
    from pathlib import Path
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    r = subprocess.run(
        [sys.executable, str(Path(__file__).parent / "md_scripts"
                             / "local_frac_warm_sync.py")],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, \
        f"local_frac_warm_sync.py failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL OK" in r.stdout

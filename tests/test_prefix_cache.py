"""Property-based invariants for the refcounted ``BlockAllocator`` and the
``RadixPrefixCache`` (pure Python — no JAX, no engine), plus a runtime-level
no-CoW-aliasing property on a live serving stream.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback in ``tests/_hypothesis_fallback.py`` (see conftest.py) — both CI
legs execute the same properties.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serving.api import Request
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.runtime import BlockAllocator

BS = 4          # cache block size for the pure-Python properties
VOCAB = 5       # tiny alphabet maximises accidental prefix collisions


# ---------------------------------------------------------------------------
# BlockAllocator: model-based refcount invariants
# ---------------------------------------------------------------------------

@st.composite
def allocator_ops(draw):
    """A random alloc/acquire/release schedule (encoded with plain integers
    so it runs under the hypothesis fallback)."""
    ops = []
    for _ in range(draw(st.integers(5, 40))):
        ops.append((draw(st.integers(0, 2)),      # 0 alloc / 1 acquire / 2 rel
                    draw(st.integers(1, 3)),      # alloc size
                    draw(st.integers(0, 10 ** 6))))  # victim selector
    return draw(st.integers(4, 12)), ops          # n_blocks, schedule


@settings(max_examples=40, deadline=None)
@given(allocator_ops())
def test_allocator_refcount_invariants(scenario):
    """Against a reference refcount model: a live block is never re-issued,
    a block is recycled exactly when its last reference drops, the free
    count always complements the live set, and the null block never moves."""
    n_blocks, ops = scenario
    a = BlockAllocator(n_blocks)
    model: dict[int, int] = {}                    # block -> expected rc
    for kind, size, sel in ops:
        live = sorted(model)
        if kind == 0:
            if a.can_alloc(size):
                got = a.alloc(size)
                assert len(set(got)) == size
                assert not set(got) & set(live)   # no live block re-issued
                assert 0 not in got
                for b in got:
                    model[b] = 1
        elif kind == 1 and live:
            b = live[sel % len(live)]
            a.acquire([b])
            model[b] += 1
        elif kind == 2 and live:
            b = live[sel % len(live)]
            freed = a.release([b])
            model[b] -= 1
            if model[b] == 0:
                del model[b]
                assert freed == 1                 # recycled at rc 0 ...
            else:
                assert freed == 0                 # ... and only at rc 0
        assert a.live() == model
        assert a.n_free == a.capacity_blocks - len(model)


# ---------------------------------------------------------------------------
# RadixPrefixCache: lookup == longest block-aligned common prefix
# ---------------------------------------------------------------------------

def _brute_force_match(query: np.ndarray, inserted: list) -> int:
    """Reference: longest block-aligned common prefix (in tokens) between
    ``query`` and any *cached span* — capped one block short of the whole
    query when no full-prompt entry exists (the final token must be
    recomputed for its logits)."""
    best = 0
    for p, nblocks in inserted:
        span = min(len(query), nblocks * BS)
        m = 0
        while m + BS <= span and np.array_equal(query[m:m + BS],
                                                p[m:m + BS]):
            m += BS
        best = max(best, m)
    if best == len(query):
        best -= BS
    return best


@st.composite
def trie_scenario(draw):
    """Random prompt sets over a tiny alphabet (so shared prefixes happen
    by collision, not construction) plus query prompts."""
    def prompt(n):
        return [draw(st.integers(0, VOCAB - 1)) for _ in range(n)]
    inserted = [prompt(draw(st.integers(1, 5)) * BS)
                for _ in range(draw(st.integers(1, 6)))]
    queries = [prompt(draw(st.integers(1, 6)) * BS +
                      draw(st.sampled_from((0, 1, 3))))
               for _ in range(draw(st.integers(1, 4)))]
    return inserted, queries


@settings(max_examples=40, deadline=None)
@given(trie_scenario())
def test_radix_lookup_is_longest_block_aligned_prefix(scenario):
    inserted, queries = scenario
    alloc = BlockAllocator(256)
    cache = RadixPrefixCache(BS, alloc)
    ref: list = []
    for p in inserted:
        p = np.asarray(p, np.int32)
        nblocks = len(p) // BS
        blocks = alloc.alloc(nblocks)
        cache.insert_prefix(p, blocks)
        alloc.release(blocks)                 # cache refs keep them live
        ref.append((p, nblocks))
    for q in queries:
        q = np.asarray(q, np.int32)
        m = cache.lookup(q)
        assert m.tokens == _brute_force_match(q, ref)
        assert len(m.blocks) * BS == m.tokens
        assert m.logits is None and m.tail_block is None
        # the returned run must be the cached blocks of a witness prompt
        if m.tokens:
            witness = [blocks for p, nb in ref
                       if nb * BS >= m.tokens
                       and np.array_equal(p[:m.tokens], q[:m.tokens])]
            assert witness
    # identical nodes are deduplicated: refcounts are one per trie node
    for b, rc in alloc.live().items():
        assert rc == 1
    assert sum(cache.block_refs().values()) == len(alloc.live())


def test_full_prompt_hits_tail_and_logits():
    """Deterministic full-hit semantics: a block-aligned prompt hits via
    node logits; a ragged prompt needs its tail entry; lookup without
    either backs off one block so the last token is recomputed."""
    alloc = BlockAllocator(64)
    cache = RadixPrefixCache(BS, alloc)
    aligned = np.arange(2 * BS, dtype=np.int32)
    blocks = alloc.alloc(2)
    cache.insert_prefix(aligned, blocks)
    m = cache.lookup(aligned)
    assert m.tokens == BS and len(m.blocks) == 1      # back-off: no logits
    cache.set_logits(aligned, np.ones(7))
    m = cache.lookup(aligned)
    assert m.full_hit and m.tokens == 2 * BS and m.tail_block is None

    ragged = np.concatenate([aligned, np.asarray([9, 9], np.int32)])
    m = cache.lookup(ragged)
    assert not m.full_hit and m.tokens == 2 * BS      # partial: shared run
    (tail,) = alloc.alloc(1)
    assert cache.insert_tail(ragged, tail, np.zeros(7))
    assert not cache.insert_tail(ragged, tail, np.zeros(7))   # dedup
    m = cache.lookup(ragged)
    assert m.full_hit and m.tokens == len(ragged) and m.tail_block == tail


def test_eviction_never_frees_or_drops_a_shared_block():
    """Eviction skips entries whose block a live request still shares —
    no memory would be freed and the reuse would be destroyed (the
    anti-thrashing rule). Once the last sharer retires, the entry becomes
    evictable and recycles its block."""
    alloc = BlockAllocator(8)
    cache = RadixPrefixCache(BS, alloc)
    p = np.arange(2 * BS, dtype=np.int32)
    blocks = alloc.alloc(2)
    cache.insert_prefix(p, blocks)          # rc 2 each: "slot" + cache
    assert cache.evict(2) == 0              # shared: skipped entirely
    assert cache.lookup(p).blocks == [blocks[0]]   # entries survived
    assert alloc.refcount(blocks[0]) == 2   # slot + cache (lookup adds none)
    assert alloc.release(blocks) == 0       # "slot" retires; cache holds
    assert cache.evict(2) == 2              # now evictable -> recycled
    assert alloc.n_free == alloc.capacity_blocks
    # clear() force-drops even shared entries (shutdown path)
    blocks2 = alloc.alloc(2)
    p2 = np.arange(2 * BS, dtype=np.int32) + 1
    cache.insert_prefix(p2, blocks2)
    assert cache.clear() == 0               # refs dropped; "slot" still holds
    assert alloc.release(blocks2) == 2
    assert alloc.n_free == alloc.capacity_blocks


def test_lru_eviction_order_and_leaf_only():
    """Eviction is LRU over leaves: a recently-looked-up branch outlives a
    cold one, and an inner node is never evicted before its extension."""
    alloc = BlockAllocator(16)
    cache = RadixPrefixCache(BS, alloc)
    cold = np.asarray([1] * BS, np.int32)
    hot_long = np.asarray([2] * (2 * BS), np.int32)
    for p, n in ((cold, 1), (hot_long, 2)):
        blocks = alloc.alloc(n)
        cache.insert_prefix(p, blocks)
        alloc.release(blocks)
    cache.lookup(hot_long)                  # refresh both hot nodes
    assert cache.evict(1) == 1              # evicts the cold leaf
    m = cache.lookup(hot_long)
    assert m.tokens >= BS                   # hot chain survived
    assert cache.lookup(cold).tokens == 0
    # the deep leaf goes before its parent
    assert cache.evict(1) == 1
    assert cache.lookup(hot_long).tokens == BS
    cache.clear()
    assert alloc.n_free == alloc.capacity_blocks


# ---------------------------------------------------------------------------
# Runtime-level property: refcount exactness + no CoW aliasing on a stream
# ---------------------------------------------------------------------------

@st.composite
def runtime_stream(draw):
    jobs = []
    for k in range(draw(st.integers(2, 5))):
        jobs.append((draw(st.integers(0, 1)),          # family id
                     draw(st.sampled_from((0, 2, 6))),  # unique tail length
                     draw(st.integers(1, 4)),           # steps
                     draw(st.integers(0, 4))))          # arrival tick
    return jobs, draw(st.sampled_from([7, 17]))


@settings(max_examples=8, deadline=None)
@given(runtime_stream())
def test_runtime_refcounts_and_cow_on_live_stream(scenario):
    """Drive the real paged runtime over shared-prefix streams and assert
    the structural invariants every tick (``check_invariants``: refcounts
    == slot holds + cache refs, write frontiers exclusively owned — i.e.
    no copy-on-write aliasing), ending with a fully returned pool."""
    from test_paged_equivalence import _engine       # lazy: heavy import

    eng, _, _ = _engine(False)                       # shared cached engine
    from repro.serving.runtime import ServingRuntime
    jobs, n_blocks = scenario
    rtm = ServingRuntime(eng, max_slots=2, block_size=8, n_blocks=n_blocks)
    vocab = eng.rt.cfg.vocab_size
    rng = np.random.default_rng(7)
    pending = []
    for fam, tail, steps, arrival in jobs:
        base = (np.arange(12, dtype=np.int32) + fam) % vocab
        prompt = (base if tail == 0 else np.concatenate(
            [base, rng.integers(0, vocab, tail).astype(np.int32)]))
        npages = -(-(len(prompt) + steps - 1) // 8)
        if npages <= min(n_blocks - 1, rtm.max_pages):
            pending.append((arrival, prompt, steps))
    pending.sort(key=lambda x: x[0])
    t = 0
    while pending or rtm.queue or rtm.active:
        while pending and pending[0][0] <= t:
            _, prompt, steps = pending.pop(0)
            rtm.enqueue(Request(prompt=prompt,
                                max_new_tokens=steps))
        rtm.step()
        rtm.check_invariants()
        t += 1
    rtm.drop_prefix_cache()
    assert not rtm.allocator.live()
    assert rtm.allocator.n_free == rtm.allocator.capacity_blocks

"""Streaming workload engine, SLO-aware scheduling and goodput accounting.

Covers the workload subsystem end to end:

* ``WorkloadStream``: bit-identical restarts, laziness (no materialized
  trace), flash-crowd/diurnal/skew/task-shift structure, validation;
* the sim backend's SLO admission: sheds under the flash crowd, strict
  goodput win over the FIFO baseline on the same seeded stream, replay
  identity;
* the ``slo_met`` regression (the fault fast-forward used to mis-anchor
  the FINISHED latency at the *fast-forwarded* arrival instead of the
  submit time, silently flipping SLO verdicts);
* the runtime backend's EDF admission + shed path (SHED event contract);
* seeded Gumbel-max sampling: greedy identity at temperature 0,
  batch-composition independence, host/jit agreement;
* router properties under origin skew (hypothesis, satellite): the
  least-loaded router keeps every origin's p99 queue wait within the
  SLO, and home routing never shed-starves an origin outright;
* the jitted-runtime goodput/EDF/sampling leg as a subprocess
  (``md_scripts/workload_runtime.py``).

This file must stay clean under ``-W error::DeprecationWarning`` (the CI
``strict-deprecations`` leg).
"""
import dataclasses
import itertools
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import uniform_plan
from repro.serving.api import EventType, Request
from repro.serving.cluster import ClusterSpec, EdgeCluster, MoEProfile, ServerSpec
from repro.serving.workload import (FlashCrowd, WorkloadSpec, WorkloadStream,
                                    drive, goodput_report)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

PF = MoEProfile(num_layers=8, num_experts=16, top_k=2,
                d_model=512, d_ff=1024)

SPEC = WorkloadSpec(
    duration=80.0, base_rate=2.0, n_origins=3, origin_skew=0.8,
    diurnal_period=60.0, diurnal_amplitude=0.4,
    crowds=(FlashCrowd(start=25.0, duration=20.0, multiplier=6.0,
                       origin=2, fraction=0.9, task="flashtask"),),
    prompt_len=(96.0, 0.6, 8, 384), output_len=(16.0, 0.5, 4, 48),
    slo=6.0, seed=0)


def _sim_cluster(slo_aware: bool, router=None) -> EdgeCluster:
    """Plan-based sim cluster (no controller: these tests isolate the
    scheduling policy from the placement reviews)."""
    # 25 Mbps interconnect: remote expert dispatch dominates service time,
    # so the flash crowd genuinely overloads the cluster (500 Mbps serves
    # the whole stream inside the SLO and nothing would ever shed)
    spec = ClusterSpec(servers=tuple(
        ServerSpec(f"s{k}", mem_bytes=64 * PF.expert_bytes)
        for k in range(3)), bandwidth=25e6 / 8)
    plan = uniform_plan(PF.num_layers, 3, PF.num_experts)
    return EdgeCluster("sim", spec=spec, profile=PF, plan=plan,
                       router=router, slo_aware=slo_aware)


# ---------------------------------------------------------------------------
# WorkloadStream: determinism, laziness, structure
# ---------------------------------------------------------------------------

def test_stream_replays_bit_identically():
    a, b = list(WorkloadStream(SPEC)), list(WorkloadStream(SPEC))
    assert len(a) == len(b) > 100
    for x, y in zip(a, b):
        assert x.arrival == y.arrival and x.seed == y.seed
        assert x.origin == y.origin and x.task == y.task
        assert x.max_new_tokens == y.max_new_tokens
        np.testing.assert_array_equal(x.prompt, y.prompt)
    # a different seed is a different stream
    c = list(WorkloadStream(dataclasses.replace(SPEC, seed=1)))
    assert [r.arrival for r in c] != [r.arrival for r in a]


def test_stream_is_lazy():
    """A multi-year scenario yields its head without materializing: the
    stream is a generator, not a list builder."""
    huge = dataclasses.replace(SPEC, duration=1e8, crowds=())
    head = list(itertools.islice(WorkloadStream(huge), 50))
    assert len(head) == 50
    assert all(head[i].arrival < head[i + 1].arrival for i in range(49))


def test_stream_structure():
    reqs = list(WorkloadStream(SPEC))
    phases = {p: [r for r in reqs if SPEC.phase_of(r.arrival) == p]
              for p in ("flash", "peak", "offpeak")}
    # the crowd multiplies the rate: the 20 s flash window out-arrives
    # the rest of the 80 s scenario combined
    assert len(phases["flash"]) > len(phases["peak"]) + len(phases["offpeak"])
    # ...and pins most of its requests to the crowd origin + task
    crowd = [r for r in phases["flash"] if r.task == "flashtask"]
    assert len(crowd) > 0.6 * len(phases["flash"])
    assert all(r.origin == 2 for r in crowd)
    assert not any(r.task == "flashtask" for r in reqs
                   if not SPEC.crowds[0].active(r.arrival))
    # Zipf skew outside the crowd: origin 0 strictly busiest
    rest = phases["peak"] + phases["offpeak"]
    counts = np.bincount([r.origin for r in rest], minlength=3)
    assert counts[0] > counts[1] > 0
    # every request carries the SLO and its own sampling seed
    assert all(r.slo == SPEC.slo for r in reqs)
    assert len({r.seed for r in reqs}) > 0.9 * len(reqs)
    # lengths respect the clip bounds
    assert all(8 <= len(r.prompt) <= 384 for r in reqs)
    assert all(4 <= r.max_new_tokens <= 48 for r in reqs)


def test_stream_task_shift():
    spec = dataclasses.replace(SPEC, crowds=(), task_shift_at=40.0)
    reqs = list(WorkloadStream(spec))
    before = {r.task for r in reqs if r.arrival < 40.0}
    after = {r.task for r in reqs if r.arrival >= 40.0}
    assert before <= {"task0", "task1", "task2"}
    assert after <= {"task3", "task4", "task5"}


def test_spec_validation():
    with pytest.raises(ValueError, match="base_rate"):
        WorkloadSpec(base_rate=0.0)
    with pytest.raises(ValueError, match="amplitude"):
        WorkloadSpec(diurnal_amplitude=1.0)
    with pytest.raises(ValueError, match="origin"):
        WorkloadSpec(n_origins=2, crowds=(FlashCrowd(0.0, 1.0, origin=5),))
    with pytest.raises(ValueError, match="multiplier"):
        FlashCrowd(0.0, 1.0, multiplier=0.5)
    with pytest.raises(ValueError, match="max_pending"):
        drive(_sim_cluster(False), [], max_pending=0)


# ---------------------------------------------------------------------------
# Sim backend: shed-on-overload, goodput win, replay identity
# ---------------------------------------------------------------------------

def _sim_leg(slo_aware: bool):
    ec = _sim_cluster(slo_aware)
    handles = drive(ec, WorkloadStream(SPEC), max_pending=32)
    return ec, handles, goodput_report(handles, phase_of=SPEC.phase_of)


def test_sim_slo_aware_beats_fifo_goodput():
    ec_s, h_s, rep_s = _sim_leg(True)
    ec_f, h_f, rep_f = _sim_leg(False)
    assert rep_s["requests"] == rep_f["requests"] > 100   # same stream
    # the crowd overloads the cluster: the SLO-aware leg sheds...
    assert rep_s["sheds"] >= 1
    assert ec_s.metrics()["sheds"] == rep_s["sheds"]
    assert rep_f["sheds"] == 0
    # ...and wins goodput strictly; FIFO still finishes everything (late)
    assert (rep_s["goodput_tokens_per_s"] > rep_f["goodput_tokens_per_s"])
    assert rep_f["finished"] == rep_f["requests"]
    assert rep_s["slo_attainment"] <= 1.0
    # shedding concentrates in the flash phase
    assert rep_s["phases"]["flash"]["sheds"] == rep_s["sheds"]
    # shed handles resolve empty with the SHED -> FINISHED contract
    shed = [h for h in h_s if h.metrics.get("shed")]
    assert len(shed) == rep_s["sheds"]
    for h in shed:
        assert h.done and h.metrics["tokens"] == 0
        assert h.metrics["slo_met"] is False
        assert [e.type for e in h.events][-2:] == [EventType.SHED,
                                                   EventType.FINISHED]
    # shed latencies must not pollute the cluster's serving latency means
    assert all(v >= 0.0 for v in ec_s.metrics()["per_server"]["mean_latency"])


def test_sim_replay_is_bit_identical():
    _, h1, rep1 = _sim_leg(True)
    _, h2, rep2 = _sim_leg(True)
    assert rep1 == rep2
    assert ([h.metrics for h in h1] == [h.metrics for h in h2])


def test_drive_bounds_backlog():
    """drive() must keep the backend's pending set at the cap, and reach
    the same result as unbounded submission."""
    ec = _sim_cluster(True)
    seen = []
    orig_submit = ec.submit

    def probe(req):
        seen.append(len(ec.backend._pending))
        return orig_submit(req)

    ec.submit = probe
    handles = drive(ec, WorkloadStream(SPEC), max_pending=8)
    assert max(seen) <= 8
    rep = goodput_report(handles, phase_of=SPEC.phase_of)
    _, _, ref = _sim_leg(True)
    assert rep == ref


# ---------------------------------------------------------------------------
# slo_met regression: the fault fast-forward must not move the SLO anchor
# ---------------------------------------------------------------------------

def test_slo_met_anchored_at_submit_time_under_fault_stall():
    """When a crash leaves experts with no live replica, arrivals are
    fast-forwarded to the recovery migration's eta. The FINISHED latency
    and the slo_met verdict must still be measured from the *submit*
    time — the pre-fix code measured from the fast-forwarded arrival,
    reporting latencies that were too small and slo_met=True on requests
    that actually blew their deadline."""
    from benchmarks.topology import BENCH_PROFILE, _historical_stats, build_requests
    from repro.core.policies import ClusterView, PlacementController, get_policy
    from repro.serving.faults import FaultSchedule
    from repro.serving.net import CommCostModel, ServerProfile, Topology
    pf = BENCH_PROFILE
    eb = pf.expert_bytes
    # server 2 holds experts exclusively (big memory), and the surviving
    # pair talks over a slow WAN hop — so its crash leaves uncovered
    # experts whose recovery transfers stall later arrivals
    profiles = (
        ServerProfile("edge0", mem_bytes=64 * eb, kv_mem_bytes=8e9,
                      compute_speed=50e12),
        ServerProfile("edge1", mem_bytes=64 * eb, kv_mem_bytes=8e9,
                      compute_speed=50e12),
        ServerProfile("big2", mem_bytes=128 * eb, kv_mem_bytes=4e9,
                      compute_speed=50e12),
    )
    bw = np.full((3, 3), 500e6 / 8)
    lat = np.full((3, 3), 2e-3)
    bw[0, 1] = bw[1, 0] = 10e6 / 8
    lat[0, 1] = lat[1, 0] = 40e-3
    np.fill_diagonal(lat, 0.0)
    topo = Topology(profiles, bw, lat)
    cm = CommCostModel(topology=topo, expert_bytes=eb,
                       activation_bytes=pf.hidden_bytes_per_token,
                       tokens_per_horizon=1e5)
    ctrl = PlacementController(policy=get_policy("dancemoe"), cost=cm,
                               cluster=ClusterView.from_topology(topo, pf),
                               interval=20.0, topology=topo,
                               stats=_historical_stats(topo, pf, 0))
    ec = EdgeCluster("sim", topology=topo, profile=pf, controller=ctrl,
                     seed=0, failover=True,
                     fault_schedule=FaultSchedule.server_crash(30.0, 2))
    reqs = [dataclasses.replace(r, slo=1.5)
            for r in build_requests(20, 3, seed=0)]
    handles = [ec.submit(r) for r in reqs]
    ec.run()
    stalled = 0
    for h in handles:
        m = h.metrics
        fin = next(e for e in h.events if e.type == EventType.FINISHED)
        start = next(e for e in h.events
                     if e.type == EventType.ADMITTED).time
        # the contract under test: latency and slo_met are anchored at
        # the submit time, whatever the fault machinery did in between
        assert m["latency"] == pytest.approx(fin.time - h.submitted_at)
        assert m["wait"] == pytest.approx(start - h.submitted_at)
        assert m["slo_met"] == (m["latency"] <= 1.5)
        if start - h.submitted_at > 0.2:       # fast-forward stall
            stalled += 1
    # the scenario is only a regression test if the stall really
    # happened AND pushed someone past the deadline
    assert stalled >= 1, "crash recovery never stalled an arrival"
    assert any(h.metrics["slo_met"] is False and h.metrics["latency"] > 1.5
               for h in handles)


# ---------------------------------------------------------------------------
# Runtime backend: EDF admission + shed (in-process, dense engine)
# ---------------------------------------------------------------------------

def test_runtime_shed_and_edf():
    from repro.serving.runtime import ServingRuntime
    from test_paged_equivalence import BLOCK_SIZE, _engine
    eng, src, _ = _engine(False)
    # one slot: the queue is real. The doomed request (needs 8 ticks,
    # 3-tick budget) must be shed without ever occupying the slot.
    rtm = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE,
                         slo_aware=True)
    blocker = rtm.enqueue(Request(prompt=src.sample(1, 8)[0],
                                  max_new_tokens=4))
    doomed = rtm.enqueue(Request(prompt=src.sample(1, 8)[0],
                                 max_new_tokens=8, slo=3.0))
    rtm.run()
    assert rtm.sheds == 1
    assert blocker.done and len(blocker.result()) == 4
    assert doomed.done and len(doomed.result()) == 0
    assert doomed.metrics["shed"] and doomed.metrics["slo_met"] is False
    shed_ev = next(e for e in doomed.events if e.type == EventType.SHED)
    assert shed_ev.data["deadline"] == 3.0
    # EDF: tighter deadline jumps the queue (admitted first)
    rtm2 = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE,
                          slo_aware=True)
    b = rtm2.enqueue(Request(prompt=src.sample(1, 8)[0], max_new_tokens=2))
    loose = rtm2.enqueue(Request(prompt=src.sample(1, 8)[0],
                                 max_new_tokens=2, slo=200.0))
    tight = rtm2.enqueue(Request(prompt=src.sample(1, 8)[0],
                                 max_new_tokens=2, slo=50.0))
    rtm2.run()
    assert b.done and loose.done and tight.done and rtm2.sheds == 0
    assert tight.admitted_at < loose.admitted_at
    # FIFO (default) is unchanged: same stream admits in arrival order
    rtm3 = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE)
    l2 = rtm3.enqueue(Request(prompt=src.sample(1, 8)[0],
                              max_new_tokens=2, slo=200.0))
    t2 = rtm3.enqueue(Request(prompt=src.sample(1, 8)[0],
                              max_new_tokens=2, slo=50.0))
    rtm3.run()
    assert l2.admitted_at < t2.admitted_at and rtm3.sheds == 0


# ---------------------------------------------------------------------------
# Seeded Gumbel-max sampling
# ---------------------------------------------------------------------------

def test_sampling_greedy_identity_and_determinism():
    import jax.numpy as jnp
    from repro.serving.sampling import sample_token_host, sample_tokens
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(4, 64)).astype(np.float32)
    zeros = jnp.zeros((4,), jnp.float32)
    seeds = jnp.asarray([5, 6, 7, 8], jnp.uint32)
    pos = jnp.asarray([3, 3, 9, 9], jnp.uint32)
    # temperature 0 rows are exact argmax
    out0 = np.asarray(sample_tokens(jnp.asarray(logits), zeros, seeds, pos))
    np.testing.assert_array_equal(out0, np.argmax(logits, -1))
    # a sampled row depends only on (logits, temp, seed, position) — not
    # on what else sits in the batch
    temps = jnp.full((4,), 0.9, jnp.float32)
    full = np.asarray(sample_tokens(jnp.asarray(logits), temps, seeds, pos))
    for j in range(4):
        solo = sample_token_host(logits[j], 0.9, int(seeds[j]), int(pos[j]))
        assert solo == full[j]
    # ...and reruns are bit-identical
    again = np.asarray(sample_tokens(jnp.asarray(logits), temps, seeds, pos))
    np.testing.assert_array_equal(full, again)
    # the draw actually varies with the key: across 64 seeds at a hot
    # temperature the same row yields more than one token
    row = logits[0]
    outs = {sample_token_host(row, 1.5, s, 0) for s in range(64)}
    assert len(outs) > 1


# ---------------------------------------------------------------------------
# Router properties under origin skew (hypothesis satellite)
# ---------------------------------------------------------------------------

@st.composite
def skew_instance(draw):
    seed = draw(st.integers(0, 2 ** 16))
    skew = draw(st.integers(10, 25))           # /10 -> 1.0 .. 2.5
    mult = draw(st.integers(4, 8))
    return WorkloadSpec(
        duration=40.0, base_rate=2.5, n_origins=3, origin_skew=skew / 10.0,
        diurnal_period=40.0, diurnal_amplitude=0.3,
        crowds=(FlashCrowd(start=10.0, duration=15.0, multiplier=float(mult),
                           origin=0, fraction=0.85),),
        prompt_len=(96.0, 0.5, 8, 256), output_len=(16.0, 0.4, 4, 32),
        slo=6.0, seed=seed)


@settings(max_examples=6, deadline=None)
@given(skew_instance())
def test_router_properties_under_skew(spec):
    # least-loaded + SLO admission: a served request is only admitted on
    # a server that can start it inside its budget, so every origin's
    # p99 queue wait stays within the SLO — skew cannot fence an origin
    # behind the hot server's backlog
    ec = _sim_cluster(True, router="least-loaded")
    handles = drive(ec, WorkloadStream(spec), max_pending=32)
    waits: dict[int, list] = {}
    for h in handles:
        m = h.metrics
        if m.get("shed") or m.get("wait") is None:
            continue
        waits.setdefault(h.request.origin, []).append(m["wait"])
    assert waits
    for origin, ws in waits.items():
        assert float(np.percentile(ws, 99)) <= spec.slo + 1e-6, (
            f"origin {origin} p99 wait blew the SLO under least-loaded")
    # home routing + SLO admission: the crowd may force sheds, but no
    # origin is starved outright — every origin gets served requests
    # (the deadline-redirect rule spills the hot origin's overflow)
    ec2 = _sim_cluster(True, router="home")
    handles2 = drive(ec2, WorkloadStream(spec), max_pending=32)
    served = {o: 0 for o in range(3)}
    submitted = {o: 0 for o in range(3)}
    for h in handles2:
        submitted[h.request.origin] += 1
        if not h.metrics.get("shed"):
            served[h.request.origin] += 1
    for o in range(3):
        if submitted[o] >= 3:
            assert served[o] >= 1, (
                f"home routing shed-starved origin {o}: "
                f"{served[o]}/{submitted[o]} served")


# ---------------------------------------------------------------------------
# goodput_report unit semantics
# ---------------------------------------------------------------------------

def test_goodput_report_math():
    from repro.serving.api import RequestHandle
    hs = []
    for k, (lat, met, tokens) in enumerate(
            [(2.0, True, 10), (9.0, False, 10), (0.0, None, 5)]):
        r = Request(prompt=np.zeros(4, np.int32), max_new_tokens=tokens,
                    slo=6.0 if met is not None else None, arrival=float(k))
        h = RequestHandle(k, r, clock="seconds")
        h.submitted_at = float(k)
        h._emit(EventType.ADMITTED, k + 0.5, server=0)
        h._emit(EventType.FINISHED, k + max(lat, 0.5), tokens=tokens,
                latency=max(lat, 0.5), wait=0.5, slo=r.slo, slo_met=met,
                shed=False, origin=None, server=0)
        hs.append(h)
    rep = goodput_report(hs, span=10.0)
    # good tokens: the met request (10) + the no-SLO request (5); the
    # late request's 10 tokens were wasted work
    assert rep["goodput_tokens_per_s"] == pytest.approx(1.5)
    assert rep["total_tokens"] == 25
    assert rep["slo_met"] == 1 and rep["slo_attainment"] == 0.5
    assert rep["ttft"]["p50"] > 0 and rep["itl"]["p99"] >= 0


# ---------------------------------------------------------------------------
# Jitted-runtime leg (subprocess: own engine, kept out of this process)
# ---------------------------------------------------------------------------

def test_runtime_goodput_subprocess():
    """The flash-crowd economics on the real jitted stack: EDF + shed
    beats FIFO on goodput, reruns (with temperature sampling) are
    bit-identical, temperature-0 rows equal greedy generate()."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    script = Path(__file__).parent / "md_scripts" / "workload_runtime.py"
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, (
        f"workload_runtime.py failed:\n{r.stdout}\n{r.stderr}")
    assert "ALL OK" in r.stdout

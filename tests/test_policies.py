"""Unified placement control plane: policy registry + PlacementController
(the single owner of the Eq.-4 adopt decision)."""
import numpy as np
import pytest

from repro.core.migration import CostModel, MigrationController, \
    should_migrate
from repro.core.policies import (ClusterView, PlacementController,
                                 as_policy, get_policy, list_policies)
from repro.serving.cluster import DEEPSEEK_V2_LITE_PROFILE, paper_testbed
from tests.test_placement import skewed_freqs


def _cost_model(io=1e9):
    return CostModel(expert_bytes=50e6, activation_bytes=8192,
                     bandwidth=62.5e6, io_speed=io,
                     tokens_per_horizon=1e4)


def _cluster(L=4, N=3):
    cap = np.array([14, 16, 20])
    slots = np.minimum(cap // L + 2, 8)
    return ClusterView(capacity=cap, slots_cap=slots)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_covers_all_strategies():
    assert set(list_policies()) >= {"dancemoe", "uniform", "redundance",
                                    "smartmoe", "eplb"}


@pytest.mark.parametrize("name", ["dancemoe", "uniform", "redundance",
                                  "smartmoe", "eplb"])
def test_every_policy_produces_valid_coverage(name):
    L, N, E = 4, 3, 8
    freqs = skewed_freqs(L, N, E, seed=2)
    plan = get_policy(name).propose(freqs, _cluster(L, N))
    assert plan.num_experts == E
    # full expert coverage per layer
    assert (plan.residency().sum(1) > 0).all()


def test_cluster_view_constructors():
    pf = DEEPSEEK_V2_LITE_PROFILE
    cl = paper_testbed(0.3)
    cv = ClusterView.from_cluster(cl, pf)
    assert cv.n == cl.n
    np.testing.assert_array_equal(cv.capacity,
                                  cl.expert_capacity(pf.expert_bytes))
    assert (cv.slots_cap >= 1).all()
    assert (cv.slots_cap <= pf.num_experts).all()


def test_as_policy_accepts_name_callable_and_policy():
    L, N, E = 2, 3, 8
    freqs = skewed_freqs(L, N, E, seed=0)
    by_name = as_policy("uniform").propose(freqs, _cluster(2, 3))
    by_obj = as_policy(get_policy("uniform")).propose(freqs, _cluster(2, 3))
    from repro.core.baselines import uniform_plan
    by_fn = as_policy(lambda f: uniform_plan(*f.shape)).propose(
        freqs, _cluster(2, 3))
    assert by_name.assign == by_obj.assign == by_fn.assign


# ---------------------------------------------------------------------------
# Controller: adopt exactly when should_migrate says so
# ---------------------------------------------------------------------------

def test_initial_review_always_adopts_and_is_recorded():
    ctrl = PlacementController(policy="dancemoe", cost=_cost_model(),
                               cluster=_cluster(), interval=300.0)
    f = skewed_freqs(4, 3, 8, seed=1)
    dec = ctrl.review(0.0, f)
    assert dec.adopted and dec.plan is ctrl.plan
    assert ctrl.events[-1]["reason"] == "initial"
    # the legacy MigrationController shim adopted the initial plan but never
    # recorded it; GlobalScheduler recorded it — the unified controller
    # records it, and the shim filters it out for API compatibility
    shim = MigrationController(
        placement_fn=lambda fr: get_policy("dancemoe").propose(
            fr, _cluster()),
        cost=_cost_model(), interval=300.0)
    plan0, adopted0 = shim.maybe_migrate(0.0, f)
    assert adopted0 and shim.history == []
    assert shim.ctrl.events[-1]["reason"] == "initial"


def test_controller_matches_should_migrate_verbatim():
    """The controller's adopt/reject sequence must equal a hand-rolled
    should_migrate over the same candidate sequence."""
    L, N, E = 4, 3, 8
    cm = _cost_model()
    cluster = _cluster()
    policy = get_policy("dancemoe")
    freq_seq = [skewed_freqs(L, N, E, seed=s) for s in (1, 9, 9, 3)]

    ctrl = PlacementController(policy=policy, cost=cm, cluster=cluster,
                               interval=1.0)
    got = []
    plan = None
    expected = []
    for i, f in enumerate(freq_seq):
        dec = ctrl.review(float(i), f)
        got.append(dec.adopted)
        cand = policy.propose(f, cluster)
        if plan is None:
            exp = True
        else:
            exp, _ = should_migrate(plan, cand, f, cm)
        expected.append(exp)
        if exp:
            plan = cand
    assert got == expected
    # and every non-interval review appended exactly one event
    assert len(ctrl.events) == len(freq_seq)


def test_interval_gating_and_force():
    ctrl = PlacementController(policy="dancemoe", cost=_cost_model(),
                               cluster=_cluster(), interval=300.0)
    f1 = skewed_freqs(4, 3, 8, seed=1)
    f2 = skewed_freqs(4, 3, 8, seed=9)
    assert ctrl.review(0.0, f1).adopted
    within = ctrl.review(100.0, f2)
    assert not within.adopted and within.diag["reason"] == "interval"
    assert len(ctrl.events) == 1           # interval skips are not events
    forced = ctrl.review(100.0, f2, force=True)
    assert forced.diag.get("reason") != "interval"
    assert "C_old" in forced.diag                  # a real Eq.-4 review ran
    due = ctrl.review(500.0, f2)
    assert due.diag.get("reason") != "interval"


def test_no_cost_model_always_follows_policy():
    ctrl = PlacementController(policy="dancemoe", cost=None,
                               cluster=_cluster(), interval=1.0)
    f1 = skewed_freqs(4, 3, 8, seed=1)
    f2 = skewed_freqs(4, 3, 8, seed=9)
    assert ctrl.review(0.0, f1).adopted
    dec = ctrl.review(10.0, f2)
    assert dec.adopted and dec.diag["reason"] == "no-cost-model"


def test_controller_owns_stats_ingestion():
    from repro.core.stats import ActivationStats
    L, N, E = 2, 3, 8
    ctrl = PlacementController(policy="uniform", cluster=_cluster(L, N),
                               stats=ActivationStats(L, N, E))
    counts = np.zeros((L, N, E))
    counts[:, 1, 3] = 5.0
    ctrl.observe(counts)
    ctrl.observe_server(0, np.ones((L, E)))
    f = ctrl.freqs()
    assert f.shape == (L, N, E)
    assert np.allclose(f.sum(-1), 1.0)
    assert f[0, 1, 3] > f[0, 1, 0]
    dec = ctrl.review(0.0)                 # freqs pulled from owned stats
    assert dec.adopted

"""Property-based token-identity suite for the paged serving runtime.

Randomized request streams (arrival ticks, prompt lengths, max_new_tokens,
pool geometry) must produce outputs identical to sequential
``ServingEngine.generate()`` *and* to the legacy dense-pool runtime —
for the fp32 KV layout and the int8 KV-quant layout. Runs under real
``hypothesis`` when installed, else the deterministic fallback in
``tests/_hypothesis_fallback.py`` (see conftest.py).

The randomized bulk (>= 25 cases per leg) drives a dense-MoE-impl engine
— identical attention/KV-paging code paths without the ~0.7 s/call CPU
overhead of the shard_map EP dispatch — while deterministic three-way
tests cover the full EP-dispatch engine for both KV layouts.

Also exercises the runtime-level allocator behavior: admission deferral on
block exhaustion, page reuse, and the no-aliasing invariants.
"""
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data.pipeline import TaskTokenSource
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models import transformer as tr
from repro.serving.api import Request
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ServingRuntime

MAX_LEN = 64
# small menus keep the jit-compile universe tiny: each distinct prompt
# length compiles the reference prefill once per engine (module-cached)
PROMPT_LENS = (4, 8, 12, 17, 24)
BLOCK_SIZE = 8

_ENGINES: dict = {}


def _engine(kv_quant: bool):
    """Fast engine for the randomized bulk: mixtral with the dense MoE
    impl — identical attention/paging code paths, no shard_map dispatch
    overhead per jitted call. Module-level lazy singleton (the hypothesis
    fallback's ``given`` wrapper takes no pytest fixtures)."""
    key = ("dense", kv_quant)
    if key not in _ENGINES:
        cfg = get_config("mixtral-8x7b").reduced()
        mesh = make_test_mesh(1, 1)
        rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense",
                        kv_quant=kv_quant)
        params = tr.init_params(
            tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense"),
            jax.random.PRNGKey(0))
        eng = ServingEngine(rt=rt, params=params, placement=None,
                            max_len=MAX_LEN)
        src = TaskTokenSource("arith", cfg.vocab_size, seed=3)
        refs: dict = {}
        _ENGINES[key] = (eng, src, refs)
    return _ENGINES[key]


def _ep_engine(kv_quant: bool):
    """Full EP-dispatch engine (uniform placement) — the production path;
    used by the deterministic three-way tests and the shim regression
    suite (shard_map calls are ~0.7 s each on CPU, so the randomized bulk
    runs on ``_engine`` instead)."""
    key = ("ep", kv_quant)
    if key not in _ENGINES:
        cfg = get_config("mixtral-8x7b").reduced()
        mesh = make_test_mesh(1, 1)
        spec = M.EPSpec.build(mesh, cfg, ep_axes=("model",),
                              slots=cfg.num_experts, capacity=4096,
                              slot_capacity=8192)
        _, n_groups = cfg.layer_pattern()
        rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="ep", ep_spec=spec,
                        kv_quant=kv_quant)
        rt_dense = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
        params_dense = tr.init_params(rt_dense, jax.random.PRNGKey(0))
        pl = M.uniform_placement(spec.n_ep, spec.slots, cfg.num_experts)
        pls = tr.stack_placement(pl, n_groups)
        params = dict(params_dense)
        params["groups"] = M.regather_ep_groups(params_dense["groups"], pls,
                                                n_groups)
        eng = ServingEngine(rt=rt, params=params, placement=pls,
                            max_len=MAX_LEN)
        src = TaskTokenSource("arith", cfg.vocab_size, seed=3)
        refs: dict = {}
        _ENGINES[key] = (eng, src, refs)
    return _ENGINES[key]


def _reference(eng, refs, prompt, steps):
    key = (prompt.tobytes(), steps)
    if key not in refs:
        refs[key] = eng.generate(prompt[None], steps=steps)[0][0]
    return refs[key]


@st.composite
def request_stream(draw):
    """A randomized request stream plus a paged-pool geometry."""
    n = draw(st.integers(1, 4))
    reqs = []
    for _ in range(n):
        reqs.append(dict(
            plen=draw(st.sampled_from(PROMPT_LENS)),
            pseed=draw(st.integers(0, 3)),
            steps=draw(st.integers(1, 6)),
            arrival=draw(st.integers(0, 5)),
        ))
    # two geometries: roomy, and tight enough to force deferrals
    n_blocks = draw(st.sampled_from([7, 33]))
    return reqs, n_blocks


def _drive(rtm, jobs):
    """Submit per arrival tick, step to drain; returns {rid: tokens}.
    Backlog-aware: a ``warmup=True`` runtime keeps stepping while pending
    round records exist and force-drains at the end (both no-ops on the
    synchronous loop)."""
    pending = sorted(jobs, key=lambda j: j["arrival"])
    t = 0
    rids = {}
    while pending or rtm.queue or rtm.active or rtm._pending:
        while pending and pending[0]["arrival"] <= t:
            j = pending.pop(0)
            rids[id(j)] = rtm.enqueue(Request(prompt=j["prompt"],
                                              max_new_tokens=j["steps"],
                                              eos=j.get("eos"))).rid
        rtm.step()
        rtm.check_invariants()
        t += 1
    rtm.flush()
    return {id(j): rtm.finished[rids[id(j)]] for j in jobs}


def _run_equivalence(kv_quant: bool, scenario):
    eng, src, refs = _engine(kv_quant)
    reqs, n_blocks = scenario
    jobs = []
    for r in reqs:
        prompt = TaskTokenSource("arith", eng.rt.cfg.vocab_size,
                                 seed=r["pseed"]).sample(1, r["plen"])[0]
        jobs.append(dict(prompt=prompt, steps=r["steps"],
                         arrival=r["arrival"]))
    # skip streams no pool of this size can ever serve
    cap_blocks = n_blocks - 1
    need = [-(-(len(j["prompt"]) + j["steps"] - 1) // BLOCK_SIZE)
            for j in jobs]
    jobs = [j for j, np_ in zip(jobs, need) if np_ <= cap_blocks]
    if not jobs:
        return

    paged = ServingRuntime(eng, max_slots=3, block_size=BLOCK_SIZE,
                           n_blocks=n_blocks)
    assert paged.paged
    out_p = _drive(paged, jobs)
    # every non-cache-held page returned; dropping the radix cache
    # releases the rest down to an empty allocator
    paged.drop_prefix_cache()
    assert paged.allocator.n_free == paged.allocator.capacity_blocks
    assert not paged.allocator.live()            # all pages returned

    dense = ServingRuntime(eng, max_slots=3, paged=False)
    out_d = _drive(dense, jobs)

    for j in jobs:
        ref = _reference(eng, refs, j["prompt"], j["steps"])
        np.testing.assert_array_equal(out_p[id(j)], ref)
        np.testing.assert_array_equal(out_d[id(j)], ref)


@settings(max_examples=25, deadline=None)
@given(request_stream())
def test_paged_matches_sequential_and_dense_fp(scenario):
    """fp32 KV leg: paged == dense == sequential, >= 25 random streams."""
    _run_equivalence(False, scenario)


@settings(max_examples=25, deadline=None)
@given(request_stream())
def test_paged_matches_sequential_and_dense_int8(scenario):
    """int8 KV-quant leg: paged == dense == sequential (the engine's
    serve-consistent fake-quant prefill makes all three bit-identical)."""
    _run_equivalence(True, scenario)


# ---------------------------------------------------------------------------
# Radix prefix cache: shared-prefix families stay token-identical
# ---------------------------------------------------------------------------

def _family_prompt(vocab: int, shared_len: int, fam: int, tail_len: int,
                   member: int) -> np.ndarray:
    """Member prompt = family-shared prefix + member-unique tail. Distinct
    leading tokens per family keep different families disjoint."""
    shared = TaskTokenSource("arith", vocab, seed=1000 + fam).sample(
        1, shared_len)[0]
    shared[0] = fam % vocab              # families never share block 1
    if tail_len == 0:
        return shared
    tail = TaskTokenSource("arith", vocab,
                           seed=2000 + 17 * fam + member).sample(
        1, tail_len)[0]
    return np.concatenate([shared, tail])


@st.composite
def prefix_family_stream(draw):
    """Streams dominated by shared-prefix prompt families (the edge
    workload the radix cache targets), incl. exact-duplicate prompts
    (tail_len 0 duplicates the family prefix prompt)."""
    jobs = []
    for fam in range(draw(st.integers(1, 2))):
        shared_len = draw(st.sampled_from((8, 12, 16, 24)))
        for member in range(draw(st.integers(2, 3))):
            jobs.append(dict(
                fam=fam, shared_len=shared_len,
                tail_len=draw(st.sampled_from((0, 3, 5, 8))),
                member=member, steps=draw(st.integers(1, 6)),
                arrival=draw(st.integers(0, 6)),
            ))
    n_blocks = draw(st.sampled_from([9, 33]))    # tight pool forces evictions
    return jobs, n_blocks


def _run_prefix_equivalence(kv_quant: bool, scenario):
    eng, src, refs = _engine(kv_quant)
    specs, n_blocks = scenario
    jobs = []
    for sp in specs:
        prompt = _family_prompt(eng.rt.cfg.vocab_size, sp["shared_len"],
                                sp["fam"], sp["tail_len"], sp["member"])
        jobs.append(dict(prompt=prompt, steps=sp["steps"],
                         arrival=sp["arrival"]))
    cap_blocks = n_blocks - 1
    need = [-(-(len(j["prompt"]) + j["steps"] - 1) // BLOCK_SIZE)
            for j in jobs]
    jobs = [j for j, np_ in zip(jobs, need) if np_ <= cap_blocks]
    if not jobs:
        return
    paged = ServingRuntime(eng, max_slots=3, block_size=BLOCK_SIZE,
                           n_blocks=n_blocks)
    assert paged.prefix_cache is not None
    out_p = _drive(paged, jobs)
    paged.drop_prefix_cache()
    assert paged.allocator.n_free == paged.allocator.capacity_blocks
    for j in jobs:
        ref = _reference(eng, refs, j["prompt"], j["steps"])
        np.testing.assert_array_equal(out_p[id(j)], ref)


@settings(max_examples=15, deadline=None)
@given(prefix_family_stream())
def test_prefix_cache_matches_sequential_fp(scenario):
    """fp32 KV leg: shared-prefix streams served through the radix cache
    (partial hits, full hits + CoW, evictions under tight pools) are
    token-identical to sequential ``generate()``."""
    _run_prefix_equivalence(False, scenario)


@settings(max_examples=15, deadline=None)
@given(prefix_family_stream())
def test_prefix_cache_matches_sequential_int8(scenario):
    """int8 KV-quant leg of the shared-prefix property: cached pages store
    quantized k/v, and sharers read back exactly what the original request
    wrote — bit-identical to the cold path."""
    _run_prefix_equivalence(True, scenario)


def test_disjoint_stream_unaffected_by_prefix_cache():
    """A stream with no shared block-aligned prefixes behaves *identically*
    with the cache on and off: same tokens, same chunk compute, zero hits,
    zero CoW copies."""
    eng, src, refs = _engine(False)
    vocab = eng.rt.cfg.vocab_size
    jobs = []
    for k in range(5):
        prompt = TaskTokenSource("arith", vocab, seed=50 + k).sample(
            1, 12 + 4 * (k % 3))[0]
        prompt[0] = k % vocab                    # distinct first block
        jobs.append(dict(prompt=prompt, steps=2 + k % 4, arrival=k // 2))
    outs, stats = [], []
    for cache_on in (True, False):
        rtm = ServingRuntime(eng, max_slots=3, block_size=BLOCK_SIZE,
                             n_blocks=17, prefix_cache=cache_on)
        outs.append(_drive(rtm, jobs))
        stats.append((rtm.chunks_executed, rtm.prefill_calls, rtm.ticks,
                      rtm.deferrals))
        if cache_on:
            assert rtm.prefix_hits == 0
            assert rtm.prefix_tokens_skipped == 0
            assert rtm.cow_copies == 0
        else:
            assert rtm.prefix_cache is None
    assert stats[0] == stats[1]                  # identical schedule/compute
    for j in jobs:
        np.testing.assert_array_equal(outs[0][id(j)], outs[1][id(j)])


def test_identical_prompts_skip_prefill_entirely():
    """Second occurrence of an identical prompt is a full hit: zero chunks
    executed for it, first token recomputed from the cached logits, CoW
    clone taken for its decode writes."""
    eng, src, refs = _engine(False)
    prompt = src.sample(1, 20)[0]                # 2 full blocks + 4-token tail
    ref = _reference(eng, refs, prompt, 4)
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                         n_blocks=17)
    r0 = rtm.enqueue(Request(prompt=prompt, max_new_tokens=4)).rid
    rtm.run()
    chunks_cold = rtm.chunks_executed
    r1 = rtm.enqueue(Request(prompt=prompt, max_new_tokens=4)).rid
    out = rtm.run()
    assert rtm.chunks_executed == chunks_cold    # no prefill for the rerun
    assert rtm.prefix_hits == 1
    assert rtm.prefix_tokens_skipped == len(prompt)
    assert rtm.cow_copies == 1                   # shared tail was cloned
    np.testing.assert_array_equal(out[r0], ref)
    np.testing.assert_array_equal(out[r1], ref)


# ---------------------------------------------------------------------------
# AOT warmup + zero-stall loop: warm == sync == sequential
# ---------------------------------------------------------------------------

# one pool geometry for every warmup leg below, so all legs (fp32 and int8
# engines separately) share a single AOT bucket ladder per engine
_WARM_SLOTS, _WARM_BLOCKS = 3, 33


def _warm_vs_sync(kv_quant: bool, cache_on: bool):
    """warmup-on == warmup-off == sequential ``generate()`` on a staggered
    stream, and the warmed leg performs zero post-warmup jit traces."""
    eng, src, refs = _engine(kv_quant)
    jobs = [dict(prompt=src.sample(1, plen)[0], steps=s, arrival=a)
            for plen, s, a in ((16, 6, 0), (12, 4, 0), (17, 5, 2),
                               (8, 3, 4))]
    outs = {}
    for warm in (False, True):
        rtm = ServingRuntime(eng, max_slots=_WARM_SLOTS,
                             block_size=BLOCK_SIZE, n_blocks=_WARM_BLOCKS,
                             prefix_cache=cache_on, warmup=warm,
                             warmup_origins="untagged")
        outs[warm] = _drive(rtm, jobs)
        if warm:
            assert rtm.traces_after_warmup == 0
    for j in jobs:
        ref = _reference(eng, refs, j["prompt"], j["steps"])
        np.testing.assert_array_equal(outs[False][id(j)], ref)
        np.testing.assert_array_equal(outs[True][id(j)], ref)


def test_warm_equivalence_fp():
    _warm_vs_sync(False, cache_on=False)


def test_warm_equivalence_fp_prefix_cache():
    _warm_vs_sync(False, cache_on=True)


def test_warm_equivalence_int8():
    _warm_vs_sync(True, cache_on=False)


def test_warm_equivalence_int8_prefix_cache():
    _warm_vs_sync(True, cache_on=True)


def test_warm_eos_lagged_stop_detection():
    """EOS-hitting requests: the zero-stall loop detects the stop at drain
    (one round late) yet emits exactly the synchronous stream — the extra
    speculative token is dropped by the rid guard, pages are released, and
    the pool invariants hold throughout."""
    eng, src, refs = _engine(False)
    prompt = src.sample(1, 16)[0]
    ref = np.asarray(_reference(eng, refs, prompt, 8))[-8:]
    # stop on the latest token whose *first* occurrence is mid-stream, so
    # the eos genuinely fires at position k; a constant stream (rare, the
    # token source is hash-salted per process) degrades to k=0, where the
    # eos stop and the one-token stream still have to agree
    k = max((i for i in range(1, len(ref))
             if ref[i] not in ref[:i]), default=0)
    eos = int(ref[k])
    jobs = [dict(prompt=prompt, steps=8, arrival=0, eos=eos),
            dict(prompt=src.sample(1, 12)[0], steps=5, arrival=1)]
    outs = {}
    for warm in (False, True):
        rtm = ServingRuntime(eng, max_slots=_WARM_SLOTS,
                             block_size=BLOCK_SIZE, n_blocks=_WARM_BLOCKS,
                             warmup=warm, warmup_origins="untagged")
        outs[warm] = _drive(rtm, jobs)
        # EOS retirement returned the pages in both loop structures
        rtm.drop_prefix_cache()
        assert not rtm.allocator.live()
    np.testing.assert_array_equal(outs[False][id(jobs[0])], ref[:k + 1])
    np.testing.assert_array_equal(outs[True][id(jobs[0])], ref[:k + 1])
    ref1 = _reference(eng, refs, jobs[1]["prompt"], 5)
    np.testing.assert_array_equal(outs[False][id(jobs[1])], ref1)
    np.testing.assert_array_equal(outs[True][id(jobs[1])], ref1)


# ---------------------------------------------------------------------------
# EP-dispatch engine: deterministic three-way checks on the production path
# ---------------------------------------------------------------------------

def _ep_three_way(kv_quant: bool):
    eng, src, refs = _ep_engine(kv_quant)
    jobs = [dict(prompt=src.sample(1, 16)[0], steps=5, arrival=0),
            dict(prompt=src.sample(1, 12)[0], steps=3, arrival=1),
            dict(prompt=src.sample(1, 20)[0], steps=4, arrival=2)]
    paged = ServingRuntime(eng, max_slots=3, block_size=BLOCK_SIZE,
                           n_blocks=25)
    assert paged.paged
    out_p = _drive(paged, jobs)
    dense = ServingRuntime(eng, max_slots=3, paged=False)
    out_d = _drive(dense, jobs)
    for j in jobs:
        ref = _reference(eng, refs, j["prompt"], j["steps"])
        np.testing.assert_array_equal(out_p[id(j)], ref)
        np.testing.assert_array_equal(out_d[id(j)], ref)
    assert paged.max_concurrency >= 2          # streams truly shared a batch


def test_ep_paged_three_way_fp():
    """EP dispatch + paged pool: paged == dense == sequential (fp32 KV)."""
    _ep_three_way(False)


def test_ep_paged_three_way_int8():
    """EP dispatch + paged pool, int8 KV-quant layout: all three paths
    bit-identical (serve-consistent fake-quant prefill)."""
    _ep_three_way(True)


# ---------------------------------------------------------------------------
# Runtime-level allocator behavior (deterministic)
# ---------------------------------------------------------------------------

def test_exhaustion_defers_admission_then_serves():
    """A pool too small for the whole stream defers admissions (no crash,
    no drop) and serves every request as retirements free blocks."""
    eng, src, refs = _engine(False)
    prompt = src.sample(1, 12)[0]
    ref = _reference(eng, refs, prompt, 4)
    rtm = ServingRuntime(eng, max_slots=4, block_size=BLOCK_SIZE, n_blocks=5)
    rids = [rtm.enqueue(Request(prompt=prompt, max_new_tokens=4)).rid
            for _ in range(4)]
    out = rtm.run()
    assert rtm.deferrals > 0                      # pool pressure was real
    assert len(out) == 4
    for rid in rids:
        np.testing.assert_array_equal(out[rid], ref)


def test_freed_pages_are_reused():
    eng, src, refs = _engine(False)
    prompt = src.sample(1, 12)[0]
    rtm = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE, n_blocks=5,
                         prefix_cache=False)
    pages_by_rid: dict = {}
    rtm.enqueue(Request(prompt=prompt, max_new_tokens=2))
    rtm.enqueue(Request(prompt=prompt, max_new_tokens=2))
    while rtm.queue or rtm.active:
        rtm.step()
        for s in rtm.slots:
            if s is not None:
                pages_by_rid.setdefault(s.rid, set()).update(s.pages)
    # with a 1-slot cache-less runtime the requests run strictly in
    # sequence; the second's pages must come out of the first's freed set
    assert set(rtm.finished) == {0, 1}
    assert pages_by_rid[1] <= pages_by_rid[0]
    assert rtm.allocator.n_free == rtm.allocator.capacity_blocks


def test_shared_prefix_pages_are_not_duplicated():
    """With the cache on, a same-prefix successor *shares* the cached
    blocks (refcount) instead of re-allocating them — the memory half of
    the prefix-cache win."""
    eng, src, refs = _engine(False)
    shared = src.sample(1, 16)[0]                 # 2 full blocks
    p_a = np.concatenate([shared, src.sample(1, 5)[0]])
    p_b = np.concatenate([shared, src.sample(1, 7)[0]])
    rtm = ServingRuntime(eng, max_slots=1, block_size=BLOCK_SIZE,
                         n_blocks=17)
    rtm.enqueue(Request(prompt=p_a, max_new_tokens=2))
    rtm.run()
    pages_a = set()
    rtm.enqueue(Request(prompt=p_b, max_new_tokens=2))
    while rtm.queue or rtm.active:
        rtm.step()
        rtm.check_invariants()
        for s in rtm.slots:
            if s is not None:
                pages_a.update(s.pages[:2])       # its two prefix blocks
    assert rtm.prefix_hits == 1
    assert rtm.prefix_tokens_skipped == 16
    # the successor's prefix blocks are exactly the cached (still-held) ones
    cache_blocks = set(rtm.prefix_cache.block_refs())
    assert pages_a <= cache_blocks


def test_no_page_aliasing_and_full_return_under_churn():
    """Across a churning stream with prefix sharing, refcounts always match
    the holders, no slot ever writes a shared block, and dropping the cache
    at the end returns every page."""
    eng, src, refs = _engine(False)
    rtm = ServingRuntime(eng, max_slots=3, block_size=BLOCK_SIZE,
                         n_blocks=9)
    rng = np.random.default_rng(0)
    for k in range(6):
        rtm.enqueue(Request(
            prompt=src.sample(1, int(rng.choice([4, 8, 12])))[0],
            max_new_tokens=int(rng.integers(1, 5))))
    while rtm.queue or rtm.active:
        rtm.step()
        rtm.check_invariants()                   # asserts no aliasing
    rtm.drop_prefix_cache()
    assert not rtm.allocator.live()
    assert rtm.allocator.n_free == rtm.allocator.capacity_blocks


def test_origin_attribution_and_validation():
    """Requests tagged with ``submit(origin=...)`` keep their outputs
    identical to untagged serving (origin only relabels statistics), and
    out-of-range origins are rejected up front — the gating-stats scatter
    would otherwise drop them silently."""
    import pytest
    eng, src, refs = _ep_engine(False)
    prompt = src.sample(1, 12)[0]
    ref = _reference(eng, refs, prompt, 3)
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                         n_blocks=9, prefix_cache=False)
    before = eng.stats.counts.sum()
    rid = rtm.enqueue(Request(prompt=prompt, max_new_tokens=3,
                       origin=0)).rid        # explicit origin leg
    out = rtm.run()
    np.testing.assert_array_equal(out[rid], ref)
    assert eng.stats.counts.sum() > before        # stats did flow
    with pytest.raises(ValueError):
        rtm.enqueue(Request(prompt=prompt, max_new_tokens=3,
                    origin=1))                # n_ep == 1: rank 1 invalid
    with pytest.raises(ValueError):
        Request(prompt=prompt, max_new_tokens=3, origin=-1)
    with pytest.raises(ValueError):
        rtm.enqueue(Request(prompt=prompt,
                    max_new_tokens=3))        # tagged stream: no mixing
    untagged = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                              n_blocks=9, prefix_cache=False)
    untagged.enqueue(Request(prompt=prompt, max_new_tokens=3))
    with pytest.raises(ValueError):
        untagged.enqueue(Request(prompt=prompt, max_new_tokens=3,
                         origin=0))           # and the reverse


def test_submit_validates_against_pool_capacity():
    """Satellite fix: paged admission control is total-capacity based.
    A request longer than the legacy ``max_len`` is admissible when the
    pool can hold it; one exceeding the pool is rejected up front."""
    eng, src, refs = _engine(False)
    # capacity: 16 blocks x 8 = 128 positions > max_len = 64
    rtm = ServingRuntime(eng, max_slots=2, block_size=BLOCK_SIZE,
                         n_blocks=17)
    long_prompt = src.sample(1, 70)[0]            # > max_len, fits pool
    rid = rtm.enqueue(Request(prompt=long_prompt, max_new_tokens=4)).rid
    out = rtm.run()
    assert len(out[rid]) == 4
    import pytest
    with pytest.raises(ValueError):
        rtm.enqueue(Request(prompt=src.sample(1, 126)[0],
                    max_new_tokens=8))        # 133 > 128 positions


def test_compact_prefill_token_identity_and_row_savings():
    """Satellite: bucketing the batched ``prefill_chunk`` call at
    power-of-two occupied-slot widths (mirroring ``compact_decode``) is
    output-invariant — and a staggered stream over a wide pool executes
    strictly fewer batch rows than the fixed ``max_slots`` width. (The
    randomized property suite above runs with the bucketing ON, so this
    pins the OFF path and the savings.)"""
    eng, src, refs = _engine(False)
    jobs = [dict(prompt=src.sample(1, plen)[0], steps=3, arrival=a)
            for plen, a in ((24, 0), (17, 0), (12, 3), (8, 5))]
    outs, rows = {}, {}
    for compact in (True, False):
        rtm = ServingRuntime(eng, max_slots=8, block_size=BLOCK_SIZE,
                             n_blocks=65, compact_prefill=compact)
        outs[compact] = _drive(rtm, jobs)
        rows[compact] = rtm.prefill_rows
        assert rtm.chunks_executed == sum(-(-len(j["prompt"]) // BLOCK_SIZE)
                                          for j in jobs)
    for j in jobs:
        ref = _reference(eng, refs, j["prompt"], j["steps"])
        np.testing.assert_array_equal(outs[True][id(j)], ref)
        np.testing.assert_array_equal(outs[False][id(j)], ref)
    # <= 4 slots ever prefill together: buckets of 1/2/4 vs always 8
    assert rows[True] < rows[False]

"""Topology / communication subsystem tests (``repro.serving.net``).

Covers the three contracts the subsystem makes:

* **metering** — the per-link dispatch bytes the ``TrafficMeter`` derives
  from the per-origin ``[n_ep, E]`` gating attribution equal gating mass x
  bytes/token under *any* placement (property test over random residencies,
  counts and link costs, checked against a brute-force per-(src, e) walk);
* **staged migration** — an adopted plan switches only after its modeled
  transfers finish (event-ordering), transfers serialize per link, and the
  schedule is deterministic: reruns of both ``EdgeCluster`` backends
  complete migrations at identical modeled times (the runtime backend runs
  in a 3-device subprocess, ``md_scripts/staged_migration_runtime.py``);
* **budgets** — ``ServerProfile`` memory caps bound expert and KV-block
  budgets heterogeneously.
"""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import uniform_plan
from repro.core.placement import PlacementPlan, dancemoe_placement
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.api import EventType, Request
from repro.serving.cluster import EdgeCluster, MoEProfile
from repro.serving.net import (CommCostModel, ServerProfile, Topology,
                               TrafficMeter, TransferTask,
                               plan_transfers, route_targets,
                               schedule_transfers)


def skewed_freqs(L, N, E, seed=0):
    rng = np.random.default_rng(seed)
    return rng.dirichlet(np.full(E, 0.3), size=(L, N))


def wan_topology(n: int = 3) -> Topology:
    """Non-uniform test topology: server n-1 sits behind a slow link."""
    profiles = tuple(
        ServerProfile(f"s{i}", mem_bytes=4e9 if i < n - 1 else 1e9,
                      kv_mem_bytes=2e9 if i < n - 1 else 0.5e9)
        for i in range(n))
    bw = np.full((n, n), 64e6)
    lat = np.full((n, n), 2e-3)
    bw[:, n - 1] = bw[n - 1, :] = 4e6
    lat[:, n - 1] = lat[n - 1, :] = 40e-3
    np.fill_diagonal(lat, 0.0)
    return Topology(profiles, bw, lat)


# ---------------------------------------------------------------------------
# Profiles, topology, budgets
# ---------------------------------------------------------------------------

def test_server_profile_budgets_are_heterogeneous():
    topo = wan_topology(3)
    eb = 50e6
    budgets = topo.expert_budgets(eb)
    assert budgets[0] == budgets[1] == int(4e9 // eb)
    assert budgets[2] == int(1e9 // eb) < budgets[0]
    kv = topo.kv_block_budgets(1e6)
    assert kv[2] < kv[0]
    assert (kv >= 1).all()


def test_topology_validation():
    with pytest.raises(ValueError):       # shape mismatch
        Topology((ServerProfile("a"),), np.zeros((2, 2)), np.zeros((2, 2)))
    bw = np.full((2, 2), 1e6)
    bad = bw.copy()
    bad[0, 1] = 0.0                        # zero off-diagonal bandwidth
    with pytest.raises(ValueError):
        Topology((ServerProfile("a"), ServerProfile("b")), bad,
                 np.zeros((2, 2)))
    with pytest.raises(ValueError):        # negative latency
        Topology((ServerProfile("a"), ServerProfile("b")), bw,
                 np.full((2, 2), -1.0))


def test_transfer_seconds_and_asymmetry():
    bw = np.array([[1.0, 1e6], [2e6, 1.0]])
    lat = np.array([[0.0, 0.5], [0.25, 0.0]])
    topo = Topology((ServerProfile("a"), ServerProfile("b")), bw, lat)
    assert topo.transfer_seconds(0, 0, 1e9) == 0.0
    assert topo.transfer_seconds(0, 1, 1e6) == pytest.approx(1.0 + 0.5)
    assert topo.transfer_seconds(1, 0, 1e6) == pytest.approx(0.5 + 0.25)
    ls = topo.link_seconds(2e6)
    assert ls[0, 0] == ls[1, 1] == 0.0
    assert ls[0, 1] == pytest.approx(2.0 + 0.5)


def test_cluster_spec_round_trip():
    from repro.serving.cluster import paper_testbed
    spec = paper_testbed(0.3)
    topo = Topology.from_cluster_spec(spec)
    assert topo.n == spec.n
    assert np.allclose(topo.bandwidth[0, 1], spec.bandwidth)
    assert topo.profiles[2].mem_bytes == spec.servers[2].mem_bytes
    # the legacy rtt is a round-trip charge: the lifted topology splits
    # it per leg so a remote invocation pays exactly rtt, not 2x
    assert topo.round_trip_seconds(0.0)[0, 1] == pytest.approx(spec.rtt)
    back = topo.to_cluster_spec()
    assert back.bandwidth == pytest.approx(spec.bandwidth)
    assert back.rtt == pytest.approx(spec.rtt)
    assert [s.mem_bytes for s in back.servers] == \
        [s.mem_bytes for s in spec.servers]


def test_route_targets_cheapest_link_and_local_override():
    # expert 0 resident on 0 and 2; expert 1 only on 2; expert 2 only on 1
    res = np.array([[1, 0, 0],
                    [0, 0, 1],
                    [1, 1, 0]]).T          # [N=3, E=3]
    cost = np.array([[0.0, 1.0, 9.0],
                     [1.0, 0.0, 2.0],
                     [9.0, 2.0, 0.0]])
    tgt = route_targets(res, cost)
    assert tgt[0, 0] == 0                  # local always wins
    assert tgt[1, 0] == 0                  # cheapest holder of e0 from s1
    assert tgt[0, 1] == 2                  # only holder
    assert tgt[2, 2] == 1
    with pytest.raises(ValueError):        # uncovered expert
        route_targets(np.zeros((2, 2)), np.zeros((2, 2)))


# ---------------------------------------------------------------------------
# Traffic metering property: metered bytes == gating mass x bytes/token
# ---------------------------------------------------------------------------

@st.composite
def metering_case(draw):
    N = draw(st.integers(2, 4))
    E = draw(st.integers(3, 6))
    L = draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    # random placement with coverage: every expert resident somewhere
    res = (rng.random((L, N, E)) < 0.4).astype(float)
    for l in range(L):
        for e in range(E):
            if res[l, :, e].sum() == 0:
                res[l, rng.integers(N), e] = 1.0
    counts = rng.integers(0, 50, size=(L, N, E)).astype(float)
    bw = rng.uniform(1e6, 1e8, size=(N, N))
    lat = rng.uniform(0.0, 0.05, size=(N, N))
    np.fill_diagonal(lat, 0.0)
    return res, counts, bw, lat, seed


@settings(max_examples=40, deadline=None)
@given(metering_case())
def test_metered_bytes_equal_gating_mass_times_bytes_per_token(case):
    res, counts, bw, lat, seed = case
    L, N, E = counts.shape
    topo = Topology(tuple(ServerProfile(f"s{i}") for i in range(N)), bw, lat)
    hidden = 1024.0
    meter = TrafficMeter(topo, hidden)
    got = meter.record(counts, res)

    # brute force: every (layer, origin, expert) activation pays one
    # forward + one return activation transfer on its cheapest-holder
    # link *pair* (round trip — the return leg has its own bandwidth on
    # asymmetric topologies); local activations pay nothing
    expect = np.zeros((N, N))
    cost = topo.round_trip_seconds(hidden)
    remote_mass = 0.0
    for l in range(L):
        for src in range(N):
            for e in range(E):
                c = counts[l, src, e]
                if c == 0:
                    continue
                if res[l, src, e] > 0:
                    continue               # local: no link traffic
                holders = np.where(res[l, :, e] > 0)[0]
                tgt = holders[np.argmin(cost[src, holders])]
                expect[src, tgt] += c * hidden
                expect[tgt, src] += c * hidden
                remote_mass += c
    np.testing.assert_allclose(got, expect)
    np.testing.assert_allclose(meter.link_bytes, expect)
    assert meter.cross_server_bytes == pytest.approx(
        remote_mass * 2 * hidden)
    assert np.all(np.diag(got) == 0.0)


def test_meter_observe_diffs_cumulative_counts():
    topo = Topology.uniform(2, bandwidth=1e7, rtt=1e-3)
    res = np.ones((1, 2, 2))               # all local everywhere
    res[0, 0, 1] = 0.0                     # e1 not on s0 -> remote for s0
    meter = TrafficMeter(topo, hidden_bytes=100.0)
    total = np.zeros((1, 2, 2))
    total[0, 0, 1] = 5                     # 5 activations s0 -> e1 (on s1)
    meter.observe(total, res)
    assert meter.cross_server_bytes == pytest.approx(5 * 2 * 100.0)
    meter.observe(total, res)              # no new traffic
    assert meter.cross_server_bytes == pytest.approx(5 * 2 * 100.0)
    total[0, 0, 1] = 8.0                   # +3
    meter.observe(total, res)
    assert meter.cross_server_bytes == pytest.approx(8 * 2 * 100.0)
    assert meter.rounds == 3


# ---------------------------------------------------------------------------
# Link-aware cost model + transfer scheduling
# ---------------------------------------------------------------------------

def test_meter_seed_excludes_preexisting_history():
    topo = Topology.uniform(2, bandwidth=1e7, rtt=1e-3)
    res = np.ones((1, 2, 2))
    res[0, 0, 1] = 0.0                     # e1 remote for s0
    history = np.zeros((1, 2, 2))
    history[0, 0, 1] = 100.0               # traffic from before the meter
    meter = TrafficMeter(topo, hidden_bytes=10.0)
    meter.seed(history)
    meter.observe(history, res)            # nothing new since the seed
    assert meter.cross_server_bytes == 0.0
    history[0, 0, 1] = 103.0               # +3 real activations
    meter.observe(history, res)
    assert meter.cross_server_bytes == pytest.approx(3 * 2 * 10.0)


def test_round_trip_prices_each_leg_on_its_own_link():
    bw = np.array([[1.0, 1e6], [1e3, 1.0]])     # slow 1 KB/s return leg
    lat = np.zeros((2, 2))
    topo = Topology((ServerProfile("a"), ServerProfile("b")), bw, lat)
    rt = topo.round_trip_seconds(1e3)
    # 0 -> 1 forward at 1 MB/s (1 ms) + 1 -> 0 return at 1 KB/s (1 s)
    assert rt[0, 1] == pytest.approx(1e3 / 1e6 + 1e3 / 1e3)
    assert rt[0, 1] == rt[1, 0]                 # a round trip is symmetric
    cm = CommCostModel(topology=topo, expert_bytes=1e6,
                       activation_bytes=1e3)
    inv = cm.invocation_seconds()
    assert inv[0, 1] == pytest.approx(rt[0, 1])
    # one-way bulk transfers keep per-direction costs
    assert topo.link_seconds(1e3)[0, 1] != topo.link_seconds(1e3)[1, 0]


def test_attach_topology_rejects_conflicting_link_models():
    t1 = Topology.uniform(2)
    t2 = Topology.uniform(2)
    ctrl = PlacementController(policy=get_policy("uniform"), topology=t1)
    assert ctrl.attach_topology(None) is t1        # hand back the attached
    assert ctrl.attach_topology(t1) is t1          # same object: fine
    with pytest.raises(ValueError):
        ctrl.attach_topology(t2)                   # divergent link models


def test_forced_review_cannot_drop_inflight_migration():
    L, N, E = 2, 3, 8
    topo = wan_topology(N)
    cap = np.array([8, 8, 4])
    slots = np.minimum(cap // L + 1, E)
    ctrl = PlacementController(
        policy=lambda f: dancemoe_placement(f, cap, slots), cost=None,
        interval=10.0, topology=topo, expert_bytes=20e6)
    ctrl.review(0.0, skewed_freqs(L, N, E, 1))
    dec = ctrl.review(20.0, skewed_freqs(L, N, E, 9))
    assert dec.staged
    pending = ctrl.pending
    forced = ctrl.review(21.0, skewed_freqs(L, N, E, 5), force=True)
    assert not forced.adopted
    assert forced.diag["reason"] == "migration-in-flight"
    assert ctrl.pending is pending                 # M1 still in flight
    assert ctrl.poll(pending.eta) is pending       # and still completes


def test_comm_cost_zero_when_fully_local():
    L, N, E = 2, 2, 4
    freqs = skewed_freqs(L, N, E)
    full = PlacementPlan(
        assign=[[list(range(E)) for _ in range(N)] for _ in range(L)],
        counts=np.full((L, N), E), num_experts=E)
    cm = CommCostModel(topology=Topology.uniform(N), expert_bytes=1e6,
                       activation_bytes=1024)
    assert cm.comm_cost_seconds(full, freqs) == 0.0


def test_comm_cost_prices_the_actual_link():
    # e0 only on server 1 (cheap link from 0), e1 only on server 2 (slow
    # WAN link from 0): the same remote *fraction* must cost more when it
    # rides the slow link
    L, N, E = 1, 3, 2
    topo = wan_topology(3)
    plan_cheap = PlacementPlan(assign=[[[], [0, 1], [1]]],
                               counts=np.array([[0, 2, 1]]), num_experts=E)
    plan_wan = PlacementPlan(assign=[[[], [1], [0, 1]]],
                             counts=np.array([[0, 1, 2]]), num_experts=E)
    freqs = np.zeros((L, N, E))
    freqs[0, 0, 0] = 1.0                   # all of s0's traffic wants e0
    cm = CommCostModel(topology=topo, expert_bytes=1e6,
                       activation_bytes=4096)
    assert cm.comm_cost_seconds(plan_wan, freqs) > \
        2 * cm.comm_cost_seconds(plan_cheap, freqs)


def test_transfers_serialize_per_link_and_parallel_across_links():
    topo = Topology.uniform(3, bandwidth=1e6, rtt=0.0)   # 1 MB/s links
    old = PlacementPlan(assign=[[[0, 1], [2], [3]]],
                        counts=np.array([[2, 1, 1]]), num_experts=4)
    # server 2 gains experts 0 and 1 (both from server 0: one link,
    # serialized); server 1 gains expert 3 (different link: parallel)
    new = PlacementPlan(assign=[[[0, 1], [2, 3], [3, 0, 1]]],
                        counts=np.array([[2, 2, 3]]), num_experts=4)
    tasks = plan_transfers(old, new, topo, expert_bytes=1e6)
    finish = schedule_transfers(tasks, topo)
    by_dst = {}
    for t in tasks:
        by_dst.setdefault(t.dst, []).append(t)
    (a, b), (c,) = by_dst[2], by_dst[1]
    assert a.src == b.src == 0 and c.src == 2   # 3's only holder is s2
    # the (0 -> 2) link carries two 1 s transfers back to back
    assert {round(a.start, 6), round(b.start, 6)} == {0.0, 1.0}
    assert finish == pytest.approx(2.0)
    # the (2 -> 1) transfer overlapped the first (0 -> 2) one
    assert c.start == 0.0 and c.end == pytest.approx(1.0)


def test_transfer_source_is_cheapest_holder_and_local_load_fallback():
    topo = wan_topology(3)
    # expert 0 held by servers 0 and 2; server 1 should fetch it from 0
    # (LAN) not 2 (WAN). Expert 3 resident nowhere -> local IO load.
    old = PlacementPlan(assign=[[[0], [1], [0, 2]]],
                        counts=np.array([[1, 1, 2]]), num_experts=4)
    new = PlacementPlan(assign=[[[0], [1, 0, 3], [0, 2]]],
                        counts=np.array([[1, 3, 2]]), num_experts=4)
    tasks = {t.expert: t for t in plan_transfers(old, new, topo, 1e6)}
    assert tasks[0].src == 0 and tasks[0].dst == 1
    assert tasks[3].src == tasks[3].dst == 1     # nowhere resident
    schedule_transfers(list(tasks.values()), topo)
    io = topo.profiles[1].io_speed
    assert tasks[3].end - tasks[3].start == pytest.approx(1e6 / io)


def test_migration_seconds_matches_schedule_makespan():
    topo = wan_topology(3)
    freqs = skewed_freqs(2, 3, 8, seed=3)
    cap = np.array([10, 10, 6])
    slots = np.array([5, 5, 3])
    old = uniform_plan(2, 3, 8)
    new = dancemoe_placement(freqs, cap, slots)
    cm = CommCostModel(topology=topo, expert_bytes=5e6,
                       activation_bytes=1024)
    tasks = plan_transfers(old, new, topo, 5e6)
    assert cm.migration_seconds(old, new) == pytest.approx(
        schedule_transfers(tasks, topo))
    assert cm.migration_seconds(old, old) == 0.0


# ---------------------------------------------------------------------------
# Staged migration: event ordering + adoption only after transfers finish
# ---------------------------------------------------------------------------

def _staged_controller(topo, cap, slots, interval=100.0):
    return PlacementController(
        policy=lambda f: dancemoe_placement(f, cap, slots),
        cost=CommCostModel(topology=topo, expert_bytes=20e6,
                           activation_bytes=8192, tokens_per_horizon=1e5),
        interval=interval, topology=topo)


def test_plan_adopts_only_after_transfers_finish():
    L, N, E = 4, 3, 8
    topo = wan_topology(N)
    cap = np.array([14, 16, 8])
    slots = np.minimum(cap // L + 2, E)
    ctrl = _staged_controller(topo, cap, slots)
    f1, f2 = skewed_freqs(L, N, E, 1), skewed_freqs(L, N, E, 9)
    assert ctrl.review(0.0, f1).adopted          # initial: instant
    incumbent = ctrl.plan
    dec = ctrl.review(200.0, f2)
    assert dec.adopted and dec.staged
    assert ctrl.plan is incumbent                # not switched yet
    assert ctrl.pending is not None
    eta = ctrl.pending.eta
    assert eta > 200.0
    assert not ctrl.review_due(1e9)              # reviews pause in flight
    assert ctrl.poll(eta - 1e-9) is None
    assert ctrl.plan is incumbent
    comp = ctrl.poll(eta)
    assert comp is not None and ctrl.plan is comp.plan is not incumbent
    assert ctrl.pending is None
    # event order: staged adoption strictly before migration-complete
    kinds = [(e.get("staged", False),
              e.get("reason") == "migration-complete", e["time"])
             for e in ctrl.events]
    i_start = next(i for i, k in enumerate(kinds) if k[0])
    i_done = next(i for i, k in enumerate(kinds) if k[1])
    assert i_start < i_done
    assert kinds[i_start][2] < kinds[i_done][2]
    assert len(ctrl.migrations) == 1             # counted once, not twice


def test_no_transfers_needed_adopts_instantly():
    topo = Topology.uniform(2)
    plan = uniform_plan(2, 2, 4)
    ctrl = PlacementController(policy=lambda f: plan, cost=None,
                               interval=10.0, topology=topo,
                               expert_bytes=1e6)
    ctrl.review(0.0, skewed_freqs(2, 2, 4))
    dec = ctrl.review(20.0, skewed_freqs(2, 2, 4))   # same plan again
    assert dec.adopted and not dec.staged and ctrl.pending is None


# ---------------------------------------------------------------------------
# Determinism across reruns, both EdgeCluster backends
# ---------------------------------------------------------------------------

def _sim_cluster_run(seed=0):
    pf = MoEProfile(num_layers=4, num_experts=8, top_k=2,
                    d_model=256, d_ff=512)
    topo = Topology(
        (ServerProfile("a", mem_bytes=24 * pf.expert_bytes),
         ServerProfile("b", mem_bytes=24 * pf.expert_bytes),
         ServerProfile("c", mem_bytes=12 * pf.expert_bytes)),
        *_wan_links(3))
    ctrl = PlacementController(
        policy=get_policy("dancemoe"), cost=None,
        cluster=ClusterView.from_topology(topo, pf),
        interval=15.0, topology=topo)
    ec = EdgeCluster("sim", topology=topo, profile=pf, controller=ctrl,
                     seed=seed)
    rng = np.random.default_rng(7)
    t = 0.0
    for k in range(30):
        t += float(rng.exponential(2.0))
        o = k % 3
        task = f"t{o}" if k < 15 else f"shift{o}"   # mid-stream task shift
        ec.submit(Request(prompt=np.zeros(64, np.int32), max_new_tokens=8,
                          origin=o, arrival=t, task=task))
    ec.run()
    timeline = [(e.type, e.time, e.data.get("eta"),
                 e.data.get("transfer_seconds")) for e in ec.events]
    return timeline, ec.metrics()


def _wan_links(n):
    bw = np.full((n, n), 64e6)
    lat = np.full((n, n), 2e-3)
    bw[:, n - 1] = bw[n - 1, :] = 8e6
    np.fill_diagonal(lat, 0.0)
    return bw, lat


def test_sim_backend_staged_migrations_deterministic_across_reruns():
    t1, m1 = _sim_cluster_run()
    t2, m2 = _sim_cluster_run()
    assert t1, "run produced no migration events (test needs >= 1)"
    assert t1 == t2
    assert any(k[0] == EventType.MIGRATION_COMPLETED for k in t1)
    # ordering: every completion follows its start on the seconds clock
    starts = [e for e in t1 if e[0] == EventType.MIGRATION_STARTED]
    dones = [e for e in t1 if e[0] == EventType.MIGRATION_COMPLETED]
    for s, d in zip(starts, dones):
        assert s[1] < d[1]
        assert d[3] > 0                     # modeled transfer seconds
    np.testing.assert_allclose(
        m1["net"]["link_bytes"], m2["net"]["link_bytes"])


def test_per_server_kv_pools_sized_by_profile():
    """``shared_runtime=False`` + topology: each server's paged pool is
    bounded by its own ``ServerProfile.kv_mem_bytes`` — the memory-poor
    box gets the smaller block budget."""
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import transformer as tr
    from repro.serving.engine import ServingEngine

    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_test_mesh(1, 1)
    rt = tr.Runtime(cfg=cfg, mesh=mesh, moe_impl="dense")
    eng = ServingEngine(rt=rt, params=tr.init_params(rt, jax.random.PRNGKey(0)),
                        placement=None, max_len=64)
    pos_bytes = 2.0 * cfg.num_layers * cfg.d_model * 4     # fp32
    block_bytes = 16 * pos_bytes
    topo = Topology.uniform((
        ServerProfile("big", kv_mem_bytes=64 * block_bytes),
        ServerProfile("mid", kv_mem_bytes=16 * block_bytes),
        ServerProfile("small", kv_mem_bytes=4 * block_bytes)))
    ec = EdgeCluster("runtime", engine=eng, n_servers=3,
                     shared_runtime=False, topology=topo,
                     runtime_opts=dict(max_slots=2, block_size=16))
    budgets = [r.allocator.capacity_blocks for r in ec.backend.runtimes]
    assert budgets == [64, 16, 4]


SCRIPTS = Path(__file__).parent / "md_scripts"


def test_runtime_backend_staged_migration_subprocess():
    """Runtime backend on 3 fake devices (one EP rank per server): staged
    migration events are ordered, reruns complete at identical ticks, and
    outputs stay token-identical to sequential generate() across the
    staged switch. Subprocess keeps the fake device count out of this
    process (the tier-1 convention, see test_multidevice)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    r = subprocess.run(
        [sys.executable, str(SCRIPTS / "staged_migration_runtime.py")],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, \
        f"staged_migration_runtime.py failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL OK" in r.stdout


# ---------------------------------------------------------------------------
# expert tiers: config validation, host-link pricing, TierManager mechanics
# ---------------------------------------------------------------------------

def test_tiered_profile_validation():
    """Tier capacities must nest (GPU <= host <= disk), tiers are either
    absent or positive, and a disk tier cannot float without a host tier
    — each misconfiguration raises with a message naming the field."""
    with pytest.raises(ValueError, match="zero-capacity tiers"):
        ServerProfile("z", mem_bytes=8e9, host_mem_bytes=0)
    with pytest.raises(ValueError, match="zero-capacity tiers"):
        ServerProfile("z2", mem_bytes=8e9, host_mem_bytes=16e9,
                      disk_mem_bytes=-1)
    with pytest.raises(ValueError, match="disk tier requires a host tier"):
        ServerProfile("d", mem_bytes=8e9, disk_mem_bytes=64e9)
    with pytest.raises(ValueError, match="must nest"):
        ServerProfile("n", mem_bytes=8e9, host_mem_bytes=4e9)
    with pytest.raises(ValueError, match="must nest"):
        ServerProfile("n2", mem_bytes=8e9, host_mem_bytes=16e9,
                      disk_mem_bytes=12e9)
    p = ServerProfile("ok", mem_bytes=8e9, host_mem_bytes=16e9,
                      disk_mem_bytes=32e9, host_bw=12e9, disk_bw=2e9)
    assert p.tiered
    assert p.tier_slots(1e9) == (8, 16, 32)      # cumulative (inclusive)
    assert p.tiered_expert_budget(1e9) == 32     # plans may use the deepest
    flat = ServerProfile("flat", mem_bytes=8e9)
    assert not flat.tiered
    assert flat.tier_slots(1e9) == (8, 8, 8)
    assert flat.tiered_expert_budget(1e9) == flat.expert_budget(1e9)


def test_topology_requires_tier_link_pricing():
    """A tiered profile without a priced host<->device (or disk<->host)
    link is rejected at Topology construction — the cost model cannot
    compare 'fetch from my host tier' vs 'invoke the remote replica'
    without it."""
    tiered = ServerProfile("t", mem_bytes=8e9, host_mem_bytes=16e9)
    with pytest.raises(ValueError, match="must price the host"):
        Topology.uniform((tiered, ServerProfile("f")))
    nodisk = ServerProfile("t", mem_bytes=8e9, host_mem_bytes=16e9,
                           disk_mem_bytes=32e9, host_bw=12e9)
    with pytest.raises(ValueError, match="disk tier must price"):
        Topology.uniform((nodisk, ServerProfile("f")))
    ok = ServerProfile("t", mem_bytes=8e9, host_mem_bytes=16e9,
                       host_bw=12e9)
    topo = Topology.uniform((ok, ServerProfile("f")))
    assert topo.tiered
    assert topo.host_fetch_seconds(0, 12e9) == pytest.approx(1.0)
    assert list(topo.tiered_expert_budgets(1e9)) == [16, 16]
    assert topo.tier_slot_capacities(1e9)[0].tolist() == [8, 16, 16]


def test_host_transfer_tasks_serialize_per_server():
    """``via="host"`` promotions ride the destination's host<->device
    link: two fetches on one server serialize, fetches on distinct
    servers proceed in parallel, and each is priced at
    nbytes / host_bw."""
    prof = ServerProfile("a", mem_bytes=8e9, host_mem_bytes=32e9,
                         host_bw=1e9)
    topo = Topology.uniform((prof, dataclasses.replace(prof, name="b")))
    t1 = TransferTask(0, 1, 0, 0, 1e9, via="host")
    t2 = TransferTask(0, 2, 0, 0, 1e9, via="host")
    t3 = TransferTask(0, 3, 1, 1, 1e9, via="host")
    makespan = schedule_transfers([t1, t2, t3], topo)
    assert t1.end == pytest.approx(1.0)
    assert t2.start == pytest.approx(1.0)      # same host link: serialized
    assert t2.end == pytest.approx(2.0)
    assert t3.end == pytest.approx(1.0)        # other server: parallel
    assert makespan == pytest.approx(2.0)


def test_slot_tables_priority_puts_gpu_tier_first():
    """With a tier table as ``priority``, slot truncation keeps the
    GPU-tier (hot) experts instead of the lowest expert ids."""
    plan = PlacementPlan(assign=[[[0, 1, 2, 3]]],
                         counts=np.array([[4]]), num_experts=4)
    assert plan.slot_tables(2)[0, 0].tolist() == [0, 1]
    prio = np.array([[[2, 0, 1, 2]]])          # e1 hottest, then e2
    assert plan.slot_tables(2, priority=prio)[0, 0].tolist() == [1, 2]


def test_tier_manager_bind_promote_drop():
    """TierManager end to end on one server: bind splits hottest-first
    under the per-layer GPU quota, observe books hits/fetches/stalls,
    prefetch_step promotes a strictly hotter back-tier expert over the
    host link, poll lands it (evicting the coldest GPU resident for
    free), and a crash wipes the server's tiers."""
    from repro.serving.tiers import TIER_GPU, TIER_HOST, TierManager

    prof = ServerProfile("t", mem_bytes=2e9, host_mem_bytes=4e9,
                         host_bw=1e9)
    topo = Topology.uniform((prof,))
    plan = PlacementPlan(assign=[[[0, 1, 2, 3]]],
                         counts=np.array([[4]]), num_experts=4)
    tm = TierManager(topology=topo, expert_bytes=1e9)
    tm.bind(plan)
    # no heat yet: expert id breaks ties — e0, e1 take the 2 GPU slots
    assert tm.tier[0, 0].tolist() == [TIER_GPU, TIER_GPU,
                                      TIER_HOST, TIER_HOST]
    counts = np.zeros((1, 1, 4))
    counts[0, 0] = [0.0, 1.0, 10.0, 0.0]
    tm.observe(counts)
    assert tm.gpu_hit_tokens == pytest.approx(1.0)       # e1, GPU-resident
    assert tm.fetch_tokens == pytest.approx(10.0)        # e2, host tier
    assert tm.on_demand_fetches == 1
    assert tm.on_demand_stall_seconds == pytest.approx(1.0)   # 1e9 / 1e9
    assert tm.fetch_stall_seconds(0, 0, 2) == pytest.approx(1.0)
    assert tm.fetch_stall_seconds(0, 0, 0) == 0.0

    tm.prefetch_step(now=0.0)       # e2 (heat 10) > e0 (heat 0): promote
    tm.poll(now=0.5)
    assert tm.promotions == 0       # fetch still in flight at t=0.5
    tm.poll(now=2.0)
    assert tm.promotions == 1
    assert tm.tier[0, 0].tolist() == [TIER_HOST, TIER_GPU,
                                      TIER_GPU, TIER_HOST]
    assert tm.fetch_stall_seconds(0, 0, 2) == 0.0
    s = tm.summary()
    assert s["per_server_gpu_resident"] == [2]
    assert s["per_server_host_resident"] == [2]
    assert s["prefetch_hit_ratio"] == pytest.approx(1.0 / 11.0, abs=1e-6)

    tm.drop_server(0)
    assert (tm.tier[0, 0] == -1).all()
    assert tm.summary()["per_server_gpu_resident"] == [0]


def test_prefetch_disabled_freezes_residency():
    """``prefetch=False``: heat still accumulates (for rebinds) but
    ``prefetch_step`` never schedules a promotion."""
    from repro.serving.tiers import TierManager

    prof = ServerProfile("t", mem_bytes=2e9, host_mem_bytes=4e9,
                         host_bw=1e9)
    topo = Topology.uniform((prof,))
    plan = PlacementPlan(assign=[[[0, 1, 2, 3]]],
                         counts=np.array([[4]]), num_experts=4)
    tm = TierManager(topology=topo, expert_bytes=1e9, prefetch=False)
    tm.bind(plan)
    counts = np.zeros((1, 1, 4))
    counts[0, 0] = [0.0, 1.0, 10.0, 0.0]
    tm.observe(counts)
    before = tm.tier.copy()
    tm.prefetch_step(now=0.0)
    tm.poll(now=100.0)
    assert tm.promotions == 0
    np.testing.assert_array_equal(tm.tier, before)


def test_runtime_backend_tiers_subprocess():
    """Runtime backend on 3 fake devices: the oversized-model tier
    overlay completes every request token-identically, the prefetcher
    promotes, and reruns are bit-identical (see
    md_scripts/tiers_runtime.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    r = subprocess.run(
        [sys.executable, str(SCRIPTS / "tiers_runtime.py")],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, \
        f"tiers_runtime.py failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL OK" in r.stdout

"""Migration policy (Eq. 3 / Eq. 4) tests."""
import numpy as np

from repro.core.migration import (CostModel, MigrationController,
                                  migration_time, should_migrate)
from repro.core.placement import dancemoe_placement
from repro.core.baselines import uniform_plan
from tests.test_placement import skewed_freqs


def _cost_model(io=1e9):
    return CostModel(expert_bytes=50e6, activation_bytes=8192,
                     bandwidth=62.5e6, io_speed=io,
                     tokens_per_horizon=1e4)


def test_migration_time_counts_added_experts():
    L, N, E = 2, 2, 4
    old = uniform_plan(L, N, E)
    new = uniform_plan(L, N, E)
    cm = _cost_model(io=1e8)
    assert migration_time(old, old, cm) == 0.0
    # force a difference: swap expert sets of server 0/1 in layer 0
    new.assign[0][0], new.assign[0][1] = list(new.assign[0][1]), \
        list(new.assign[0][0])
    t = migration_time(old, new, cm)
    assert t == (2 + 2) * 50e6 / 1e8           # 4 newly-placed experts


def test_eq4_adopts_only_when_beneficial():
    L, N, E = 4, 3, 8
    freqs = skewed_freqs(L, N, E, seed=1)
    cap = np.array([14, 16, 20])
    slots = np.minimum(cap // L + 2, E)
    good = dancemoe_placement(freqs, cap, slots)
    bad = uniform_plan(L, N, E)
    cm = _cost_model()
    adopt, diag = should_migrate(bad, good, freqs, cm)
    assert adopt and diag["gain"] > 0          # big win: adopt
    adopt_back, diag2 = should_migrate(good, bad, freqs, cm)
    assert not adopt_back                      # regression: reject


def test_eq4_rejects_when_migration_too_expensive():
    L, N, E = 4, 3, 8
    freqs = skewed_freqs(L, N, E, seed=1)
    cap = np.array([14, 16, 20])
    slots = np.minimum(cap // L + 2, E)
    good = dancemoe_placement(freqs, cap, slots)
    bad = uniform_plan(L, N, E)
    slow_io = _cost_model(io=1e3)              # pathologically slow loads
    adopt, _ = should_migrate(bad, good, freqs, slow_io)
    assert not adopt


def test_controller_interval_and_shift():
    L, N, E = 4, 3, 8
    f1 = skewed_freqs(L, N, E, seed=1)
    f2 = skewed_freqs(L, N, E, seed=9)         # shifted workload
    cap = np.array([14, 16, 20])
    slots = np.minimum(cap // L + 2, E)
    ctrl = MigrationController(
        placement_fn=lambda f: dancemoe_placement(f, cap, slots),
        cost=_cost_model(), interval=300.0)
    plan0, adopted0 = ctrl.maybe_migrate(0.0, f1)
    assert adopted0                            # initial placement
    _, a = ctrl.maybe_migrate(100.0, f2)
    assert not a                               # within interval: no review
    plan2, a2 = ctrl.maybe_migrate(400.0, f2)
    assert a2                                  # workload shift -> migrate
    assert plan2 is not plan0

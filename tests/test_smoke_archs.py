"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one train step + prefill + decode on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.dryrun import ASSIGNED_ARCHS
from repro.models import transformer as tr
from repro.optim.adamw import adamw
from repro.training.train_loop import make_train_step

B, T = 2, 16


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _runtime(name):
    cfg = get_config(name).reduced()
    return tr.Runtime(cfg=cfg), cfg


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_train_step(name, rng):
    rt, cfg = _runtime(name)
    params = tr.init_params(rt, rng)
    toks = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    step = make_train_step(rt, adamw(lr=1e-3))
    opt_state = adamw(lr=1e-3).init(params)
    params2, _, metrics = jax.jit(step)(params, opt_state, toks,
                                        jnp.roll(toks, -1, 1))
    assert jnp.isfinite(metrics["loss"]), name
    # params actually changed
    delta = sum(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0, name


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_prefill_decode(name, rng):
    rt, cfg = _runtime(name)
    params = tr.init_params(rt, rng)
    if cfg.frontend != "none":
        # modality stub: the backbone consumes precomputed embeddings
        embeds = jax.random.normal(rng, (B, T, cfg.d_model)) * 0.02
        logits, cache, _ = tr.prefill(rt, params, embeds=embeds,
                                      cache_len=T + 4)
    else:
        toks = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
        logits, cache, _ = tr.prefill(rt, params, tokens=toks,
                                      cache_len=T + 4)
    assert logits.shape == (B, cfg.vocab_size), name
    assert not bool(jnp.isnan(logits).any()), name
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2, _ = tr.decode_step(rt, params, cache, nxt, jnp.int32(T))
    assert logits2.shape == (B, cfg.vocab_size), name
    assert not bool(jnp.isnan(logits2).any()), name
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "zamba2-2.7b",
                                  "falcon-mamba-7b", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_prefill(name, rng):
    """Incremental decode == one-shot forward at the last position."""
    rt, cfg = _runtime(name)
    params = tr.init_params(rt, rng)
    toks = jax.random.randint(rng, (B, T + 1), 0, cfg.vocab_size)
    full, _, _ = tr.prefill(rt, params, tokens=toks)
    part, cache, _ = tr.prefill(rt, params, tokens=toks[:, :T],
                                cache_len=T + 4)
    inc, _, _ = tr.decode_step(rt, params, cache, toks[:, T:T + 1],
                               jnp.int32(T))
    assert float(jnp.max(jnp.abs(full - inc))) < 5e-4, name

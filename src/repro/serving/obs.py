"""Unified observability: span tracer, metrics registry, Chrome-trace
export (``repro.serving.obs``).

Every subsystem grown so far — runtime, cluster, net, tiers, faults,
workload — exposes its own ad-hoc ``metrics()`` dict, but none of them
can answer *where one request's time went* (queue wait vs prefill vs
decode vs cold-expert stalls) or *why the controller adopted a plan*
(the Eq.-4 trade of ``C(P') + T_mig`` against ``C(P)``). This module
adds the missing layer, in three parts:

* :class:`Tracer` — a span recorder on the owning backend's **model
  clock** (scheduler ticks for the runtime backend, modeled seconds for
  the simulator). Emission sites guard on ``tracer.enabled``, so a
  disabled tracer allocates nothing on the hot path; :data:`NULL_TRACER`
  is the shared always-off instance every subsystem defaults to. The
  span vocabulary (:class:`SpanKind`): per-request ``QUEUE_WAIT`` /
  ``PREFILL_CHUNK`` / ``DECODE_ROUND`` / ``PREFIX_HIT`` / ``SHED`` /
  ``FAILOVER_REPREFILL`` / ``COLD_FETCH_STALL``, and control-plane
  ``PLACEMENT_REVIEW`` (the full decision diag, Eq.-4 numbers included)
  / ``TRANSFER_TASK`` (per-link staged-migration transfers) / ``FAULT``
  / ``PREFETCH`` (tier promotions).

  **Determinism contract.** Span records carry model-clock times and a
  monotonic sequence number only — never the wall clock — so a traced
  rerun of a ``FaultSchedule`` scenario exports byte-identical JSON.
  Wall time appears exactly once, as the aggregate ``overhead_ms`` the
  ``obs`` metrics namespace reports (the analogue of the document's
  ``elapsed_s``, equally replay-exempt). The runtime backend records
  launch-side metadata only (tick, batch rows — host-known at launch),
  and completion data rides the existing async drain backlog, so
  tracing adds **zero host syncs** to the warmed zero-stall loop.

* :class:`Registry` — metric primitives (:class:`Counter`,
  :class:`Gauge`, :class:`Histogram`) plus namespaced **providers**:
  each subsystem registers a callable producing its section
  (``per_server``, ``perf``, ``net``, ``tiers``, ``faults``, ``obs``)
  and :meth:`Registry.collect` assembles the one namespaced tree that
  ``EdgeCluster.metrics()`` used to hand-merge from six call sites.
  :func:`snapshot_diff` turns two collected trees into a windowed
  reading (the registry-level analogue of ``TrafficMeter``'s
  cumulative-counts diff).

* :meth:`Tracer.export` — Chrome trace-event JSON (the format Perfetto
  and ``chrome://tracing`` load): one track per server plus a
  control-plane track, complete ("X") events in microseconds (1 tick
  renders as 1 ms), sorted keys and a stable event order — byte-stable
  across reruns. ``tools/trace_view.py`` prints the textual per-phase
  latency breakdown of an exported file.

This module is dependency-light (numpy only), like ``api.py``: both
execution worlds import it, never the other way around.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np


class SpanKind:
    """Span vocabulary (plain strings, mirroring ``api.EventType``)."""

    # per-request phases (rid >= 0)
    QUEUE_WAIT = "QUEUE_WAIT"  # submit/enqueue -> admission (or shed)
    PREFILL_CHUNK = "PREFILL_CHUNK"  # one batched chunk call (runtime
    #   backend; rid = -1, args.rows requests rode it) / the modeled
    #   prefill phase of one request (sim backend; rid >= 0)
    DECODE_ROUND = "DECODE_ROUND"  # one decode round (runtime backend;
    #   rid = -1, batch-level) / the modeled decode phase (sim; rid >= 0)
    PREFIX_HIT = "PREFIX_HIT"  # instant: admission reused cached pages
    SHED = "SHED"  # instant: SLO-aware admission dropped the request
    FAILOVER_REPREFILL = "FAILOVER_REPREFILL"  # instant: crash victim
    #   re-enqueued on a surviving server (re-prefills from scratch)
    COLD_FETCH_STALL = "COLD_FETCH_STALL"  # a back-tier expert was
    #   invoked before any prefetch landed it (modeled stall span)

    # control-plane / system spans (rid = -1)
    PLACEMENT_REVIEW = "PLACEMENT_REVIEW"  # instant: one controller
    #   decision record (adopt/reject reason + Eq.-4 cost breakdown)
    TRANSFER_TASK = "TRANSFER_TASK"  # one staged-migration transfer
    #   occupying one link (span = its slice of the schedule)
    FAULT = "FAULT"  # instant: one consumed FaultEvent
    PREFETCH = "PREFETCH"  # one tier promotion fetch that landed

    REQUEST = (QUEUE_WAIT, PREFILL_CHUNK, DECODE_ROUND, PREFIX_HIT, SHED,
               FAILOVER_REPREFILL, COLD_FETCH_STALL)
    SYSTEM = (PLACEMENT_REVIEW, TRANSFER_TASK, FAULT, PREFETCH)
    ALL = REQUEST + SYSTEM


_REQUEST_KINDS = frozenset(SpanKind.REQUEST)


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded span on the tracer's model clock.

    ``start == end`` marks an instant event. ``rid`` is -1 for system
    and batch-level spans; ``server`` is -1 for cluster-wide ones (the
    control-plane track). ``seq`` is the tracer-assigned monotonic
    emission index — the rerun-stable total order within equal times.
    """

    kind: str
    start: float
    end: float
    rid: int = -1
    server: int = -1
    seq: int = -1
    args: dict | None = None

    @property
    def duration(self) -> float:
        return max(self.end - self.start, 0.0)


def _jsonable(v):
    """Coerce a span-args value into plain JSON types (numpy scalars and
    arrays appear in controller diags and fault payloads)."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    return str(v)


class Tracer:
    """Deterministic dual-clock span recorder.

    clock:      the model clock spans are stamped with — ``"ticks"``
                (runtime backend: scheduler ticks) or ``"seconds"``
                (sim backend: modeled seconds). Export renders one tick
                as one millisecond.
    max_events: hard cap on retained spans; further emissions are
                counted in ``dropped`` instead of growing without bound
                (the bench gate asserts ``dropped_events == 0``).

    The wall clock is deliberately absent from span records (reruns
    must export byte-identical traces); it is metered only into
    ``overhead_s`` — the cumulative wall cost of recording itself.
    """

    enabled = True

    def __init__(self, clock: str = "ticks", max_events: int = 1_000_000):
        if clock not in ("ticks", "seconds"):
            raise ValueError(
                f"clock must be 'ticks' or 'seconds', got {clock!r}")
        self.clock = clock
        self.max_events = int(max_events)
        self.spans: list[Span] = []
        self.dropped = 0
        self.overhead_s = 0.0
        self._counts: dict[str, int] = {}
        self._seq = 0

    # -- recording -----------------------------------------------------
    def span(self, kind: str, start: float, end: float, rid: int = -1,
             server: int = -1, **args) -> Span | None:
        """Record one completed span (emission sites know both endpoints
        on the model clock by the time they emit). Returns the record,
        or None when the ``max_events`` cap dropped it."""
        t0 = time.perf_counter()
        if len(self.spans) >= self.max_events:
            self.dropped += 1
            self.overhead_s += time.perf_counter() - t0
            return None
        sp = Span(kind, float(start), float(end), int(rid), int(server),
                  self._seq, args or None)
        self._seq += 1
        self.spans.append(sp)
        self._counts[kind] = self._counts.get(kind, 0) + 1
        self.overhead_s += time.perf_counter() - t0
        return sp

    def instant(self, kind: str, t: float, rid: int = -1, server: int = -1,
                **args) -> Span | None:
        """Record a zero-duration event."""
        return self.span(kind, t, t, rid=rid, server=server, **args)

    # -- reading -------------------------------------------------------
    def by_kind(self, kind: str) -> list[Span]:
        return [s for s in self.spans if s.kind == kind]

    def request_spans(self, rid: int) -> list[Span]:
        """One request's spans, in emission order."""
        return [s for s in self.spans if s.rid == rid]

    def summary(self) -> dict:
        """The ``metrics.obs`` section of ``bench-serving/v8``: span
        counts by kind, the drop counter (gated == 0) and the tracer's
        wall-clock recording overhead."""
        return {
            "enabled": int(self.enabled),
            "clock": self.clock,
            "events": len(self.spans),
            "dropped_events": int(self.dropped),
            "overhead_ms": round(self.overhead_s * 1e3, 6),
            "span_counts": {k: self._counts[k] for k in sorted(self._counts)},
        }

    # -- Chrome-trace / Perfetto export --------------------------------
    def to_trace_doc(self) -> dict:
        """The trace as a Chrome trace-event document (one dict per
        event; load the exported file at https://ui.perfetto.dev or
        ``chrome://tracing``). Tracks: ``tid = server + 1`` per server,
        ``tid 0`` = the control plane (and any span without a server).
        Times are microseconds; the tick clock renders 1 tick = 1 ms so
        a decode round is a legible 1 ms block. Field values are plain
        JSON and the event order is (ts, seq) — deterministic, so two
        runs of the same ``FaultSchedule`` scenario serialize to
        identical bytes."""
        scale = 1e3 if self.clock == "ticks" else 1e6
        events = []
        tids = set()
        for sp in self.spans:
            tid = sp.server + 1
            tids.add(tid)
            args = {"rid": sp.rid, "seq": sp.seq}
            if sp.args:
                args.update(_jsonable(sp.args))
            events.append({
                "ph": "X",
                "name": sp.kind,
                "cat": "request" if sp.kind in _REQUEST_KINDS else "system",
                "pid": 0,
                "tid": tid,
                "ts": round(sp.start * scale, 3),
                "dur": round(sp.duration * scale, 3),
                "args": args,
            })
        events.sort(key=lambda e: (e["ts"], e["args"]["seq"]))
        meta = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "repro-serving"},
        }]
        for tid in sorted(tids):
            meta.append({
                "ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                "args": {"name": ("control-plane" if tid == 0
                                  else f"server{tid - 1}")},
            })
        return {
            "displayTimeUnit": "ms",
            "otherData": {"clock": self.clock, "spans": len(self.spans),
                          "dropped": int(self.dropped)},
            "traceEvents": meta + events,
        }

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` (sorted keys +
        trailing newline: the byte-stable form the determinism tests and
        the CI artifact gate compare). Returns ``path``."""
        doc = self.to_trace_doc()
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True, indent=1)
            f.write("\n")
        return path


class _NullTracer(Tracer):
    """The shared always-off tracer: every record call is a no-op and
    ``enabled`` is False, so hot paths guarded on it skip argument
    construction entirely (zero allocation when disabled)."""

    enabled = False

    def __init__(self):
        super().__init__(clock="ticks", max_events=0)

    def span(self, kind, start, end, rid=-1, server=-1, **args):
        return None

    def instant(self, kind, t, rid=-1, server=-1, **args):
        return None

    def export(self, path: str) -> str:
        raise RuntimeError(
            "tracing is disabled: construct the runtime/cluster with a "
            "Tracer (e.g. EdgeCluster(..., trace=True)) before exporting")


NULL_TRACER = _NullTracer()


def as_tracer(trace, clock: str) -> Tracer:
    """Normalize the ``trace=`` knob: a Tracer instance is used as-is
    (its clock must match the backend's), truthy builds one on the
    backend's clock, falsy is :data:`NULL_TRACER`."""
    if isinstance(trace, Tracer):
        if trace.enabled and trace.clock != clock:
            raise ValueError(
                f"tracer records the {trace.clock!r} clock but this "
                f"backend runs on {clock!r}")
        return trace
    return Tracer(clock=clock) if trace else NULL_TRACER


# ---------------------------------------------------------------------------
# Metric primitives + the namespaced registry
# ---------------------------------------------------------------------------

class Counter:
    """A monotonic counter."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value


class Gauge:
    """A last-value-wins scalar."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """A bounded value distribution: a deterministic systematic 1-in-2^k
    subsample (no RNG — replays stay bit-identical), the same scheme the
    runtime's latency reservoirs use. ``count`` is the total number of
    observations, not the retained sample size."""

    def __init__(self, max_items: int = 4096):
        self.max_items = int(max_items)
        self.count = 0
        self._stride = 1
        self._items: list[float] = []

    def observe(self, x: float) -> None:
        if self.count % self._stride == 0:
            self._items.append(float(x))
            if len(self._items) >= self.max_items:
                self._items = self._items[::2]
                self._stride *= 2
        self.count += 1

    def __iter__(self):
        return iter(self._items)

    def percentiles(self, qs=(50, 99)) -> dict:
        if not self._items:
            return {f"p{int(q)}": 0.0 for q in qs}
        return {f"p{int(q)}": float(np.percentile(self._items, q))
                for q in qs}


class Registry:
    """Namespaced metrics tree assembled from per-subsystem providers.

    Each subsystem registers a zero-argument callable producing its
    section dict (or ``None`` to omit it this collection — e.g. no
    fault schedule attached). :meth:`collect` calls them in
    registration order, so the assembled tree is deterministic and
    always reflects live state — the pattern ``EdgeCluster.metrics()``
    is rebuilt on.
    """

    def __init__(self):
        self._providers: dict = {}

    def register(self, namespace: str, provider) -> None:
        """Register (or replace) the provider for ``namespace``."""
        if not callable(provider):
            raise TypeError(
                f"provider for {namespace!r} must be callable, got "
                f"{provider!r}")
        self._providers[namespace] = provider

    @property
    def namespaces(self) -> tuple:
        return tuple(self._providers)

    def collect(self) -> dict:
        """One namespaced tree: ``{namespace: provider()}`` in
        registration order, omitting providers that returned None."""
        out = {}
        for ns, provider in self._providers.items():
            v = provider()
            if v is not None:
                out[ns] = v
        return out


def snapshot_diff(before: dict, after: dict) -> dict:
    """Windowed reading of two collected trees: numeric leaves become
    ``after - before``, non-numeric and newly-appeared leaves pass
    through from ``after``. Both inputs are left untouched."""
    out = {}
    for k, v in after.items():
        prev = before.get(k)
        if isinstance(v, dict) and isinstance(prev, dict):
            out[k] = snapshot_diff(prev, v)
        elif (isinstance(v, (int, float)) and not isinstance(v, bool)
                and isinstance(prev, (int, float))
                and not isinstance(prev, bool)):
            out[k] = v - prev
        else:
            out[k] = v
    return out

"""Deterministic fault injection for the cluster backends.

Edge deployments churn: servers crash and rejoin, links sag under
cross-traffic. The cluster backends consume a :class:`FaultSchedule` — a
fixed list of timed :class:`FaultEvent`s — from their own clock (scheduler
ticks for the runtime backend, seconds for the simulator), so a fault run
is exactly as reproducible as a fault-free one: no RNG, no wall clock,
and two runs of the same schedule produce bit-identical event timelines.

The schedule only *describes* faults. Applying one mutates the shared
:class:`~repro.serving.net.Topology`'s :class:`~repro.serving.net.LinkState`
(:func:`apply_fault`); the failover response — re-routing in-flight
requests off a dead server, force-reviewing placement around the lost
capacity, aborting in-flight migrations whose source died — lives in the
backends and the :class:`~repro.core.policies.PlacementController`.

Event kinds (mirrored as ``EventType.SERVER_DOWN`` etc. in the serving
API so cluster consumers see one event vocabulary):

* ``SERVER_DOWN(server)``    — the server vanishes: capacity, resident
  experts, KV pages and in-flight work are lost.
* ``SERVER_JOINED(server)``  — the server (re)joins empty; placement may
  expand onto it at the next review.
* ``LINK_DEGRADED(src, dst, factor)`` — the src->dst link's bandwidth is
  multiplied by ``factor`` (0 < factor < 1).
* ``LINK_RESTORED(src, dst)`` — the link returns to its profiled
  bandwidth.
"""

from __future__ import annotations

import dataclasses

SERVER_DOWN = "SERVER_DOWN"
SERVER_JOINED = "SERVER_JOINED"
LINK_DEGRADED = "LINK_DEGRADED"
LINK_RESTORED = "LINK_RESTORED"

KINDS = (SERVER_DOWN, SERVER_JOINED, LINK_DEGRADED, LINK_RESTORED)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed fault. ``time`` is in the consuming backend's clock
    (ticks or seconds). Server events use ``server``; link events use
    ``src``/``dst`` (+ ``factor`` for degradation)."""

    time: float
    kind: str
    server: int | None = None
    src: int | None = None
    dst: int | None = None
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {KINDS}"
            )
        if self.time < 0:
            raise ValueError(f"fault time must be >= 0 (got {self.time})")
        if self.kind in (SERVER_DOWN, SERVER_JOINED):
            if self.server is None or self.server < 0:
                raise ValueError(f"{self.kind} requires server >= 0")
        else:
            if (
                self.src is None
                or self.dst is None
                or self.src < 0
                or self.dst < 0
                or self.src == self.dst
            ):
                raise ValueError(f"{self.kind} requires distinct src/dst >= 0")
        if self.kind == LINK_DEGRADED and not (0.0 < self.factor < 1.0):
            raise ValueError(
                f"LINK_DEGRADED factor must be in (0, 1), got {self.factor}"
            )

    def payload(self) -> dict:
        """JSON-able event payload (for cluster Event records)."""
        out = {"kind": self.kind, "time": self.time}
        if self.server is not None:
            out["server"] = self.server
        if self.src is not None:
            out["src"] = self.src
            out["dst"] = self.dst
        if self.kind == LINK_DEGRADED:
            out["factor"] = self.factor
        return out


class FaultSchedule:
    """An ordered, replayable fault timeline.

    Events are consumed in (time, insertion-order) order via :meth:`due`
    as the owning backend's clock advances. ``reset()`` rewinds for a
    bit-identical rerun; the event list itself is never mutated.
    """

    def __init__(self, events=()):
        evs = list(events)
        for ev in evs:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"not a FaultEvent: {ev!r}")
        # stable sort: same-time events keep insertion order
        self.events: tuple[FaultEvent, ...] = tuple(sorted(evs, key=lambda e: e.time))
        self._next = 0

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @property
    def remaining(self) -> int:
        return len(self.events) - self._next

    def due(self, now: float) -> list[FaultEvent]:
        """Pop every event with ``time <= now``, in schedule order."""
        out = []
        while self._next < len(self.events) and self.events[self._next].time <= now:
            out.append(self.events[self._next])
            self._next += 1
        return out

    def peek(self) -> FaultEvent | None:
        """Next un-consumed event (None when exhausted)."""
        if self._next < len(self.events):
            return self.events[self._next]
        return None

    def reset(self) -> "FaultSchedule":
        self._next = 0
        return self

    def copy(self) -> "FaultSchedule":
        """Fresh un-consumed schedule over the same events."""
        return FaultSchedule(self.events)

    # -- convenience constructors -------------------------------------
    @staticmethod
    def server_crash(
        time: float, server: int, rejoin_at: float | None = None
    ) -> "FaultSchedule":
        """One server dies at ``time`` (and optionally rejoins later)."""
        events = [FaultEvent(time, SERVER_DOWN, server=server)]
        if rejoin_at is not None:
            if rejoin_at <= time:
                raise ValueError("rejoin_at must be after the crash time")
            events.append(FaultEvent(rejoin_at, SERVER_JOINED, server=server))
        return FaultSchedule(events)

    @staticmethod
    def link_brownout(
        time: float, src: int, dst: int, factor: float, restore_at: float | None = None
    ) -> "FaultSchedule":
        """The src->dst link degrades to ``factor`` of its bandwidth at
        ``time`` (and optionally recovers later)."""
        events = [FaultEvent(time, LINK_DEGRADED, src=src, dst=dst, factor=factor)]
        if restore_at is not None:
            if restore_at <= time:
                raise ValueError("restore_at must be after the fault time")
            events.append(FaultEvent(restore_at, LINK_RESTORED, src=src, dst=dst))
        return FaultSchedule(events)


def apply_fault(event: FaultEvent, topology, tracer=None, now: float = 0.0) -> None:
    """Mutate ``topology.state`` (the shared :class:`LinkState`) to
    reflect ``event``. The placement/failover *response* is the caller's
    job; this only flips the liveness/bandwidth switches every cost
    primitive reads. A ``tracer`` (``repro.serving.obs.Tracer``) records
    the consumption as a ``FAULT`` instant at ``now`` on the caller's
    clock, carrying the full :meth:`FaultEvent.payload`."""
    if tracer is not None and tracer.enabled:
        tracer.instant(
            "FAULT",
            now,
            server=event.server if event.server is not None else -1,
            fault=event.payload(),
        )
    state = topology.state
    if event.kind == SERVER_DOWN:
        state.up[event.server] = False
    elif event.kind == SERVER_JOINED:
        state.up[event.server] = True
    elif event.kind == LINK_DEGRADED:
        state.bw_factor[event.src, event.dst] = event.factor
    elif event.kind == LINK_RESTORED:
        state.bw_factor[event.src, event.dst] = 1.0

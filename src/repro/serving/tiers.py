"""Per-server expert tier hierarchy behind GPU residency
(``repro.serving.tiers``).

The paper's headline constraint is that MoE footprints overwhelm edge
servers; every scenario served before this module assumed the full expert
set fits in aggregate GPU memory. A :class:`TierManager` lifts that
assumption: placement plans may legally assign a server as many experts
as its *deepest* tier holds (``ServerProfile.tiered_expert_budget``), and
the manager tracks which of them are GPU-resident (tier 0), parked in
host RAM (tier 1) or on modeled disk (tier 2).

Three mechanisms, all deterministic (no RNG, no wall clock):

* **bind(plan)** — whenever the controller adopts a plan, each server's
  assigned experts are split across its tiers hottest-first (by the
  accumulated gating heat; expert id breaks ties), so the GPU tier holds
  the historically hottest subset.
* **prefetch** — ``observe()`` folds the same per-origin ``[n_ep, E]``
  cumulative gating counts the ``TrafficMeter`` consumes into a per-
  (layer, expert) heat table; ``prefetch_step(now)`` swaps the hottest
  back-tier expert with the coldest GPU-resident one whenever it is
  strictly hotter, as a :class:`~repro.serving.net.TransferTask` over the
  server's host<->device link (``via="host"``/``"disk"``) priced by
  :func:`~repro.serving.net.schedule_transfers` and overlapped with
  decode; ``poll(now)`` flips the tiers once the modeled fetch lands.
  Demotion is free — tiers are inclusive, the host copy still exists.
* **accounting** — every observed activation on an expert its origin
  holds GPU-resident is a *prefetch hit*; one parked in a back tier books
  an *on-demand fetch* (one per (layer, origin, expert) cell per round)
  with a modeled stall of the tier's fetch time. ``summary()`` is the
  ``metrics.tiers`` payload (schema ``bench-serving/v6``).

A ``SERVER_DOWN`` fault calls ``drop_server``: the crashed server's
entire tier table is wiped and its in-flight promotions abort, so the
fault review re-plans tiered residency deterministically (the
fault-determinism contract extends to tiers — see ``tests/test_faults.py``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.placement import PlacementPlan
from repro.serving.net import Topology, TransferTask, schedule_transfers

TIER_GPU = 0
TIER_HOST = 1
TIER_DISK = 2


@dataclasses.dataclass
class _Promotion:
    """One in-flight host->GPU fetch (promote ``expert``, demote
    ``evict``); ``eta`` is in the owner's clock units."""

    layer: int
    server: int
    expert: int
    evict: int
    eta: float
    seconds: float


@dataclasses.dataclass
class TierManager:
    """Owns the per-server expert-tier tables and the activation-aware
    prefetcher.

    topology:     the cluster fabric (tier capacities + fetch pricing).
    expert_bytes: one expert's weights in bytes (the promotion payload).
    prefetch:     False freezes residency at the bind-time split — cold
                  experts keep paying on-demand fetches (the baseline leg
                  of the oversized-model benchmark).
    clock_rate:   modeled seconds per unit of the owner's clock (1.0 for
                  the seconds-clock sim backend; the runtime backend's
                  tick length), mirroring ``PlacementController``.
    """

    topology: Topology
    expert_bytes: float
    prefetch: bool = True
    clock_rate: float = 1.0

    tier: np.ndarray | None = None  # [L, N, E] int8, -1 = unplaced
    events: list = dataclasses.field(default_factory=list)
    # optional span tracer (repro.serving.obs.Tracer): PREFETCH spans for
    # landed promotions, COLD_FETCH_STALL spans for on-demand fetches —
    # duck-typed so this module stays importable without obs
    tracer: "object | None" = None

    def __post_init__(self):
        self._heat: np.ndarray | None = None  # [L, E] accumulated
        self._snapshot: np.ndarray | None = None  # last cumulative counts
        self._inflight: list[_Promotion] = []
        self.promotions = 0
        self.demotions = 0
        self.gpu_hit_tokens = 0.0
        self.fetch_tokens = 0.0
        self.on_demand_fetches = 0
        self.on_demand_stall_seconds = 0.0

    # -- residency ----------------------------------------------------
    def bind(self, plan: PlacementPlan) -> None:
        """Split ``plan``'s per-server expert assignments across tiers,
        hottest-first. Called on every plan switch (initial adoption,
        staged-migration completion, fault review); re-binding counts
        GPU-residents pushed to a back tier as demotions."""
        L = len(plan.assign)
        N, E = self.topology.n, plan.num_experts
        old = self.tier
        # whole-server byte budgets split evenly across layers (the same
        # heuristic ClusterView uses for its per-layer slot caps)
        caps = self.topology.tier_slot_capacities(self.expert_bytes) // L
        tier = np.full((L, N, E), -1, np.int8)
        for l in range(L):
            heat = self._heat[l] if self._heat is not None else np.zeros(E)
            for n in range(N):
                order = sorted(plan.assign[l][n], key=lambda e: (-heat[e], e))
                gpu, host, _ = caps[n]
                for rank, e in enumerate(order):
                    if rank < gpu:
                        tier[l, n, e] = TIER_GPU
                    elif rank < host:
                        tier[l, n, e] = TIER_HOST
                    else:
                        tier[l, n, e] = TIER_DISK
        if old is not None and old.shape == tier.shape:
            self.demotions += int(((old == TIER_GPU) & (tier > TIER_GPU)).sum())
        self.tier = tier
        self._inflight = [
            p
            for p in self._inflight
            if tier[p.layer, p.server, p.expert] > TIER_GPU
            and tier[p.layer, p.server, p.evict] == TIER_GPU
        ]

    def gpu_residency(self) -> np.ndarray | None:
        """[L, N, E] 0/1 — which assigned experts are GPU-resident now."""
        if self.tier is None:
            return None
        return (self.tier == TIER_GPU).astype(np.int8)

    def slot_priority(self) -> np.ndarray | None:
        """[L, N, E] sort key for engine slot tables: GPU-tier experts
        fill the (scarce) physical slots before back-tier ones."""
        return self.tier

    # -- stats ingestion + hit/stall accounting ------------------------
    def observe(self, total_counts: np.ndarray, now: float = 0.0) -> None:
        """Fold a cumulative per-origin ``[L, N, E]`` gating-counts matrix
        (the same accumulator the ``TrafficMeter`` observes) into the
        prefetch heat table, and book this round's hits/fetches against
        the current tier residency. ``now`` (owner's clock) anchors the
        traced ``COLD_FETCH_STALL`` spans; it never affects the
        accounting itself."""
        total = np.asarray(total_counts, float)
        if self._snapshot is None or self._snapshot.shape != total.shape:
            self._snapshot = np.zeros_like(total)
        delta = np.maximum(total - self._snapshot, 0.0)
        self._snapshot = total.copy()
        if not delta.any():
            return
        if self._heat is None or self._heat.shape != total.shape[::2]:
            self._heat = np.zeros((total.shape[0], total.shape[2]))
        self._heat += delta.sum(axis=1)
        if self.tier is None:
            return
        eb = self.expert_bytes
        n_srv = self.topology.n
        L = min(self.tier.shape[0], delta.shape[0])
        for l in range(L):
            t_l = self.tier[l]  # [N, E]
            d_l = delta[l][:n_srv]
            self.gpu_hit_tokens += float(d_l[t_l == TIER_GPU].sum())
            for n, e in zip(*np.nonzero((t_l > TIER_GPU) & (d_l > 0))):
                self.fetch_tokens += float(d_l[n, e])
                self.on_demand_fetches += 1
                if t_l[n, e] == TIER_DISK:
                    stall = self.topology.disk_fetch_seconds(int(n), eb)
                else:
                    stall = self.topology.host_fetch_seconds(int(n), eb)
                self.on_demand_stall_seconds += stall
                if self.tracer is not None and self.tracer.enabled:
                    self.tracer.span(
                        "COLD_FETCH_STALL",
                        now,
                        now + stall / self.clock_rate,
                        server=int(n),
                        layer=int(l),
                        expert=int(e),
                        tier=int(t_l[n, e]),
                        stall_seconds=stall,
                    )

    def fetch_stall_seconds(self, layer: int, server: int, expert: int) -> float:
        """Modeled stall for invoking ``expert`` on ``server`` right now:
        0 when GPU-resident, the tier's fetch time when parked behind, inf
        when not assigned there at all. On-demand fetches are transient —
        they never mutate the tier table (determinism: latency pricing
        stays a pure function of the tier state)."""
        if self.tier is None:
            return 0.0
        t = self.tier[layer, server, expert]
        if t < 0:
            return float("inf")
        if t == TIER_GPU:
            return 0.0
        if t == TIER_DISK:
            return self.topology.disk_fetch_seconds(server, self.expert_bytes)
        return self.topology.host_fetch_seconds(server, self.expert_bytes)

    # -- the prefetcher ------------------------------------------------
    def prefetch_step(self, now: float) -> None:
        """Promote the hottest back-tier expert per (server, layer) when
        it is strictly hotter than the coldest GPU-resident one, as a
        host-link :class:`TransferTask` overlapped with decode."""
        if not self.prefetch or self.tier is None or self._heat is None:
            return
        busy = {(p.layer, p.server) for p in self._inflight}
        L, N, _ = self.tier.shape
        tasks, promos = [], []
        for l in range(L):
            heat = self._heat[l]
            for n in range(N):
                if (l, n) in busy or not self.topology.profiles[n].tiered:
                    continue
                back = np.nonzero(self.tier[l, n] > TIER_GPU)[0]
                res = np.nonzero(self.tier[l, n] == TIER_GPU)[0]
                if not len(back) or not len(res):
                    continue
                hot = int(min(back, key=lambda e: (-heat[e], e)))
                cold = int(min(res, key=lambda e: (heat[e], e)))
                if heat[hot] <= heat[cold]:
                    continue
                via = "disk" if self.tier[l, n, hot] == TIER_DISK else "host"
                tasks.append(TransferTask(l, hot, n, n, self.expert_bytes, via=via))
                promos.append((l, n, hot, cold))
        if not tasks:
            return
        # one shared schedule: fetches on one server's host link
        # serialize, distinct servers proceed in parallel
        schedule_transfers(tasks, self.topology)
        for t, (l, n, hot, cold) in zip(tasks, promos):
            eta = now + t.end / self.clock_rate
            self._inflight.append(_Promotion(l, n, hot, cold, eta=eta, seconds=t.end))

    def poll(self, now: float) -> None:
        """Land every promotion whose modeled fetch has finished: the
        promoted expert becomes GPU-resident, the evicted one drops to
        the host tier (free — its host copy never left)."""
        if not self._inflight:
            return
        landed = [p for p in self._inflight if now >= p.eta]
        if not landed:
            return
        self._inflight = [p for p in self._inflight if now < p.eta]
        for p in landed:
            if (
                self.tier[p.layer, p.server, p.expert] <= TIER_GPU
                or self.tier[p.layer, p.server, p.evict] != TIER_GPU
            ):
                continue  # a rebind overtook this fetch
            self.tier[p.layer, p.server, p.expert] = TIER_GPU
            self.tier[p.layer, p.server, p.evict] = TIER_HOST
            self.promotions += 1
            self.demotions += 1
            if self.tracer is not None and self.tracer.enabled:
                # the fetch occupied the host link for p.seconds modeled
                # seconds ending at its eta (poll may run late; the span
                # records the modeled transfer window, not the poll time)
                self.tracer.span(
                    "PREFETCH",
                    p.eta - p.seconds / self.clock_rate,
                    p.eta,
                    server=p.server,
                    layer=p.layer,
                    expert=p.expert,
                    evict=p.evict,
                    seconds=p.seconds,
                )
            self.events.append(
                {
                    "type": "tier-promotion",
                    "time": now,
                    "layer": p.layer,
                    "server": p.server,
                    "expert": p.expert,
                    "evict": p.evict,
                    "seconds": p.seconds,
                }
            )

    # -- faults --------------------------------------------------------
    def drop_server(self, server: int) -> None:
        """A crash loses every tier on the server (host RAM and modeled
        disk die with the box); in-flight promotions there abort."""
        if self.tier is not None:
            self.tier[:, server, :] = -1
        self._inflight = [p for p in self._inflight if p.server != server]

    # -- metrics -------------------------------------------------------
    def summary(self) -> dict:
        """The ``metrics.tiers`` section of ``bench-serving/v6``."""
        N = self.topology.n
        caps = self.topology.tier_slot_capacities(self.expert_bytes)
        gpu_res = [0] * N
        host_res = [0] * N
        if self.tier is not None:
            gpu_res = (self.tier == TIER_GPU).sum(axis=(0, 2)).tolist()
            host_res = (self.tier > TIER_GPU).sum(axis=(0, 2)).tolist()
        served = self.gpu_hit_tokens + self.fetch_tokens
        hit_ratio = self.gpu_hit_tokens / served if served else 0.0
        return {
            "n_servers": N,
            "per_server_gpu_slots": [int(c[0]) for c in caps],
            "per_server_host_slots": [int(c[2]) for c in caps],
            "per_server_gpu_resident": [int(v) for v in gpu_res],
            "per_server_host_resident": [int(v) for v in host_res],
            "promotions": self.promotions,
            "demotions": self.demotions,
            "prefetch_hit_ratio": round(hit_ratio, 6),
            "on_demand_fetches": self.on_demand_fetches,
            "on_demand_stall_seconds": round(self.on_demand_stall_seconds, 6),
        }

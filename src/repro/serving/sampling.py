"""Seeded Gumbel-max temperature sampling shared by every decode surface.

One sampling rule serves the jitted paged step functions, the dense
(non-paged) admission/decode paths and the host-side full-prefix-hit
admission, so a request's token stream depends only on its own
``(seed, temperature)`` and the logits it sees:

* rows with ``temperature == 0`` reduce to ``argmax(logits)`` exactly —
  the pre-sampling greedy behavior, bit-identical;
* rows with ``temperature > 0`` draw via the Gumbel-max trick with a
  threefry key derived **only** from ``(seed, token position)`` — never
  from batch composition, bucket width or scheduling — so reruns (and the
  warm vs sync decode loops, which batch the same rows differently) are
  bit-identical by construction. jax's threefry PRNG is specified
  independently of backend/platform, which makes the seeded stream a
  contract rather than an accident.

Top-k / top-p truncation is deliberate follow-up work: the Gumbel-max
draw here is full-vocabulary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# temperatures below this clamp still count as "hot enough to divide by":
# guards the logits/temp division against inf without changing any
# realistic temperature (rows at exactly 0.0 never reach the division)
_MIN_TEMP = 1e-4


def _keys(seeds: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """[B, 2] per-row threefry keys from (request seed, token position)."""

    def one(seed, pos):
        return jax.random.fold_in(jax.random.PRNGKey(seed), pos)

    return jax.vmap(one)(
        seeds.astype(jnp.uint32), positions.astype(jnp.uint32)
    )


def sample_tokens(
    logits: jnp.ndarray,
    temps: jnp.ndarray,
    seeds: jnp.ndarray,
    positions: jnp.ndarray,
) -> jnp.ndarray:
    """[B] next-token ids from [B, V] logits.

    ``temps``/``seeds``/``positions`` are per-row; rows with ``temps == 0``
    return the exact argmax (greedy), rows with ``temps > 0`` return the
    Gumbel-max sample of ``softmax(logits / temp)`` keyed by
    ``fold_in(PRNGKey(seed), position)``. Usable inside jit and eagerly —
    both produce the same tokens for the same inputs.
    """
    greedy = jnp.argmax(logits, axis=-1)
    vocab = logits.shape[-1]
    keys = _keys(seeds, positions)
    noise = jax.vmap(
        lambda k: jax.random.gumbel(k, (vocab,), logits.dtype)
    )(keys)
    t = jnp.maximum(temps, _MIN_TEMP).astype(logits.dtype)
    sampled = jnp.argmax(logits / t[:, None] + noise, axis=-1)
    return jnp.where(temps > 0.0, sampled, greedy).astype(greedy.dtype)


def sample_token_host(
    logits_row: np.ndarray, temperature: float, seed: int, position: int
) -> int:
    """Sample one token eagerly on the host — the same keyed draw as the
    jitted path makes for identical ``(logits, temperature, seed,
    position)``. The greedy fast path avoids device work entirely."""
    row = np.asarray(logits_row)
    if temperature <= 0.0:
        return int(np.argmax(row))
    out = sample_tokens(
        jnp.asarray(row, jnp.float32)[None, :],
        jnp.full((1,), temperature, jnp.float32),
        jnp.asarray([seed], jnp.uint32),
        jnp.asarray([position], jnp.uint32),
    )
    return int(out[0])

"""Radix (token-trie) prefix cache over a paged KV block pool.

Edge request streams are dominated by shared system prompts and few-shot
templates, so consecutive prompts overlap heavily. Because k/v at position
``i`` depend only on tokens ``0..i``, two prompts with a common prefix have
*bit-identical* KV entries for every shared position — the cache exploits
this by mapping block-aligned prompt prefixes to the physical blocks that
already hold their k/v, so a new request skips prefill for every shared
page.

Structure (block granularity — only whole ``block_size``-token blocks are
reusable, since pages are the pool's unit of sharing):

* a trie whose edges are whole-block token runs: node ``n`` at depth ``j``
  holds the physical block for prompt positions ``j*bs .. (j+1)*bs - 1`` of
  every prompt whose first ``(j+1)*bs`` tokens spell the path to ``n``;
* per-node **tail entries**: a prompt whose length is not block-aligned
  ends in a partially-filled block; its remainder tokens key a tail entry
  holding that block plus the last-prompt-token logits, so an *identical*
  prompt skips prefill entirely (the first generated token is recomputed
  from the cached logits — greedy argmax, bit-equal to the live path);
* block-aligned full prompts attach their logits to the trie node itself.

Reference counting: the cache holds exactly one ``BlockAllocator`` ref per
node/tail entry. Live slots that share a cached block hold their own refs,
so a block is recycled only when the last holder (cache or slot) releases
it. **Cached blocks are never written**: a sharer that must write into a
partially-filled shared tail block gets a copy-on-write clone first (see
``ServingRuntime._admit_paged``); full shared blocks sit strictly before
any sharer's write frontier.

Eviction is LRU over leaves (tail entries and childless nodes), so a prefix
is never orphaned from its extension, and it skips entries whose block a
live slot still shares — evicting those would free no memory while
destroying reuse. Evicting an entry drops the cache's ref and recycles the
block immediately.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class PrefixMatch:
    """Result of a cache lookup.

    tokens:     prompt tokens covered by the match (block-aligned, except
                for a full-prompt hit where it equals the prompt length).
    blocks:     physical blocks of the matched *full* blocks, logical order.
    tail_block: the shared partially-filled tail block (full-prompt hits on
                non-block-aligned prompts only; requires CoW before any
                write).
    logits:     cached last-prompt-token logits (full-prompt hits only).
    """
    tokens: int
    blocks: list
    tail_block: int | None = None
    logits: np.ndarray | None = None

    @property
    def full_hit(self) -> bool:
        return self.logits is not None


class _Tail:
    __slots__ = ("block", "logits", "last_use")

    def __init__(self, block: int, logits: np.ndarray, last_use: int):
        self.block = block
        self.logits = logits
        self.last_use = last_use


class _Node:
    __slots__ = ("key", "block", "children", "tails", "logits", "parent",
                 "last_use")

    def __init__(self, key: bytes, block: int | None, parent):
        self.key = key                 # this node's block-token run (bytes)
        self.block = block             # physical block id (None: root)
        self.children: dict = {}       # next-block token run -> _Node
        self.tails: dict = {}          # remainder token run -> _Tail
        self.logits = None             # last-token logits of the
        #                                block-aligned prompt ending here
        self.parent = parent
        self.last_use = 0


class RadixPrefixCache:
    """Token-trie prefix cache; one allocator ref per cached block."""

    def __init__(self, block_size: int, allocator):
        self.block_size = block_size
        self.allocator = allocator
        self.root = _Node(b"", None, None)
        self._clock = 0
        self.evictions = 0             # entries evicted (for metrics)

    # -- internal walks ------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _walk(self, p: np.ndarray, *, create_blocks=None):
        """Walk (optionally extending with ``create_blocks``) the full-block
        path of prompt ``p``. Returns (node, matched_tokens, blocks)."""
        bs = self.block_size
        now = self._tick()
        node, k, blocks = self.root, 0, []
        j = 0
        while k + bs <= len(p):
            key = p[k:k + bs].tobytes()
            child = node.children.get(key)
            if child is None:
                if create_blocks is None or j >= len(create_blocks):
                    break
                child = _Node(key, int(create_blocks[j]), node)
                self.allocator.acquire([child.block])
                node.children[key] = child
            child.last_use = now
            node, k = child, k + bs
            blocks.append(child.block)
            j += 1
        return node, k, blocks

    # -- queries -------------------------------------------------------
    def lookup(self, prompt) -> PrefixMatch:
        """Longest block-aligned cached prefix of ``prompt`` (full-prompt
        hits also return the tail block / logits). A block-aligned full-walk
        without cached logits backs off one block: the final prompt token
        must be recomputed to produce the first sampled token."""
        p = np.asarray(prompt, np.int32).reshape(-1)
        node, k, blocks = self._walk(p)
        if k == len(p):
            if node.logits is not None:
                return PrefixMatch(k, blocks, None, node.logits)
            if blocks:
                blocks.pop()
                k -= self.block_size
            return PrefixMatch(k, blocks)
        tail = node.tails.get(p[k:].tobytes())
        if tail is not None:
            tail.last_use = self._clock
            return PrefixMatch(len(p), blocks, tail.block, tail.logits)
        return PrefixMatch(k, blocks)

    # -- insertion -----------------------------------------------------
    def insert_prefix(self, prompt, blocks) -> None:
        """Register the block-aligned prefix of ``prompt`` (``len(blocks)``
        full blocks). Existing trie nodes win — only missing nodes take a
        ref on the corresponding entry of ``blocks``."""
        p = np.asarray(prompt, np.int32).reshape(-1)
        self._walk(p[:len(blocks) * self.block_size], create_blocks=blocks)

    def set_logits(self, prompt, logits) -> None:
        """Attach last-token logits to a block-aligned full prompt (its
        prefix path must already be inserted)."""
        p = np.asarray(prompt, np.int32).reshape(-1)
        node, k, _ = self._walk(p)
        if k == len(p) and node is not self.root and node.logits is None:
            node.logits = np.asarray(logits, np.float32).copy()

    def insert_tail(self, prompt, tail_block: int, logits) -> bool:
        """Register the partially-filled tail block of a finished request
        (called at retirement — the owner will never write it again). Takes
        a ref on ``tail_block``; no-op when an identical tail is cached."""
        p = np.asarray(prompt, np.int32).reshape(-1)
        rem = len(p) % self.block_size
        if rem == 0:
            return False
        node, k, _ = self._walk(p)
        if k != len(p) - rem:          # prefix path incomplete (evicted)
            return False
        key = p[k:].tobytes()
        if key in node.tails:
            return False
        node.tails[key] = _Tail(int(tail_block),
                                np.asarray(logits, np.float32).copy(),
                                self._clock)
        self.allocator.acquire([int(tail_block)])
        return True

    # -- eviction ------------------------------------------------------
    def _leaves(self):
        """All evictable entries: (last_use, kind, node, key)."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            for key, t in n.tails.items():
                out.append((t.last_use, "tail", n, key))
            for key, c in n.children.items():
                if not c.children and not c.tails:
                    out.append((c.last_use, "node", n, key))
                stack.append(c)
        return out

    def evict(self, n_blocks: int, *, force: bool = False) -> int:
        """Drop LRU leaf entries until ``n_blocks`` physical blocks were
        recycled or nothing evictable remains. Entries whose block is still
        shared with a live slot (refcount > 1) are *skipped* — evicting
        them frees no memory and only destroys reuse (admission acquires
        its matched pages before evicting, so a hit's own prefix is always
        protected). ``force=True`` drops shared entries too (``clear``).
        Returns the number of blocks recycled."""
        freed = 0
        while freed < n_blocks:
            leaves = [e for e in self._leaves() if force or
                      self.allocator.refcount(
                          e[2].tails[e[3]].block if e[1] == "tail"
                          else e[2].children[e[3]].block) == 1]
            if not leaves:
                break
            leaves.sort(key=lambda e: e[0])
            for _, kind, parent, key in leaves:
                if kind == "tail":
                    t = parent.tails.pop(key)
                    freed += self.allocator.release([t.block])
                else:
                    c = parent.children.pop(key)
                    freed += self.allocator.release([c.block])
                self.evictions += 1
                if freed >= n_blocks:
                    break
        return freed

    def clear(self) -> int:
        """Evict everything, shared or not (tests / shutdown). Returns
        blocks recycled."""
        freed = 0
        while True:
            got = self.evict(1 << 30, force=True)
            freed += got
            if not self._leaves():
                return freed

    # -- introspection -------------------------------------------------
    def block_refs(self) -> collections.Counter:
        """Physical block -> number of cache refs held on it (0/1 each —
        every cached block backs exactly one node or tail entry)."""
        refs: collections.Counter = collections.Counter()
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.block is not None:
                refs[n.block] += 1
            for t in n.tails.values():
                refs[t.block] += 1
            stack.extend(n.children.values())
        return refs

    @property
    def held_blocks(self) -> int:
        return sum(self.block_refs().values())

"""Edge-cluster description and the multi-server serving façade.

Two layers live here:

* the faithful testbed model of the paper (Sec. IV): ``ServerSpec`` /
  ``ClusterSpec`` / ``MoEProfile`` — N servers with different GPU
  counts/memory/compute, linked by rate-limited networking (testbed:
  500 Mbps via Linux tc). The event-driven simulator consumes it.
* ``EdgeCluster`` — the serving-API-v1 façade over the paper's headline
  scenario: N edge servers cooperatively serving one MoE model, one
  pluggable router, one shared ``PlacementController``, and **two
  interchangeable backends** selected by ``backend=``:

  - ``"runtime"`` — real jitted JAX engines (``ServingRuntime``), clock =
    scheduler ticks. Either one shared runtime with origin-tagged slots
    (default — one KV pool, the EP spec already spans the N servers) or N
    per-server runtimes (``shared_runtime=False``, per-server KV pools and
    decode batches, where memory allows).
  - ``"sim"`` — the event-driven ``EdgeSimulator`` time model, clock =
    seconds.

  Both consume the same typed ``repro.serving.api.Request`` stream and
  emit the same ``RequestHandle`` events, so policies, benchmarks and
  examples run identically against either world.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.serving.api import (EventType, Request, RequestHandle, as_router)


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    name: str
    gpus: int = 1
    mem_bytes: float = 16e9            # usable GPU memory for experts
    compute_speed: float = 60e12       # effective FLOP/s for expert matmuls
    io_speed: float = 8e9              # weight-load bytes/s (PCIe/NVMe)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    servers: tuple[ServerSpec, ...]
    bandwidth: float = 500e6 / 8       # bytes/s between servers (500 Mbps)
    rtt: float = 2e-3                  # per-remote-call latency (s)

    @property
    def n(self) -> int:
        return len(self.servers)

    def expert_capacity(self, expert_bytes: float) -> np.ndarray:
        """Per-server total expert-slot budget (M_n / m_e of Algorithm 1)."""
        return np.array([int(s.mem_bytes // expert_bytes)
                         for s in self.servers])


@dataclasses.dataclass(frozen=True)
class MoEProfile:
    """Analytic per-token costs for one MoE model (drives the time model)."""
    num_layers: int
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    bytes_per_param: float = 2.0

    @property
    def expert_bytes(self) -> float:
        return 3 * self.d_model * self.d_ff * self.bytes_per_param

    @property
    def expert_flops_per_token(self) -> float:
        return 2 * 3 * self.d_model * self.d_ff

    @property
    def dense_flops_per_token(self) -> float:
        # attention projections + attention math approximation per layer
        return 2 * 4 * self.d_model * self.d_model

    @property
    def hidden_bytes_per_token(self) -> float:
        return self.d_model * self.bytes_per_param

    @staticmethod
    def from_config(cfg) -> "MoEProfile":
        return MoEProfile(num_layers=cfg.num_layers,
                          num_experts=cfg.num_experts, top_k=cfg.top_k,
                          d_model=cfg.d_model, d_ff=cfg.d_ff)


def paper_testbed(mem_fraction: float = 1.0) -> ClusterSpec:
    """The paper's testbed: 3 simulated edge servers with GPU allocations
    1/1/2 (A100-40G), 500 Mbps interconnect. ``mem_fraction`` reproduces the
    paper's artificial memory constraint (0.7 for Mixtral, 0.3 for
    DeepSeek-V2-Lite)."""
    return ClusterSpec(servers=(
        ServerSpec("server1", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server2", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server3", gpus=2, mem_bytes=mem_fraction * 2 * 40e9,
                   compute_speed=100e12),
    ))


MIXTRAL_PROFILE = MoEProfile(num_layers=32, num_experts=8, top_k=2,
                             d_model=4096, d_ff=14336)
DEEPSEEK_V2_LITE_PROFILE = MoEProfile(num_layers=26, num_experts=64, top_k=8,
                                      d_model=2048, d_ff=1408)


# ---------------------------------------------------------------------------
# EdgeCluster: the serving-API-v1 façade over both execution worlds
# ---------------------------------------------------------------------------

class _RuntimeBackend:
    """N edge servers over the jitted JAX serving stack (clock = ticks).

    One shared ``ServingRuntime`` with origin-tagged slots (default: one KV
    pool — the engine's EP spec already spans the N servers), or N
    per-server runtimes (own pools and decode batches) when memory allows.
    The router picks the serving runtime in per-server mode; in shared
    mode admission is cluster-wide, so requests are recorded at their
    origin (round-robin for origin-less ones) and never redirected.
    The shared ``PlacementController`` is reviewed on the *cluster* tick
    clock, so per-server runtimes do not double-count reviews.
    """
    clock = "ticks"

    def __init__(self, engine, n_servers: int, router, controller,
                 shared_runtime: bool, runtime_opts: dict):
        from repro.serving.runtime import ServingRuntime   # lazy: keeps the
        #   sim world (simulator.py imports this module) free of jax
        self.engine = engine
        self.n = n_servers
        self.router = router
        self.controller = controller
        self.shared = shared_runtime
        n_ep = engine.rt.ep_spec.n_ep if engine.rt.ep_spec is not None else 1
        # per-origin stats attribution needs one EP rank per server; when
        # the engine cannot represent every origin, serve untagged (the
        # positional fallback) rather than mis-crediting traffic
        self.tag_origins = n_ep >= n_servers
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                controller.last_review = 0.0       # full first interval
        self.runtimes = [
            ServingRuntime(engine, controller=None, **runtime_opts)
            for _ in range(1 if shared_runtime else n_servers)]
        self.rounds = 0
        self._rr = 0                 # round-robin cursor (shared mode)
        self.migrations: list = []

    def loads(self) -> np.ndarray:
        """[N] backlog estimate (queued + active) per server."""
        return np.array([len(r.queue) + r.active for r in self.runtimes],
                        float)

    def submit(self, req: Request) -> RequestHandle:
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site (the sim backend's contract too) —
            # not as an IndexError in routing or metrics()
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        if self.shared:
            # one pool serves the whole cluster: there is no routing
            # decision to make, so record the origin (round-robin for
            # origin-less requests) rather than reporting a degenerate
            # argmin-of-equal-loads that would pin metrics to server 0
            if req.origin is not None:
                server = req.origin
            else:
                server = self._rr
                self._rr = (self._rr + 1) % self.n
            rtm = self.runtimes[0]
        else:
            server = self.router.route(req.origin, self.loads())
            rtm = self.runtimes[server]
        if self.tag_origins:
            origin = req.origin if req.origin is not None else server
        else:
            origin = None
        handle = rtm.enqueue(dataclasses.replace(req, origin=origin))
        handle.request = req      # keep the caller's origin for metrics
        handle.server = server
        return handle

    @property
    def pending(self) -> bool:
        return any(r.queue or r.active for r in self.runtimes)

    def step(self) -> bool:
        had = self.pending
        for rtm in self.runtimes:
            rtm.step()
        self.rounds += 1
        if self.controller is not None:
            dec = self.controller.review_and_apply(self.rounds, self.engine)
            if dec is not None and dec.applied:
                self.migrations.append(dec.diag)
        return had

    def run(self) -> None:
        while self.pending:
            self.step()

    def local_ratio(self) -> np.ndarray:
        """[N] observed local-compute ratio per origin server: activation
        mass that landed on experts resident at the origin, under the
        controller's active plan."""
        ctrl = self.controller
        if (not self.tag_origins or ctrl is None or ctrl.plan is None
                or self.engine.rt.ep_spec is None):
            return np.ones(self.n)
        counts = self.engine.stats.counts          # [L, n_ep, E]
        res = ctrl.plan.residency() > 0            # [L, N, E]
        if res.shape != counts.shape:
            return np.ones(self.n)
        out = np.ones(self.n)
        for s in range(self.n):
            tot = counts[:, s, :].sum()
            if tot > 0:
                out[s] = (counts[:, s, :] * res[:, s, :]).sum() / tot
        return out


class _SimBackend:
    """N edge servers over the event-driven time model (clock = seconds).

    Typed requests become simulator arrivals: ``len(prompt)`` prompt
    tokens, ``max_new_tokens`` decode tokens, ``task`` selecting the
    activation profile, ``arrival``/``origin`` the arrival process. The
    simulator models time, not tokens, so handles get ADMITTED/FINISHED
    events (with latency + locality metrics) but no TOKEN events.
    """
    clock = "seconds"

    def __init__(self, spec: ClusterSpec, profile: MoEProfile, plan,
                 controller, router, tasks: dict | None, seed: int,
                 ratio_bucket: float):
        from repro.data.traces import Workload     # numpy-only
        from repro.serving.simulator import EdgeSimulator   # lazy: this
        #   module is imported by simulator.py (no import cycle at load)
        self.profile = profile
        self.seed = seed
        self.workload = Workload(requests=[], tasks=dict(tasks or {}),
                                 duration=0.0)
        self.sim = EdgeSimulator(spec, profile, self.workload, plan=plan,
                                 controller=controller, router=router,
                                 seed=seed, ratio_bucket=ratio_bucket)
        self.controller = controller
        self.n = spec.n
        self._pending: list = []       # heap of (arrival, seq, sim_req, h)
        self._seq = 0

    def _task_probs(self, name: str) -> None:
        from repro.data.traces import make_task_profile
        if name not in self.workload.tasks:
            self.workload.tasks[name] = make_task_profile(
                name, self.profile.num_layers, self.profile.num_experts,
                seed=self.seed)

    def submit(self, req: Request) -> RequestHandle:
        from repro.data.traces import Request as SimRequest
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site, not as an IndexError mid-simulation
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        task = req.task if req.task is not None else "default"
        self._task_probs(task)
        arrival = float(req.arrival) if req.arrival is not None else 0.0
        # origin-less requests get their server at *serve* time (step()),
        # when the router can see the live timeline; -1 marks them here
        sim_req = SimRequest(arrival=arrival,
                             server=req.origin if req.origin is not None
                             else -1,
                             task=task, prompt_tokens=len(req.prompt),
                             decode_tokens=req.max_new_tokens)
        handle = RequestHandle(self._seq, req, clock="seconds")
        handle.submitted_at = arrival
        heapq.heappush(self._pending, (arrival, self._seq, sim_req, handle))
        self._seq += 1
        return handle

    @property
    def pending(self) -> bool:
        return bool(self._pending)

    def step(self) -> bool:
        """Serve the earliest pending arrival (event-driven: one request is
        one event)."""
        if not self._pending:
            return False
        arrival, _, sim_req, handle = heapq.heappop(self._pending)
        if sim_req.server < 0:
            # origin-less: the router assigns the server against the live
            # timeline (HomeRouter/LeastLoadedRouter both fall back to the
            # least-loaded server when origin is None)
            n = self.sim.router.route(None, self.sim.loads(arrival))
            sim_req = dataclasses.replace(sim_req, server=n)
        rec = self.sim.serve_request(sim_req)
        handle._emit(EventType.ADMITTED, rec["start"], server=rec["server"])
        slo = handle.request.slo
        handle._emit(
            EventType.FINISHED, rec["done"],
            tokens=handle.request.max_new_tokens, origin=handle.request.origin,
            server=rec["server"], latency=rec["latency"],
            wait=rec["start"] - arrival, deferred_ticks=0,
            prefix_tokens_skipped=0,
            local_frac=(rec["hits"] / rec["tot"] if rec["tot"] else None),
            slo=slo,
            slo_met=(bool(rec["latency"] <= slo)
                     if slo is not None else None))
        return True

    def run(self) -> None:
        while self.step():
            pass

    @property
    def migrations(self) -> list:
        self.sim.start()
        return self.sim._migrations

    def local_ratio(self) -> np.ndarray:
        return self.sim.local_ratio_by_server()


class EdgeCluster:
    """Serving API v1 façade: N edge servers, one router, one shared
    placement control plane, two interchangeable backends.

    backend:        ``"runtime"`` (jitted JAX engines, tick clock) or
                    ``"sim"`` (event-driven time model, seconds clock).
    n_servers:      cluster size (runtime backend: defaults to the engine's
                    EP rank count; sim backend: ``spec.n``).
    router:         ``repro.serving.api.Router`` instance or name
                    (``"home"`` / ``"least-loaded"``); default home-server
                    routing (the paper's arrival semantics).
    controller:     the shared ``PlacementController`` (optional for the
                    runtime backend; the sim backend needs it or ``plan``).
    engine:         runtime backend — the ``ServingEngine`` the cluster
                    serves with.
    shared_runtime: runtime backend — one origin-tagged runtime (default)
                    vs one ``ServingRuntime`` (own KV pool/decode batch)
                    per server.
    runtime_opts:   runtime backend — kwargs forwarded to each
                    ``ServingRuntime`` (max_slots, block_size, ...).
    spec/profile:   sim backend — ``ClusterSpec`` + ``MoEProfile``.
    plan:           sim backend — static ``PlacementPlan`` (alternative to
                    a controller).
    tasks:          sim backend — {name: TaskProfile} activation profiles
                    (unknown task names get a generated profile).
    """

    def __init__(self, backend: str = "runtime", *,
                 n_servers: int | None = None, router=None, controller=None,
                 engine=None, shared_runtime: bool = True,
                 runtime_opts: dict | None = None,
                 spec: ClusterSpec | None = None,
                 profile: MoEProfile | None = None, plan=None,
                 tasks: dict | None = None, seed: int = 0,
                 ratio_bucket: float = 60.0):
        router = as_router(router)
        if backend == "runtime":
            if engine is None:
                raise ValueError("runtime backend needs engine=")
            if n_servers is None:
                n_servers = (engine.rt.ep_spec.n_ep
                             if engine.rt.ep_spec is not None else 1)
            self.backend = _RuntimeBackend(engine, n_servers, router,
                                           controller, shared_runtime,
                                           dict(runtime_opts or {}))
        elif backend == "sim":
            if spec is None or profile is None:
                raise ValueError("sim backend needs spec= and profile=")
            if n_servers is not None and n_servers != spec.n:
                raise ValueError(
                    f"n_servers={n_servers} != spec.n={spec.n}")
            n_servers = spec.n
            self.backend = _SimBackend(spec, profile, plan, controller,
                                       router, tasks, seed, ratio_bucket)
        else:
            raise ValueError(
                f"unknown backend {backend!r}: expected 'runtime' or 'sim'")
        self.backend_name = backend
        self.n_servers = n_servers
        self.controller = controller
        self.handles: list[RequestHandle] = []

    # -- the portable surface ------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        h = self.backend.submit(request)
        self.handles.append(h)
        return h

    def step(self) -> bool:
        """Advance the cluster one unit of its backend clock."""
        return self.backend.step()

    def run(self) -> list[RequestHandle]:
        """Serve until every submitted request finished; returns all
        handles in submission order."""
        self.backend.run()
        return self.handles

    @property
    def migrations(self) -> list:
        return self.backend.migrations

    def metrics(self) -> dict:
        """Per-server serving metrics in one backend-agnostic shape:
        submitted/served/finished/redirected request counts, mean latency
        by origin (backend clock units) and the local-compute ratio."""
        N = self.n_servers
        submitted = np.zeros(N, int)
        served = np.zeros(N, int)
        finished = np.zeros(N, int)
        redirected = np.zeros(N, int)
        lat_sum = np.zeros(N)
        lat_n = np.zeros(N, int)
        for h in self.handles:
            o = h.request.origin
            s = h.server if h.server is not None else (o if o is not None
                                                       else 0)
            oo = o if o is not None else s
            submitted[oo] += 1
            served[s] += 1
            if o is not None and s != o:
                redirected[oo] += 1
            if h.done:
                finished[s] += 1
                lat = h.metrics.get("latency")
                if lat is not None:
                    lat_sum[oo] += lat
                    lat_n[oo] += 1
        mean_lat = np.where(lat_n > 0, lat_sum / np.maximum(lat_n, 1), 0.0)
        return {
            "backend": self.backend_name,
            "clock": self.backend.clock,
            "n_servers": N,
            "per_server": {
                "submitted": submitted.tolist(),
                "served": served.tolist(),
                "finished": finished.tolist(),
                "redirected": redirected.tolist(),
                "mean_latency": [round(float(v), 6) for v in mean_lat],
                "local_ratio": [round(float(v), 6)
                                for v in self.backend.local_ratio()],
            },
            "redirected_total": int(redirected.sum()),
        }


def requests_from_workload(workload) -> list[Request]:
    """Convert a ``repro.data.traces.Workload`` into the equivalent typed
    API request stream (synthetic prompts of the right length — the sim
    backend models time from token *counts*). Pass ``tasks=workload.tasks``
    to ``EdgeCluster`` so the activation profiles carry over too."""
    return [Request(prompt=np.zeros(max(r.prompt_tokens, 1), np.int32),
                    max_new_tokens=r.decode_tokens, origin=r.server,
                    arrival=r.arrival, task=r.task)
            for r in workload.requests]

"""Edge-cluster description: heterogeneous servers, bandwidth, model profile.

This is the faithful testbed model of the paper (Sec. IV): N servers with
different GPU counts/memory/compute, linked by rate-limited networking
(testbed: 500 Mbps via Linux tc). The event-driven simulator consumes it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    name: str
    gpus: int = 1
    mem_bytes: float = 16e9            # usable GPU memory for experts
    compute_speed: float = 60e12       # effective FLOP/s for expert matmuls
    io_speed: float = 8e9              # weight-load bytes/s (PCIe/NVMe)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    servers: tuple[ServerSpec, ...]
    bandwidth: float = 500e6 / 8       # bytes/s between servers (500 Mbps)
    rtt: float = 2e-3                  # per-remote-call latency (s)

    @property
    def n(self) -> int:
        return len(self.servers)

    def expert_capacity(self, expert_bytes: float) -> np.ndarray:
        """Per-server total expert-slot budget (M_n / m_e of Algorithm 1)."""
        return np.array([int(s.mem_bytes // expert_bytes)
                         for s in self.servers])


@dataclasses.dataclass(frozen=True)
class MoEProfile:
    """Analytic per-token costs for one MoE model (drives the time model)."""
    num_layers: int
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    bytes_per_param: float = 2.0

    @property
    def expert_bytes(self) -> float:
        return 3 * self.d_model * self.d_ff * self.bytes_per_param

    @property
    def expert_flops_per_token(self) -> float:
        return 2 * 3 * self.d_model * self.d_ff

    @property
    def dense_flops_per_token(self) -> float:
        # attention projections + attention math approximation per layer
        return 2 * 4 * self.d_model * self.d_model

    @property
    def hidden_bytes_per_token(self) -> float:
        return self.d_model * self.bytes_per_param

    @staticmethod
    def from_config(cfg) -> "MoEProfile":
        return MoEProfile(num_layers=cfg.num_layers,
                          num_experts=cfg.num_experts, top_k=cfg.top_k,
                          d_model=cfg.d_model, d_ff=cfg.d_ff)


def paper_testbed(mem_fraction: float = 1.0) -> ClusterSpec:
    """The paper's testbed: 3 simulated edge servers with GPU allocations
    1/1/2 (A100-40G), 500 Mbps interconnect. ``mem_fraction`` reproduces the
    paper's artificial memory constraint (0.7 for Mixtral, 0.3 for
    DeepSeek-V2-Lite)."""
    return ClusterSpec(servers=(
        ServerSpec("server1", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server2", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server3", gpus=2, mem_bytes=mem_fraction * 2 * 40e9,
                   compute_speed=100e12),
    ))


MIXTRAL_PROFILE = MoEProfile(num_layers=32, num_experts=8, top_k=2,
                             d_model=4096, d_ff=14336)
DEEPSEEK_V2_LITE_PROFILE = MoEProfile(num_layers=26, num_experts=64, top_k=8,
                                      d_model=2048, d_ff=1408)

"""Edge-cluster description and the multi-server serving façade.

Two layers live here:

* the faithful testbed model of the paper (Sec. IV): ``ServerSpec`` /
  ``ClusterSpec`` / ``MoEProfile`` — N servers with different GPU
  counts/memory/compute, linked by rate-limited networking (testbed:
  500 Mbps via Linux tc). The event-driven simulator consumes it.
* ``EdgeCluster`` — the serving-API-v1 façade over the paper's headline
  scenario: N edge servers cooperatively serving one MoE model, one
  pluggable router, one shared ``PlacementController``, and **two
  interchangeable backends** selected by ``backend=``:

  - ``"runtime"`` — real jitted JAX engines (``ServingRuntime``), clock =
    scheduler ticks. Either one shared runtime with origin-tagged slots
    (default — one KV pool, the EP spec already spans the N servers) or N
    per-server runtimes (``shared_runtime=False``, per-server KV pools and
    decode batches, where memory allows).
  - ``"sim"`` — the event-driven ``EdgeSimulator`` time model, clock =
    seconds.

  Both consume the same typed ``repro.serving.api.Request`` stream and
  emit the same ``RequestHandle`` events, so policies, benchmarks and
  examples run identically against either world.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.serving.api import (Event, EventType, Request, RequestHandle,
                               as_router)
from repro.serving.net import Topology, TrafficMeter


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    name: str
    gpus: int = 1
    mem_bytes: float = 16e9            # usable GPU memory for experts
    compute_speed: float = 60e12       # effective FLOP/s for expert matmuls
    io_speed: float = 8e9              # weight-load bytes/s (PCIe/NVMe)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    servers: tuple[ServerSpec, ...]
    bandwidth: float = 500e6 / 8       # bytes/s between servers (500 Mbps)
    rtt: float = 2e-3                  # per-remote-call latency (s)

    @property
    def n(self) -> int:
        return len(self.servers)

    def expert_capacity(self, expert_bytes: float) -> np.ndarray:
        """Per-server total expert-slot budget (M_n / m_e of Algorithm 1)."""
        return np.array([int(s.mem_bytes // expert_bytes)
                         for s in self.servers])


@dataclasses.dataclass(frozen=True)
class MoEProfile:
    """Analytic per-token costs for one MoE model (drives the time model)."""
    num_layers: int
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    bytes_per_param: float = 2.0

    @property
    def expert_bytes(self) -> float:
        return 3 * self.d_model * self.d_ff * self.bytes_per_param

    @property
    def expert_flops_per_token(self) -> float:
        return 2 * 3 * self.d_model * self.d_ff

    @property
    def dense_flops_per_token(self) -> float:
        # attention projections + attention math approximation per layer
        return 2 * 4 * self.d_model * self.d_model

    @property
    def hidden_bytes_per_token(self) -> float:
        return self.d_model * self.bytes_per_param

    @staticmethod
    def from_config(cfg) -> "MoEProfile":
        return MoEProfile(num_layers=cfg.num_layers,
                          num_experts=cfg.num_experts, top_k=cfg.top_k,
                          d_model=cfg.d_model, d_ff=cfg.d_ff)


def paper_testbed(mem_fraction: float = 1.0) -> ClusterSpec:
    """The paper's testbed: 3 simulated edge servers with GPU allocations
    1/1/2 (A100-40G), 500 Mbps interconnect. ``mem_fraction`` reproduces the
    paper's artificial memory constraint (0.7 for Mixtral, 0.3 for
    DeepSeek-V2-Lite)."""
    return ClusterSpec(servers=(
        ServerSpec("server1", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server2", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server3", gpus=2, mem_bytes=mem_fraction * 2 * 40e9,
                   compute_speed=100e12),
    ))


MIXTRAL_PROFILE = MoEProfile(num_layers=32, num_experts=8, top_k=2,
                             d_model=4096, d_ff=14336)
DEEPSEEK_V2_LITE_PROFILE = MoEProfile(num_layers=26, num_experts=64, top_k=8,
                                      d_model=2048, d_ff=1408)


# ---------------------------------------------------------------------------
# EdgeCluster: the serving-API-v1 façade over both execution worlds
# ---------------------------------------------------------------------------

class _RuntimeBackend:
    """N edge servers over the jitted JAX serving stack (clock = ticks).

    One shared ``ServingRuntime`` with origin-tagged slots (default: one KV
    pool — the engine's EP spec already spans the N servers), or N
    per-server runtimes (own pools and decode batches) when memory allows.
    The router picks the serving runtime in per-server mode; in shared
    mode admission is cluster-wide, so requests are recorded at their
    origin (round-robin for origin-less ones) and never redirected.
    The shared ``PlacementController`` is reviewed on the *cluster* tick
    clock, so per-server runtimes do not double-count reviews.
    """
    clock = "ticks"

    def __init__(self, engine, n_servers: int, router, controller,
                 shared_runtime: bool, runtime_opts: dict,
                 topology: Topology | None = None):
        from repro.serving.runtime import ServingRuntime   # lazy: keeps the
        #   sim world (simulator.py imports this module) free of jax
        self.engine = engine
        self.n = n_servers
        self.router = router
        self.controller = controller
        self.shared = shared_runtime
        self.topology = topology
        n_ep = engine.rt.ep_spec.n_ep if engine.rt.ep_spec is not None else 1
        # per-origin stats attribution needs one EP rank per server; when
        # the engine cannot represent every origin, serve untagged (the
        # positional fallback) rather than mis-crediting traffic
        self.tag_origins = n_ep >= n_servers
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                controller.last_review = 0.0       # full first interval
            controller.attach_topology(topology,
                                       expert_bytes=self._expert_bytes())
        itemsize = np.dtype(engine.rt.dtype).itemsize
        self.meter = (TrafficMeter(topology,
                                   engine.rt.cfg.d_model * itemsize)
                      if topology is not None else None)
        if self.meter is not None:
            # the engine may have served before this cluster existed
            # (warmup generate(), a previous cluster): its lifetime stats
            # are not this cluster's dispatch traffic
            self.meter.seed(engine.stats.counts)
        opts = [dict(runtime_opts)
                for _ in range(1 if shared_runtime else n_servers)]
        if (not shared_runtime and topology is not None
                and "n_blocks" not in runtime_opts):
            # heterogeneous KV budgets: each server's paged pool is sized
            # by its own ServerProfile cap (per-position bytes estimated
            # as k+v full-width rows across the layers)
            bs = runtime_opts.get("block_size", 16)
            pos_bytes = (2.0 * engine.rt.cfg.num_layers
                         * engine.rt.cfg.d_model * itemsize)
            budgets = topology.kv_block_budgets(bs * pos_bytes)
            for s, o in enumerate(opts):
                o["n_blocks"] = 1 + int(budgets[s])
        self.runtimes = [ServingRuntime(engine, controller=None, **o)
                         for o in opts]
        self.rounds = 0
        self._rr = 0                 # round-robin cursor (shared mode)
        self.migrations: list = []

    def _expert_bytes(self) -> float:
        cfg = self.engine.rt.cfg
        return float(3 * cfg.d_model * cfg.d_ff
                     * np.dtype(self.engine.rt.dtype).itemsize)

    def _residency(self) -> np.ndarray | None:
        """[L, N, E] residency for the traffic meter: the controller's
        active plan, falling back to the engine's live placement tables
        (controller-less clusters still meter their dispatch traffic)."""
        ctrl = self.controller
        if ctrl is not None and ctrl.plan is not None:
            return ctrl.plan.residency()
        pl = self.engine.placement
        if pl is None:
            return None
        s2e = np.asarray(pl.slot_to_expert)          # [G, n_ep, S]
        G, N, _ = s2e.shape
        E = self.engine.rt.cfg.num_experts
        res = np.zeros((G, N, E))
        for l in range(G):
            for n in range(N):
                for e in s2e[l, n]:
                    if e >= 0:
                        res[l, n, int(e)] = 1.0
        return res

    def loads(self) -> np.ndarray:
        """[N] backlog estimate (queued + active) per server."""
        return np.array([len(r.queue) + r.active for r in self.runtimes],
                        float)

    def submit(self, req: Request) -> RequestHandle:
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site (the sim backend's contract too) —
            # not as an IndexError in routing or metrics()
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        if self.shared:
            # one pool serves the whole cluster: there is no routing
            # decision to make, so record the origin (round-robin for
            # origin-less requests) rather than reporting a degenerate
            # argmin-of-equal-loads that would pin metrics to server 0
            if req.origin is not None:
                server = req.origin
            else:
                server = self._rr
                self._rr = (self._rr + 1) % self.n
            rtm = self.runtimes[0]
        else:
            server = self.router.route(req.origin, self.loads())
            rtm = self.runtimes[server]
        if self.tag_origins:
            origin = req.origin if req.origin is not None else server
        else:
            origin = None
        handle = rtm.enqueue(dataclasses.replace(req, origin=origin))
        handle.request = req      # keep the caller's origin for metrics
        handle.server = server
        return handle

    @property
    def pending(self) -> bool:
        return any(r.queue or r.active or r._pending
                   for r in self.runtimes)

    def step(self) -> bool:
        had = self.pending
        # residency BEFORE the round: this tick's dispatch rides the
        # incumbent tables even when the review below completes a staged
        # migration, so its bytes meter against the old links
        res_before = self._residency() if self.meter is not None else None
        for rtm in self.runtimes:
            rtm.step()
        self.rounds += 1
        ctrl = self.controller
        if ctrl is not None:
            dec = ctrl.review_and_apply(self.rounds, self.engine)
            if dec is not None and dec.applied:
                self.migrations.append(dec.diag)
        if (self.meter is not None and res_before is not None
                and res_before.shape == self.engine.stats.counts.shape):
            # engine.stats is the engine's own plain accumulator (the
            # meter needs true cumulative volumes, never a user-supplied
            # EMA-decayed tracker)
            self.meter.observe(self.engine.stats.counts, res_before)
        return had

    def run(self) -> None:
        while self.pending:
            self.step()
        for rtm in self.runtimes:
            rtm.flush()

    def perf(self) -> dict:
        """Cluster-wide ``metrics.perf`` section: warmup cost and retrace/
        stall counters summed over the member runtimes, decode-round and
        TTFT wall-time percentiles pooled over every round they served."""
        rounds: list[float] = []
        ttft: list[float] = []
        for r in self.runtimes:
            rounds.extend(r.decode_round_s)
            ttft.extend(r.ttft_s)

        def pct(xs):
            if not xs:
                return {"p50": 0.0, "p99": 0.0}
            return {"p50": round(float(np.percentile(xs, 50)) * 1e3, 6),
                    "p99": round(float(np.percentile(xs, 99)) * 1e3, 6)}
        return {
            "warmup_seconds": round(sum(r.warmup_seconds
                                        for r in self.runtimes), 6),
            "executables_compiled": sum(r.executables_compiled
                                        for r in self.runtimes),
            "traces_after_warmup": sum(r.traces_after_warmup
                                       for r in self.runtimes),
            "host_syncs": sum(r.host_syncs for r in self.runtimes),
            "rounds_timed": len(rounds),
            "decode_round_ms": pct(rounds),
            "ttft_ms": pct(ttft),
        }

    def local_ratio(self) -> np.ndarray:
        """[N] observed local-compute ratio per origin server: activation
        mass that landed on experts resident at the origin, under the
        controller's active plan."""
        ctrl = self.controller
        if (not self.tag_origins or ctrl is None or ctrl.plan is None
                or self.engine.rt.ep_spec is None):
            return np.ones(self.n)
        counts = self.engine.stats.counts          # [L, n_ep, E]
        res = ctrl.plan.residency() > 0            # [L, N, E]
        if res.shape != counts.shape:
            return np.ones(self.n)
        out = np.ones(self.n)
        for s in range(self.n):
            tot = counts[:, s, :].sum()
            if tot > 0:
                out[s] = (counts[:, s, :] * res[:, s, :]).sum() / tot
        return out


class _SimBackend:
    """N edge servers over the event-driven time model (clock = seconds).

    Typed requests become simulator arrivals: ``len(prompt)`` prompt
    tokens, ``max_new_tokens`` decode tokens, ``task`` selecting the
    activation profile, ``arrival``/``origin`` the arrival process. The
    simulator models time, not tokens, so handles get ADMITTED/FINISHED
    events (with latency + locality metrics) but no TOKEN events.
    """
    clock = "seconds"

    def __init__(self, spec: ClusterSpec, profile: MoEProfile, plan,
                 controller, router, tasks: dict | None, seed: int,
                 ratio_bucket: float, topology: Topology | None = None):
        from repro.data.traces import Workload     # numpy-only
        from repro.serving.simulator import EdgeSimulator   # lazy: this
        #   module is imported by simulator.py (no import cycle at load)
        self.profile = profile
        self.seed = seed
        self.topology = topology
        self.workload = Workload(requests=[], tasks=dict(tasks or {}),
                                 duration=0.0)
        self.sim = EdgeSimulator(spec, profile, self.workload, plan=plan,
                                 controller=controller, router=router,
                                 seed=seed, ratio_bucket=ratio_bucket,
                                 topology=topology)
        self.controller = controller
        self.meter = (TrafficMeter(topology, profile.hidden_bytes_per_token)
                      if topology is not None else None)
        self.n = spec.n
        self._pending: list = []       # heap of (arrival, seq, sim_req, h)
        self._seq = 0

    def _task_probs(self, name: str) -> None:
        from repro.data.traces import make_task_profile
        if name not in self.workload.tasks:
            self.workload.tasks[name] = make_task_profile(
                name, self.profile.num_layers, self.profile.num_experts,
                seed=self.seed)

    def submit(self, req: Request) -> RequestHandle:
        from repro.data.traces import Request as SimRequest
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site, not as an IndexError mid-simulation
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        task = req.task if req.task is not None else "default"
        self._task_probs(task)
        arrival = float(req.arrival) if req.arrival is not None else 0.0
        # origin-less requests get their server at *serve* time (step()),
        # when the router can see the live timeline; -1 marks them here
        sim_req = SimRequest(arrival=arrival,
                             server=req.origin if req.origin is not None
                             else -1,
                             task=task, prompt_tokens=len(req.prompt),
                             decode_tokens=req.max_new_tokens)
        handle = RequestHandle(self._seq, req, clock="seconds")
        handle.submitted_at = arrival
        heapq.heappush(self._pending, (arrival, self._seq, sim_req, handle))
        self._seq += 1
        return handle

    @property
    def pending(self) -> bool:
        return bool(self._pending)

    def step(self) -> bool:
        """Serve the earliest pending arrival (event-driven: one request is
        one event)."""
        if not self._pending:
            return False
        self.sim.start()
        # residency BEFORE this event: the request's dispatch is routed
        # under the incumbent plan even when serving it completes a staged
        # migration, so its bytes must meter against the old links
        res_before = (None if self.sim._res is None
                      else self.sim._res.copy())
        arrival, _, sim_req, handle = heapq.heappop(self._pending)
        if sim_req.server < 0:
            # origin-less: the router assigns the server against the live
            # timeline (HomeRouter/LeastLoadedRouter both fall back to the
            # least-loaded server when origin is None)
            n = self.sim.router.route(None, self.sim.loads(arrival))
            sim_req = dataclasses.replace(sim_req, server=n)
        rec = self.sim.serve_request(sim_req)
        handle._emit(EventType.ADMITTED, rec["start"], server=rec["server"])
        slo = handle.request.slo
        handle._emit(
            EventType.FINISHED, rec["done"],
            tokens=handle.request.max_new_tokens, origin=handle.request.origin,
            server=rec["server"], latency=rec["latency"],
            wait=rec["start"] - arrival, deferred_ticks=0,
            prefix_tokens_skipped=0,
            local_frac=(rec["hits"] / rec["tot"] if rec["tot"] else None),
            slo=slo,
            slo_met=(bool(rec["latency"] <= slo)
                     if slo is not None else None))
        if self.meter is not None and res_before is not None:
            # _dispatch_counts, not the controller's (possibly EMA-decayed,
            # possibly pre-primed) ActivationStats: metering needs the true
            # cumulative per-origin volumes
            self.meter.observe(self.sim._dispatch_counts, res_before)
        return True

    def run(self) -> None:
        while self.step():
            pass

    @property
    def migrations(self) -> list:
        self.sim.start()
        return self.sim._migrations

    def local_ratio(self) -> np.ndarray:
        return self.sim.local_ratio_by_server()

    def _expert_bytes(self) -> float:
        return self.profile.expert_bytes


class EdgeCluster:
    """Serving API v1 façade: N edge servers, one router, one shared
    placement control plane, two interchangeable backends.

    backend:        ``"runtime"`` (jitted JAX engines, tick clock) or
                    ``"sim"`` (event-driven time model, seconds clock).
    n_servers:      cluster size (runtime backend: defaults to the engine's
                    EP rank count; sim backend: ``spec.n``).
    router:         ``repro.serving.api.Router`` instance or name
                    (``"home"`` / ``"least-loaded"``); default home-server
                    routing (the paper's arrival semantics).
    controller:     the shared ``PlacementController`` (optional for the
                    runtime backend; the sim backend needs it or ``plan``).
    engine:         runtime backend — the ``ServingEngine`` the cluster
                    serves with.
    shared_runtime: runtime backend — one origin-tagged runtime (default)
                    vs one ``ServingRuntime`` (own KV pool/decode batch)
                    per server.
    runtime_opts:   runtime backend — kwargs forwarded to each
                    ``ServingRuntime`` (max_slots, block_size,
                    ``warmup=True`` for the AOT bucket ladder + zero-stall
                    loop, ...); ``metrics()["perf"]`` aggregates the
                    members' warmup/retrace/stall/latency counters.
    spec/profile:   sim backend — ``ClusterSpec`` + ``MoEProfile``.
    plan:           sim backend — static ``PlacementPlan`` (alternative to
                    a controller).
    tasks:          sim backend — {name: TaskProfile} activation profiles
                    (unknown task names get a generated profile).
    topology:       optional ``repro.serving.net.Topology`` — one shared
                    link-cost model for both backends: per-(src, dst)
                    dispatch byte metering (``metrics()["net"]``),
                    bandwidth-aware *staged* migration on the shared
                    controller, per-link comm pricing in the sim time
                    model, and (``shared_runtime=False``) per-server KV
                    pools sized by each ``ServerProfile``'s memory cap.
                    The sim backend can derive ``spec`` from it. Defaults
                    to the controller's topology when it carries one. The
                    runtime backend's tick clock converts modeled transfer
                    *seconds* via ``controller.clock_rate`` (seconds per
                    tick, default 1.0) — set it on the controller when a
                    decode round is far from one second.
    """

    def __init__(self, backend: str = "runtime", *,
                 n_servers: int | None = None, router=None, controller=None,
                 engine=None, shared_runtime: bool = True,
                 runtime_opts: dict | None = None,
                 spec: ClusterSpec | None = None,
                 profile: MoEProfile | None = None, plan=None,
                 tasks: dict | None = None, seed: int = 0,
                 ratio_bucket: float = 60.0,
                 topology: Topology | None = None):
        router = as_router(router)
        if controller is not None:
            topology = controller.attach_topology(topology)   # one shared
            #   link model between the cluster and the control plane
        if backend == "runtime":
            if engine is None:
                raise ValueError("runtime backend needs engine=")
            if n_servers is None:
                n_servers = (engine.rt.ep_spec.n_ep
                             if engine.rt.ep_spec is not None else 1)
            if topology is not None and topology.n != n_servers:
                raise ValueError(
                    f"topology has {topology.n} servers, cluster has "
                    f"{n_servers}")
            self.backend = _RuntimeBackend(engine, n_servers, router,
                                           controller, shared_runtime,
                                           dict(runtime_opts or {}),
                                           topology=topology)
        elif backend == "sim":
            if spec is None and topology is not None:
                spec = topology.to_cluster_spec()
            if spec is None or profile is None:
                raise ValueError(
                    "sim backend needs spec= (or topology=) and profile=")
            if n_servers is not None and n_servers != spec.n:
                raise ValueError(
                    f"n_servers={n_servers} != spec.n={spec.n}")
            if topology is not None and topology.n != spec.n:
                raise ValueError(
                    f"topology has {topology.n} servers, spec has {spec.n}")
            n_servers = spec.n
            self.backend = _SimBackend(spec, profile, plan, controller,
                                       router, tasks, seed, ratio_bucket,
                                       topology=topology)
        else:
            raise ValueError(
                f"unknown backend {backend!r}: expected 'runtime' or 'sim'")
        self.backend_name = backend
        self.n_servers = n_servers
        self.controller = controller
        self.topology = topology
        self.handles: list[RequestHandle] = []

    # -- the portable surface ------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        h = self.backend.submit(request)
        self.handles.append(h)
        return h

    def step(self) -> bool:
        """Advance the cluster one unit of its backend clock."""
        return self.backend.step()

    def run(self) -> list[RequestHandle]:
        """Serve until every submitted request finished; returns all
        handles in submission order."""
        self.backend.run()
        return self.handles

    @property
    def migrations(self) -> list:
        return self.backend.migrations

    @property
    def events(self) -> list[Event]:
        """Cluster-level structured events (``rid = -1``): the staged
        migration lifecycle of the shared control plane, in clock order —
        ``MIGRATION_STARTED`` when a review adopts a plan and schedules
        its transfers, ``MIGRATION_COMPLETED`` when the transfers finish
        and the plan becomes active."""
        out: list[Event] = []
        ctrl = self.controller
        for e in (ctrl.events if ctrl is not None else []):
            if e.get("staged"):
                out.append(Event(EventType.MIGRATION_STARTED, -1,
                                 e["time"], dict(e)))
            elif e.get("reason") == "migration-complete":
                out.append(Event(EventType.MIGRATION_COMPLETED, -1,
                                 e["time"], dict(e)))
        return out

    def _net_metrics(self) -> dict | None:
        """The ``metrics()["net"]`` payload: per-link dispatch bytes from
        the traffic meter, staged-migration totals from the controller's
        event log, and the heterogeneous per-server budget caps."""
        meter = getattr(self.backend, "meter", None)
        if meter is None:
            return None
        out = meter.summary()
        eb = self.backend._expert_bytes()
        out["per_server_mem_gb"] = [
            round(p.mem_bytes / 1e9, 3) for p in self.topology.profiles]
        out["per_server_expert_budget"] = [
            int(b) for b in self.topology.expert_budgets(eb)]
        ctrl_events = (self.controller.events
                       if self.controller is not None else [])
        staged = [e for e in ctrl_events if e.get("staged")]
        comp = [e for e in ctrl_events
                if e.get("reason") == "migration-complete"]
        out["migrations"] = {
            "staged": len(staged),
            "completed": len(comp),
            "transfer_seconds": round(
                sum(e["transfer_seconds"] for e in comp), 6),
            "transfer_bytes": round(
                sum(e["transfer_bytes"] for e in comp), 3),
        }
        return out

    def metrics(self) -> dict:
        """Per-server serving metrics in one backend-agnostic shape:
        submitted/served/finished/redirected request counts, mean latency
        by origin (backend clock units) and the local-compute ratio. With
        a topology attached, a ``net`` section adds the per-link dispatch
        bytes, staged-migration totals and per-server budget caps."""
        N = self.n_servers
        submitted = np.zeros(N, int)
        served = np.zeros(N, int)
        finished = np.zeros(N, int)
        redirected = np.zeros(N, int)
        lat_sum = np.zeros(N)
        lat_n = np.zeros(N, int)
        for h in self.handles:
            o = h.request.origin
            s = h.server if h.server is not None else (o if o is not None
                                                       else 0)
            oo = o if o is not None else s
            submitted[oo] += 1
            served[s] += 1
            if o is not None and s != o:
                redirected[oo] += 1
            if h.done:
                finished[s] += 1
                lat = h.metrics.get("latency")
                if lat is not None:
                    lat_sum[oo] += lat
                    lat_n[oo] += 1
        mean_lat = np.where(lat_n > 0, lat_sum / np.maximum(lat_n, 1), 0.0)
        out = {
            "backend": self.backend_name,
            "clock": self.backend.clock,
            "n_servers": N,
            "per_server": {
                "submitted": submitted.tolist(),
                "served": served.tolist(),
                "finished": finished.tolist(),
                "redirected": redirected.tolist(),
                "mean_latency": [round(float(v), 6) for v in mean_lat],
                "local_ratio": [round(float(v), 6)
                                for v in self.backend.local_ratio()],
            },
            "redirected_total": int(redirected.sum()),
        }
        perf = getattr(self.backend, "perf", None)
        if perf is not None:
            # runtime backend only: AOT warmup cost, retrace/stall counters
            # and decode-round / TTFT wall-time percentiles (the sim
            # backend models time, so wall-clock perf is meaningless there)
            out["perf"] = perf()
        net = self._net_metrics()
        if net is not None:
            out["net"] = net
        return out


def requests_from_workload(workload) -> list[Request]:
    """Convert a ``repro.data.traces.Workload`` into the equivalent typed
    API request stream (synthetic prompts of the right length — the sim
    backend models time from token *counts*). Pass ``tasks=workload.tasks``
    to ``EdgeCluster`` so the activation profiles carry over too."""
    return [Request(prompt=np.zeros(max(r.prompt_tokens, 1), np.int32),
                    max_new_tokens=r.decode_tokens, origin=r.server,
                    arrival=r.arrival, task=r.task)
            for r in workload.requests]

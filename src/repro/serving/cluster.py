"""Edge-cluster description and the multi-server serving façade.

Two layers live here:

* the faithful testbed model of the paper (Sec. IV): ``ServerSpec`` /
  ``ClusterSpec`` / ``MoEProfile`` — N servers with different GPU
  counts/memory/compute, linked by rate-limited networking (testbed:
  500 Mbps via Linux tc). The event-driven simulator consumes it.
* ``EdgeCluster`` — the serving-API-v1 façade over the paper's headline
  scenario: N edge servers cooperatively serving one MoE model, one
  pluggable router, one shared ``PlacementController``, and **two
  interchangeable backends** selected by ``backend=``:

  - ``"runtime"`` — real jitted JAX engines (``ServingRuntime``), clock =
    scheduler ticks. Either one shared runtime with origin-tagged slots
    (default — one KV pool, the EP spec already spans the N servers) or N
    per-server runtimes (``shared_runtime=False``, per-server KV pools and
    decode batches, where memory allows).
  - ``"sim"`` — the event-driven ``EdgeSimulator`` time model, clock =
    seconds.

  Both consume the same typed ``repro.serving.api.Request`` stream and
  emit the same ``RequestHandle`` events, so policies, benchmarks and
  examples run identically against either world.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.serving.api import (Event, EventType, Request, RequestHandle,
                               SeqCounter, as_router)
from repro.serving.faults import (FaultSchedule, SERVER_DOWN, SERVER_JOINED,
                                  LINK_DEGRADED, apply_fault)
from repro.serving.net import Topology, TrafficMeter
from repro.serving.obs import NULL_TRACER, Registry, SpanKind, as_tracer


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """One simulated edge server's resource envelope (legacy scalar-link
    form; ``repro.serving.net.ServerProfile`` is the topology-aware
    successor). All fields are absolute units, not GB/GHz:
    ``mem_bytes`` is usable GPU memory for expert weights in **bytes**,
    ``compute_speed`` effective expert-matmul throughput in **FLOP/s**,
    ``io_speed`` local weight-load bandwidth in **bytes/s**."""

    name: str
    gpus: int = 1
    mem_bytes: float = 16e9            # usable GPU memory for experts
    compute_speed: float = 60e12       # effective FLOP/s for expert matmuls
    io_speed: float = 8e9              # weight-load bytes/s (PCIe/NVMe)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A uniform-interconnect cluster: N ``ServerSpec``s joined by one
    scalar link (``bandwidth`` in **bytes/s**, ``rtt`` per remote call in
    **seconds**). ``Topology.from_cluster_spec`` lifts this into the
    per-link matrix form the net subsystem uses."""

    servers: tuple[ServerSpec, ...]
    bandwidth: float = 500e6 / 8       # bytes/s between servers (500 Mbps)
    rtt: float = 2e-3                  # per-remote-call latency (s)

    @property
    def n(self) -> int:
        return len(self.servers)

    def expert_capacity(self, expert_bytes: float) -> np.ndarray:
        """Per-server total expert-slot budget (M_n / m_e of Algorithm 1)."""
        return np.array([int(s.mem_bytes // expert_bytes)
                         for s in self.servers])


@dataclasses.dataclass(frozen=True)
class MoEProfile:
    """Analytic per-token costs for one MoE model (drives the time model).

    Dimensionless architecture counts plus ``bytes_per_param`` (bytes per
    weight, 2.0 = bf16); everything derived is in absolute bytes/FLOPs so
    it divides cleanly by ``ServerProfile`` bandwidths (bytes/s) and
    compute speeds (FLOP/s)."""

    num_layers: int
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    bytes_per_param: float = 2.0

    @property
    def expert_bytes(self) -> float:
        """Weight bytes of ONE expert FFN (gate/up/down projections)."""
        return 3 * self.d_model * self.d_ff * self.bytes_per_param

    @property
    def expert_flops_per_token(self) -> float:
        """FLOPs one token costs in one expert (fwd matmuls only)."""
        return 2 * 3 * self.d_model * self.d_ff

    @property
    def dense_flops_per_token(self) -> float:
        # attention projections + attention math approximation per layer
        return 2 * 4 * self.d_model * self.d_model

    @property
    def hidden_bytes_per_token(self) -> float:
        """Bytes of one token's hidden-state activation (one link leg)."""
        return self.d_model * self.bytes_per_param

    @staticmethod
    def from_config(cfg) -> "MoEProfile":
        """Derive the profile from a ``repro.configs`` model config."""
        return MoEProfile(num_layers=cfg.num_layers,
                          num_experts=cfg.num_experts, top_k=cfg.top_k,
                          d_model=cfg.d_model, d_ff=cfg.d_ff)


def paper_testbed(mem_fraction: float = 1.0) -> ClusterSpec:
    """The paper's testbed: 3 simulated edge servers with GPU allocations
    1/1/2 (A100-40G), 500 Mbps interconnect. ``mem_fraction`` reproduces the
    paper's artificial memory constraint (0.7 for Mixtral, 0.3 for
    DeepSeek-V2-Lite)."""
    return ClusterSpec(servers=(
        ServerSpec("server1", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server2", gpus=1, mem_bytes=mem_fraction * 40e9,
                   compute_speed=50e12),
        ServerSpec("server3", gpus=2, mem_bytes=mem_fraction * 2 * 40e9,
                   compute_speed=100e12),
    ))


MIXTRAL_PROFILE = MoEProfile(num_layers=32, num_experts=8, top_k=2,
                             d_model=4096, d_ff=14336)
DEEPSEEK_V2_LITE_PROFILE = MoEProfile(num_layers=26, num_experts=64, top_k=8,
                                      d_model=2048, d_ff=1408)


# ---------------------------------------------------------------------------
# EdgeCluster: the serving-API-v1 façade over both execution worlds
# ---------------------------------------------------------------------------

class _RuntimeBackend:
    """N edge servers over the jitted JAX serving stack (clock = ticks).

    One shared ``ServingRuntime`` with origin-tagged slots (default: one KV
    pool — the engine's EP spec already spans the N servers), or N
    per-server runtimes (own pools and decode batches) when memory allows.
    The router picks the serving runtime in per-server mode; in shared
    mode admission is cluster-wide, so requests are recorded at their
    origin (round-robin for origin-less ones) and never redirected.
    The shared ``PlacementController`` is reviewed on the *cluster* tick
    clock, so per-server runtimes do not double-count reviews.
    """
    clock = "ticks"

    def __init__(self, engine, n_servers: int, router, controller,
                 shared_runtime: bool, runtime_opts: dict,
                 topology: Topology | None = None,
                 fault_schedule: FaultSchedule | None = None,
                 failover: bool = True, prefetch: bool = True,
                 slo_aware: bool = False, tracer=None, seq=None):
        from repro.serving.runtime import ServingRuntime   # lazy: keeps the
        #   sim world (simulator.py imports this module) free of jax
        self.engine = engine
        self.n = n_servers
        self.router = router
        self.controller = controller
        self.shared = shared_runtime
        self.topology = topology
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.seqc = seq if seq is not None else SeqCounter()
        if controller is not None and getattr(controller, "tracer",
                                              None) is None:
            controller.tracer = self.tracer
        n_ep = engine.rt.ep_spec.n_ep if engine.rt.ep_spec is not None else 1
        # per-origin stats attribution needs one EP rank per server; when
        # the engine cannot represent every origin, serve untagged (the
        # positional fallback) rather than mis-crediting traffic
        self.tag_origins = n_ep >= n_servers
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                controller.last_review = 0.0       # full first interval
            controller.attach_topology(topology,
                                       expert_bytes=self._expert_bytes())
        # -- expert tier hierarchy (host-RAM / modeled disk) ------------
        self.tiers = None
        if (topology is not None and topology.tiered
                and controller is not None):
            from repro.serving.tiers import TierManager
            eb = getattr(controller.cost, "expert_bytes", None)
            self.tiers = TierManager(
                topology, float(eb) if eb else self._expert_bytes(),
                prefetch=prefetch, clock_rate=controller.clock_rate,
                tracer=self.tracer)
            controller.tiers = self.tiers
            if controller.plan is not None:
                self.tiers.bind(controller.plan)   # pre-set plans (e.g.
                #   ctrl.plan = uniform_plan(...)) bypass _set_plan
        itemsize = np.dtype(engine.rt.dtype).itemsize
        self.meter = (TrafficMeter(topology,
                                   engine.rt.cfg.d_model * itemsize)
                      if topology is not None else None)
        if self.meter is not None:
            # the engine may have served before this cluster existed
            # (warmup generate(), a previous cluster): its lifetime stats
            # are not this cluster's dispatch traffic
            self.meter.seed(engine.stats.counts)
        opts = [dict(runtime_opts)
                for _ in range(1 if shared_runtime else n_servers)]
        if slo_aware:
            # every member runtime schedules deadline-first and sheds
            # unmeetable requests (an explicit runtime_opts wins)
            for o in opts:
                o.setdefault("slo_aware", True)
        if (not shared_runtime and topology is not None
                and "n_blocks" not in runtime_opts):
            # heterogeneous KV budgets: each server's paged pool is sized
            # by its own ServerProfile cap (per-position bytes estimated
            # as k+v full-width rows across the layers)
            bs = runtime_opts.get("block_size", 16)
            pos_bytes = (2.0 * engine.rt.cfg.num_layers
                         * engine.rt.cfg.d_model * itemsize)
            budgets = topology.kv_block_budgets(bs * pos_bytes)
            for s, o in enumerate(opts):
                o["n_blocks"] = 1 + int(budgets[s])
        self.runtimes = [
            ServingRuntime(engine, controller=None, tracer=self.tracer,
                           seq_counter=self.seqc,
                           tracer_server=(-1 if shared_runtime else s), **o)
            for s, o in enumerate(opts)]
        self.rounds = 0
        self._rr = 0                 # round-robin cursor (shared mode)
        self.migrations: list = []
        # -- satellite: metering must never fail silently --------------
        self.meter_skips = 0         # observe() calls skipped on a shape
        #   mismatch between the residency view and the engine's counts
        self._meter_skip_streak = 0
        self._meter_ok = 0           # successful observe() calls
        # -- fault injection / failover --------------------------------
        self.faults = fault_schedule
        self.failover = failover
        self.fault_events: list[Event] = []
        self.faults_injected = 0
        self.faults_recovered = 0    # crashes whose victims all finished
        self.tokens_lost = 0         # emitted tokens discarded (+ undelivered
        #                              remainder of dropped requests)
        self.requests_dropped = 0    # victims abandoned (failover=False)
        self.recovery_ticks = 0.0    # crash -> last-victim-finished, summed
        self._recovering: list[tuple[float, list[RequestHandle]]] = []

    @property
    def sheds(self) -> int:
        """Requests shed by the members' SLO-aware admission (0 unless
        ``slo_aware``)."""
        return sum(r.sheds for r in self.runtimes)

    def _alive(self) -> np.ndarray:
        """[N] bool liveness (all-up without a topology)."""
        if self.topology is None:
            return np.ones(self.n, bool)
        return np.asarray(self.topology.state.up, bool)

    def _next_live_rr(self, alive: np.ndarray) -> int:
        """Advance the shared-mode round-robin cursor to the next live
        server (identical to the plain cursor while every server is up)."""
        for _ in range(self.n):
            s = self._rr
            self._rr = (self._rr + 1) % self.n
            if alive[s]:
                return s
        raise RuntimeError("no live servers in the cluster")

    def _expert_bytes(self) -> float:
        cfg = self.engine.rt.cfg
        return float(3 * cfg.d_model * cfg.d_ff
                     * np.dtype(self.engine.rt.dtype).itemsize)

    def _residency(self) -> np.ndarray | None:
        """[L, N, E] residency for the traffic meter: the controller's
        active plan, falling back to the engine's live placement tables
        (controller-less clusters still meter their dispatch traffic)."""
        ctrl = self.controller
        if ctrl is not None and ctrl.plan is not None:
            return ctrl.plan.residency()
        pl = self.engine.placement
        if pl is None:
            return None
        s2e = np.asarray(pl.slot_to_expert)          # [G, n_ep, S]
        G, N, _ = s2e.shape
        E = self.engine.rt.cfg.num_experts
        res = np.zeros((G, N, E))
        for l in range(G):
            for n in range(N):
                for e in s2e[l, n]:
                    if e >= 0:
                        res[l, n, int(e)] = 1.0
        return res

    def loads(self) -> np.ndarray:
        """[N] backlog estimate (queued + active) per server."""
        return np.array([len(r.queue) + r.active for r in self.runtimes],
                        float)

    def submit(self, req: Request) -> RequestHandle:
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site (the sim backend's contract too) —
            # not as an IndexError in routing or metrics()
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        alive = self._alive()
        if self.shared:
            # one pool serves the whole cluster: there is no routing
            # decision to make, so record the origin (round-robin for
            # origin-less requests) rather than reporting a degenerate
            # argmin-of-equal-loads that would pin metrics to server 0;
            # a crashed origin falls back to the live round-robin
            if req.origin is not None and alive[req.origin]:
                server = req.origin
            else:
                server = self._next_live_rr(alive)
            rtm = self.runtimes[0]
        else:
            loads = np.where(alive, self.loads(), np.inf)
            origin = (req.origin
                      if req.origin is not None and alive[req.origin]
                      else None)
            server = self.router.route(origin, loads)
            if not alive[server]:
                # a custom router ignored the inf load; never enqueue
                # onto a dead server
                server = int(np.argmin(loads))
            rtm = self.runtimes[server]
        if self.tag_origins:
            origin = (req.origin
                      if req.origin is not None and alive[req.origin]
                      else server)
        else:
            origin = None
        handle = rtm.enqueue(dataclasses.replace(req, origin=origin))
        handle.request = req      # keep the caller's origin for metrics
        handle.server = server
        return handle

    @property
    def pending(self) -> bool:
        return any(r.queue or r.active or r._pending
                   for r in self.runtimes)

    def step(self) -> bool:
        had = self.pending
        now = self.rounds + 1          # the tick this call serves
        if self.faults is not None:
            for ev in self.faults.due(now):
                self._apply_fault(ev, now)
        # residency BEFORE the round: this tick's dispatch rides the
        # incumbent tables even when the review below completes a staged
        # migration, so its bytes meter against the old links
        res_before = self._residency() if self.meter is not None else None
        for rtm in self.runtimes:
            rtm.step()
        self.rounds += 1
        ctrl = self.controller
        if ctrl is not None:
            dec = ctrl.review_and_apply(self.rounds, self.engine)
            if dec is not None and dec.applied:
                self.migrations.append(dec.diag)
        tm = self.tiers
        if tm is not None:
            landed = tm.promotions
            tm.poll(self.rounds)
            if (tm.promotions != landed and ctrl is not None
                    and ctrl.plan is not None):
                # promotions change which experts are GPU-resident: refresh
                # the engine's slot tables under the new tier priority
                ctrl._apply_plan(self.engine)
            tm.observe(self.engine.stats.counts, now=self.rounds)
            tm.prefetch_step(self.rounds)
        if self.meter is not None and res_before is not None:
            if res_before.shape == self.engine.stats.counts.shape:
                # engine.stats is the engine's own plain accumulator (the
                # meter needs true cumulative volumes, never a
                # user-supplied EMA-decayed tracker)
                self.meter.observe(self.engine.stats.counts, res_before)
                self._meter_ok += 1
                self._meter_skip_streak = 0
            else:
                # previously a silent pass: a persistently mismatched
                # residency view meant metrics()["net"] reported zero
                # dispatch bytes with no hint anything was wrong
                self.meter_skips += 1
                self._meter_skip_streak += 1
                if self._meter_ok == 0 and self._meter_skip_streak >= 32:
                    raise RuntimeError(
                        f"traffic metering skipped {self._meter_skip_streak}"
                        " consecutive ticks and never once succeeded: the "
                        f"residency view {res_before.shape} cannot match "
                        "the engine's activation counts "
                        f"{self.engine.stats.counts.shape} — the "
                        "controller's plan granularity does not fit this "
                        "engine (metrics()['net'] would silently read 0)")
        self._check_recovered()
        return had

    # -- fault injection / failover ------------------------------------
    def _apply_fault(self, ev, now: float) -> None:
        """Consume one due ``FaultEvent``: flip the shared link state,
        evict + re-route (or drop) the victims of a crash, and trigger
        the controller's fault review around the capacity change."""
        apply_fault(ev, self.topology, tracer=self.tracer, now=now)
        self.faults_injected += 1
        ctrl = self.controller
        data = ev.payload()
        if ev.kind == SERVER_DOWN:
            data.update(self._fail_server(ev.server, now))
            if self.tiers is not None:
                # the crash loses the server's host/disk tiers too; the
                # fault review below rebinds tiered residency on survivors
                self.tiers.drop_server(ev.server)
            if ctrl is not None and self.failover:
                dec = ctrl.fault_review_and_apply(now, self.engine,
                                                  cause="server-down")
                if dec.applied:
                    self.migrations.append(dec.diag)
        elif ev.kind == SERVER_JOINED:
            # capacity appeared: re-review (gated on no in-flight
            # migration — the next periodic review will expand otherwise)
            if ctrl is not None and self.failover and ctrl.pending is None:
                dec = ctrl.review(now, force=True)
                if dec.adopted and not dec.staged:
                    if ctrl._apply_plan(self.engine):
                        self.migrations.append(dec.diag)
        elif ev.kind == LINK_DEGRADED:
            # an in-flight migration priced on the old bandwidth has a
            # stale eta (or a dead link): abort and re-plan immediately
            if (ctrl is not None and ctrl.pending is not None
                    and ctrl.pending_affected()):
                dec = ctrl.fault_review_and_apply(now, self.engine,
                                                  cause="link-degraded")
                if dec.applied:
                    self.migrations.append(dec.diag)
        self.fault_events.append(
            Event(getattr(EventType, ev.kind), -1, now, data, self.seqc()))

    def _fail_server(self, server: int, now: float) -> dict:
        """Evict every request the crashed server was serving. With
        failover, victims re-route through the router (dead servers at
        inf load) and re-prefill from scratch under their original
        handles — cheap when the radix cache still holds their prefix
        pages elsewhere; without it they are dropped (the no-failover
        baseline). Returns the crash event's bookkeeping payload."""
        victims: list[tuple] = []      # (runtime, rid, handle)
        rtms = self.runtimes if self.shared else [self.runtimes[server]]
        for rtm in rtms:
            for rid, h in list(rtm.handles.items()):
                if h.done or h.server != server:
                    continue
                victims.append((rtm, rid, h))
        alive = self._alive()
        lost = 0
        reassigned: list[int] = []
        recovering: list[RequestHandle] = []
        for rtm, rid, h in victims:
            done_tokens = rtm.evict(rid)
            lost += done_tokens
            req = h.request
            if not self.failover:
                self.requests_dropped += 1
                lost += req.max_new_tokens - done_tokens   # never delivered
                continue
            loads = np.where(alive, self.loads(), np.inf)
            origin = (req.origin
                      if req.origin is not None and alive[req.origin]
                      else None)
            new_server = self.router.route(origin, loads)
            if not alive[new_server]:
                new_server = int(np.argmin(loads))
            h._tokens.clear()          # the stream restarts from scratch
            h.server = new_server
            tagged = new_server if self.tag_origins else None
            target = (self.runtimes[0] if self.shared
                      else self.runtimes[new_server])
            target.enqueue(dataclasses.replace(req, origin=tagged),
                           handle=h)
            h.request = req            # keep the caller's origin for metrics
            reassigned.append(new_server)
            recovering.append(h)
            if self.tracer.enabled:
                # h.rid is the fresh re-admit rid the victim's remaining
                # spans will carry on the surviving server
                self.tracer.instant(SpanKind.FAILOVER_REPREFILL, now,
                                    rid=h.rid, server=new_server,
                                    from_server=server,
                                    tokens_lost=done_tokens)
        self.tokens_lost += lost
        if recovering:
            self._recovering.append((now, recovering))
        return {"victims": len(victims), "tokens_lost": lost,
                "reassigned": reassigned, "failover": self.failover}

    def _check_recovered(self) -> None:
        """A crash counts as recovered once every re-routed victim has
        finished; the elapsed ticks are the crash's recovery time."""
        for rec in self._recovering[:]:
            t0, victims = rec
            if all(h.done for h in victims):
                self.faults_recovered += 1
                self.recovery_ticks += self.rounds - t0
                self._recovering.remove(rec)

    def faults_metrics(self) -> dict | None:
        """The ``metrics()["faults"]`` section (None without a schedule).
        ``recovery_seconds`` converts ticks via the controller's
        ``clock_rate`` (seconds per tick, default 1.0)."""
        if self.faults is None:
            return None
        rate = (self.controller.clock_rate
                if self.controller is not None else 1.0)
        return {"injected": self.faults_injected,
                "recovered": self.faults_recovered,
                "tokens_lost": int(self.tokens_lost),
                "recovery_seconds": round(self.recovery_ticks * rate, 6),
                "requests_dropped": self.requests_dropped,
                "failover": self.failover}

    def run(self) -> None:
        while self.pending:
            self.step()
        for rtm in self.runtimes:
            rtm.flush()

    def perf(self) -> dict:
        """Cluster-wide ``metrics.perf`` section: warmup cost and retrace/
        stall counters summed over the member runtimes, decode-round and
        TTFT wall-time percentiles pooled over every round they served."""
        rounds: list[float] = []
        ttft: list[float] = []
        for r in self.runtimes:
            rounds.extend(r.decode_round_s)
            ttft.extend(r.ttft_s)

        def pct(xs):
            if not xs:
                return {"p50": 0.0, "p99": 0.0}
            return {"p50": round(float(np.percentile(xs, 50)) * 1e3, 6),
                    "p99": round(float(np.percentile(xs, 99)) * 1e3, 6)}
        return {
            "warmup_seconds": round(sum(r.warmup_seconds
                                        for r in self.runtimes), 6),
            "executables_compiled": sum(r.executables_compiled
                                        for r in self.runtimes),
            "traces_after_warmup": sum(r.traces_after_warmup
                                       for r in self.runtimes),
            "host_syncs": sum(r.host_syncs for r in self.runtimes),
            "rounds_timed": sum(r.decode_round_s.count
                                for r in self.runtimes),
            "decode_round_ms": pct(rounds),
            "ttft_ms": pct(ttft),
        }

    def local_ratio(self) -> np.ndarray:
        """[N] observed local-compute ratio per origin server: activation
        mass that landed on experts resident at the origin, under the
        controller's active plan."""
        ctrl = self.controller
        if (not self.tag_origins or ctrl is None or ctrl.plan is None
                or self.engine.rt.ep_spec is None):
            return np.ones(self.n)
        counts = self.engine.stats.counts          # [L, n_ep, E]
        res = ctrl.plan.residency() > 0            # [L, N, E]
        if res.shape != counts.shape:
            return np.ones(self.n)
        out = np.ones(self.n)
        for s in range(self.n):
            tot = counts[:, s, :].sum()
            if tot > 0:
                out[s] = (counts[:, s, :] * res[:, s, :]).sum() / tot
        return out


class _SimBackend:
    """N edge servers over the event-driven time model (clock = seconds).

    Typed requests become simulator arrivals: ``len(prompt)`` prompt
    tokens, ``max_new_tokens`` decode tokens, ``task`` selecting the
    activation profile, ``arrival``/``origin`` the arrival process. The
    simulator models time, not tokens, so handles get ADMITTED/FINISHED
    events (with latency + locality metrics) but no TOKEN events.
    """
    clock = "seconds"

    def __init__(self, spec: ClusterSpec, profile: MoEProfile, plan,
                 controller, router, tasks: dict | None, seed: int,
                 ratio_bucket: float, topology: Topology | None = None,
                 fault_schedule: FaultSchedule | None = None,
                 failover: bool = True, prefetch: bool = True,
                 slo_aware: bool = False, tracer=None, seq=None):
        from repro.data.traces import Workload     # numpy-only
        from repro.serving.simulator import EdgeSimulator   # lazy: this
        #   module is imported by simulator.py (no import cycle at load)
        self.profile = profile
        self.seed = seed
        self.topology = topology
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.seqc = seq if seq is not None else SeqCounter()
        if controller is not None and getattr(controller, "tracer",
                                              None) is None:
            controller.tracer = self.tracer
        self.workload = Workload(requests=[], tasks=dict(tasks or {}),
                                 duration=0.0)
        self.sim = EdgeSimulator(spec, profile, self.workload, plan=plan,
                                 controller=controller, router=router,
                                 seed=seed, ratio_bucket=ratio_bucket,
                                 topology=topology)
        self.controller = controller
        # -- expert tier hierarchy (host-RAM / modeled disk) ------------
        self.tiers = None
        if (topology is not None and topology.tiered
                and controller is not None):
            from repro.serving.tiers import TierManager
            eb = getattr(controller.cost, "expert_bytes", None)
            self.tiers = TierManager(
                topology, float(eb) if eb else profile.expert_bytes,
                prefetch=prefetch, clock_rate=1.0,   # seconds clock
                tracer=self.tracer)
            controller.tiers = self.tiers
            if controller.plan is not None:
                self.tiers.bind(controller.plan)
            self.sim.time_model.tiers = self.tiers   # fetch-stall pricing
        self.meter = (TrafficMeter(topology, profile.hidden_bytes_per_token)
                      if topology is not None else None)
        self.n = spec.n
        self._pending: list = []       # heap of (arrival, seq, sim_req, h)
        self._seq = 0
        self.faults = fault_schedule
        self.failover = failover
        # the no-failover baseline keeps serving survivors under the
        # pre-crash time model (dead residency unmasked): its cost is the
        # dropped requests, not an unserviceable-expert stall
        self.sim.mask_dead_residency = failover
        self.fault_events: list[Event] = []
        self.faults_injected = 0
        self.faults_recovered = 0      # crashes whose recovery plan landed
        self.tokens_lost = 0           # undelivered tokens of dropped reqs
        self.requests_dropped = 0
        self.recovery_seconds = 0.0    # crash -> recovery-migration eta
        # -- SLO-aware admission (the time model's slo_admission rule) --
        self.slo_aware = bool(slo_aware)
        self.sheds = 0                 # requests shed (no server in time)
        self.deadline_redirects = 0    # served elsewhere to make the SLO

    def _task_probs(self, name: str) -> None:
        from repro.data.traces import make_task_profile
        if name not in self.workload.tasks:
            self.workload.tasks[name] = make_task_profile(
                name, self.profile.num_layers, self.profile.num_experts,
                seed=self.seed)

    def submit(self, req: Request) -> RequestHandle:
        from repro.data.traces import Request as SimRequest
        if req.origin is not None and not 0 <= req.origin < self.n:
            # fail at the submit site, not as an IndexError mid-simulation
            raise ValueError(
                f"origin {req.origin} out of range for {self.n} server(s)")
        task = req.task if req.task is not None else "default"
        self._task_probs(task)
        arrival = float(req.arrival) if req.arrival is not None else 0.0
        # origin-less requests get their server at *serve* time (step()),
        # when the router can see the live timeline; -1 marks them here
        sim_req = SimRequest(arrival=arrival,
                             server=req.origin if req.origin is not None
                             else -1,
                             task=task, prompt_tokens=len(req.prompt),
                             decode_tokens=req.max_new_tokens)
        handle = RequestHandle(self._seq, req, clock="seconds",
                               seq=self.seqc)
        handle.submitted_at = arrival
        heapq.heappush(self._pending, (arrival, self._seq, sim_req, handle))
        self._seq += 1
        return handle

    @property
    def pending(self) -> bool:
        return bool(self._pending)

    def _alive(self) -> np.ndarray:
        if self.topology is None:
            return np.ones(self.n, bool)
        return np.asarray(self.topology.state.up, bool)

    def step(self) -> bool:
        """Serve the earliest pending arrival (event-driven: one request is
        one event). Faults due at or before the arrival are applied first,
        so a crash mid-workload re-routes (or drops) everything that
        arrives after it."""
        if not self._pending:
            return False
        self.sim.start()
        arrival, _, sim_req, handle = heapq.heappop(self._pending)
        if self.faults is not None:
            for ev in self.faults.due(arrival):
                self._apply_fault(ev, ev.time)
        ctrl = self.controller
        if (ctrl is not None and ctrl.pending is not None
                and self.sim.uncovered_live_experts()):
            # a crash left experts with no live replica: requests stall
            # until the recovery migration's transfers land (the modeled
            # analogue of re-prefilling after the failover re-placement)
            arrival = max(arrival, ctrl.pending.eta)
            self.sim.poll_migration(arrival)
            sim_req = dataclasses.replace(sim_req, arrival=arrival)
        # residency BEFORE this event: the request's dispatch is routed
        # under the incumbent plan even when serving it completes a staged
        # migration, so its bytes must meter against the old links
        res_before = (None if self.sim._res is None
                      else self.sim._res.copy())
        alive = self._alive()
        if sim_req.server >= 0 and not alive[sim_req.server]:
            if not self.failover:
                # no-failover baseline: the dead server's arrivals are
                # abandoned — every token they owed is lost
                self.requests_dropped += 1
                self.tokens_lost += sim_req.decode_tokens
                return True
            sim_req = dataclasses.replace(sim_req, server=-1)
        if sim_req.server < 0:
            # origin-less (or failed-over): the router assigns the server
            # against the live timeline, dead servers at inf load
            loads = np.where(alive, self.sim.loads(arrival), np.inf)
            n = self.sim.router.route(None, loads)
            if not alive[n]:
                n = int(np.argmin(loads))
            sim_req = dataclasses.replace(sim_req, server=n)
        slo = handle.request.slo
        # submit-time arrival, NOT the (possibly fault-fast-forwarded)
        # local `arrival`: the SLO verdict and the handle-facing latency
        # are measured on the backend clock the caller submitted on
        sub = (handle.submitted_at if handle.submitted_at is not None
               else arrival)
        if self.slo_aware and slo is not None:
            from repro.serving.simulator import slo_admission
            deadline = sub + slo
            loads = np.where(alive, self.sim.loads(arrival), np.inf)
            verdict, n = slo_admission(sim_req.server, loads, deadline)
            if verdict == "shed":
                # no live server can even *start* by the deadline —
                # admitting would burn timeline another request could use
                self.sheds += 1
                if self.tracer.enabled:
                    self.tracer.span(SpanKind.QUEUE_WAIT, sub, arrival,
                                     rid=handle.rid, shed=True)
                    self.tracer.instant(
                        SpanKind.SHED, arrival, rid=handle.rid,
                        deadline=deadline,
                        earliest_start=float(loads.min()))
                handle._emit(EventType.SHED, arrival, deadline=deadline,
                             earliest_start=float(loads.min()))
                handle._emit(
                    EventType.FINISHED, arrival,
                    tokens=0, origin=handle.request.origin, server=None,
                    latency=arrival - sub, wait=None, deferred_ticks=0,
                    prefix_tokens_skipped=0, local_frac=None,
                    slo=slo, slo_met=False, shed=True)
                return True
            if verdict == "redirect":
                self.deadline_redirects += 1
                sim_req = dataclasses.replace(sim_req, server=n)
        rec = self.sim.serve_request(sim_req)
        handle._emit(EventType.ADMITTED, rec["start"], server=rec["server"])
        if self.tracer.enabled:
            # phase split mirroring workload._ttft_itl: the modeled
            # latency spreads uniformly over prompt + decode tokens, so
            # prefill covers the first prompt_tokens shares of service
            rid, srv = handle.rid, int(rec["server"])
            T = sim_req.prompt_tokens
            toks = sim_req.decode_tokens
            itl = max(rec["done"] - rec["start"], 0.0) / max(T + toks, 1)
            split = rec["start"] + itl * T
            self.tracer.span(SpanKind.QUEUE_WAIT, sub, rec["start"],
                             rid=rid, server=srv)
            self.tracer.span(SpanKind.PREFILL_CHUNK, rec["start"], split,
                             rid=rid, server=srv, prompt_tokens=T)
            self.tracer.span(SpanKind.DECODE_ROUND, split, rec["done"],
                             rid=rid, server=srv, tokens=toks)
        latency = rec["done"] - sub
        handle._emit(
            EventType.FINISHED, rec["done"],
            tokens=handle.request.max_new_tokens, origin=handle.request.origin,
            server=rec["server"], latency=latency,
            wait=rec["start"] - sub, deferred_ticks=0,
            prefix_tokens_skipped=0,
            local_frac=(rec["hits"] / rec["tot"] if rec["tot"] else None),
            slo=slo,
            slo_met=(bool(latency <= slo)
                     if slo is not None else None),
            shed=False)
        if self.meter is not None and res_before is not None:
            # _dispatch_counts, not the controller's (possibly EMA-decayed,
            # possibly pre-primed) ActivationStats: metering needs the true
            # cumulative per-origin volumes
            self.meter.observe(self.sim._dispatch_counts, res_before)
        if self.tiers is not None:
            done = rec["done"]
            self.tiers.poll(done)
            self.tiers.observe(self.sim._dispatch_counts, now=done)
            self.tiers.prefetch_step(done)
        return True

    def run(self) -> None:
        while self.step():
            pass

    # -- fault injection / failover ------------------------------------
    def _apply_fault(self, ev, now: float) -> None:
        """Consume one due ``FaultEvent``: flip the shared link state and
        trigger the controller's recovery response. The no-failover
        baseline skips the recovery (and the simulator keeps serving the
        survivors under the pre-crash time model — only the dead server's
        arrivals are lost)."""
        apply_fault(ev, self.topology, tracer=self.tracer, now=now)
        self.faults_injected += 1
        ctrl = self.controller
        data = ev.payload()
        data["failover"] = self.failover
        if ev.kind == SERVER_DOWN and self.failover and self.tiers is not None:
            # host/disk tiers die with the box (the crash-oblivious
            # no-failover baseline keeps its pre-crash model instead)
            self.tiers.drop_server(ev.server)
        if ev.kind == SERVER_DOWN and self.failover and ctrl is not None:
            dec = ctrl.fault_review(now, cause="server-down")
            self._note_decision(dec, now)
            if dec.staged:
                self.recovery_seconds += float(dec.diag["eta"]) - now
            if dec.adopted:
                self.faults_recovered += 1
        elif ev.kind == SERVER_JOINED and self.failover and ctrl is not None:
            if ctrl.pending is None:
                self._note_decision(ctrl.review(now, force=True), now)
        elif ev.kind == LINK_DEGRADED and ctrl is not None:
            if ctrl.pending is not None and ctrl.pending_affected():
                self._note_decision(
                    ctrl.fault_review(now, cause="link-degraded"), now)
        self.fault_events.append(
            Event(getattr(EventType, ev.kind), -1, now, data, self.seqc()))

    def _note_decision(self, dec, now: float) -> None:
        if not dec.adopted:
            return
        if dec.staged:
            self.sim._migrations.append({
                "time": now, "staged": True, "eta": dec.diag["eta"],
                "transfers": dec.diag["transfers"],
                "transfer_bytes": dec.diag["transfer_bytes"]})
        else:
            self.sim.adopt_plan(dec.plan)

    def faults_metrics(self) -> dict | None:
        if self.faults is None:
            return None
        return {"injected": self.faults_injected,
                "recovered": self.faults_recovered,
                "tokens_lost": int(self.tokens_lost),
                "recovery_seconds": round(self.recovery_seconds, 6),
                "requests_dropped": self.requests_dropped,
                "failover": self.failover}

    @property
    def migrations(self) -> list:
        self.sim.start()
        return self.sim._migrations

    def local_ratio(self) -> np.ndarray:
        return self.sim.local_ratio_by_server()

    def _expert_bytes(self) -> float:
        return self.profile.expert_bytes


class EdgeCluster:
    """Serving API v1 façade: N edge servers, one router, one shared
    placement control plane, two interchangeable backends.

    backend:        ``"runtime"`` (jitted JAX engines, tick clock) or
                    ``"sim"`` (event-driven time model, seconds clock).
    n_servers:      cluster size (runtime backend: defaults to the engine's
                    EP rank count; sim backend: ``spec.n``).
    router:         ``repro.serving.api.Router`` instance or name
                    (``"home"`` / ``"least-loaded"``); default home-server
                    routing (the paper's arrival semantics).
    controller:     the shared ``PlacementController`` (optional for the
                    runtime backend; the sim backend needs it or ``plan``).
    engine:         runtime backend — the ``ServingEngine`` the cluster
                    serves with.
    shared_runtime: runtime backend — one origin-tagged runtime (default)
                    vs one ``ServingRuntime`` (own KV pool/decode batch)
                    per server.
    runtime_opts:   runtime backend — kwargs forwarded to each
                    ``ServingRuntime`` (max_slots, block_size,
                    ``warmup=True`` for the AOT bucket ladder + zero-stall
                    loop, ...); ``metrics()["perf"]`` aggregates the
                    members' warmup/retrace/stall/latency counters.
    spec/profile:   sim backend — ``ClusterSpec`` + ``MoEProfile``.
    plan:           sim backend — static ``PlacementPlan`` (alternative to
                    a controller).
    tasks:          sim backend — {name: TaskProfile} activation profiles
                    (unknown task names get a generated profile).
    topology:       optional ``repro.serving.net.Topology`` — one shared
                    link-cost model for both backends: per-(src, dst)
                    dispatch byte metering (``metrics()["net"]``),
                    bandwidth-aware *staged* migration on the shared
                    controller, per-link comm pricing in the sim time
                    model, and (``shared_runtime=False``) per-server KV
                    pools sized by each ``ServerProfile``'s memory cap.
                    The sim backend can derive ``spec`` from it. Defaults
                    to the controller's topology when it carries one. The
                    runtime backend's tick clock converts modeled transfer
                    *seconds* via ``controller.clock_rate`` (seconds per
                    tick, default 1.0) — set it on the controller when a
                    decode round is far from one second.
    fault_schedule: optional ``repro.serving.faults.FaultSchedule`` —
                    deterministic timed server crashes / rejoins and link
                    degradations, consumed from the backend's own clock
                    (requires ``topology=``: faults mutate its shared
                    ``LinkState``). ``metrics()["faults"]`` reports
                    injected/recovered counts, tokens lost and recovery
                    time; ``events`` carries one record per consumed
                    fault. Two runs of the same schedule (``.copy()`` it —
                    consumption advances a cursor) are bit-identical.
    failover:       fault response (default True): a crashed server's
                    in-flight requests re-route through the router and
                    re-prefill under their original handles, and the
                    controller force-reviews placement around the lost
                    capacity. ``failover=False`` is the measurement
                    baseline — victims are dropped and every token they
                    owed counts as lost.
    slo_aware:      SLO-aware scheduling (default False). Runtime backend:
                    every member ``ServingRuntime`` admits
                    earliest-deadline-first instead of FIFO and *sheds*
                    requests whose ``slo`` deadline became unmeetable
                    (``SHED`` event, then a terminal
                    ``FINISHED(tokens=0, shed=True, slo_met=False)``).
                    Sim backend: the time model's ``slo_admission`` rule —
                    shed when no live server can start by the deadline,
                    redirect to the earliest-start server when the routed
                    one would start too late. Off by default: the
                    scheduling-oblivious FIFO baseline the goodput
                    benchmark compares against.
    prefetch:       expert-tier prefetching (default True). When the
                    topology carries tiered ``ServerProfile``s (host-RAM /
                    modeled-disk capacities behind the GPU) and a
                    controller is attached, a ``repro.serving.tiers
                    .TierManager`` splits each server's assigned experts
                    across its tiers and — with ``prefetch=True`` —
                    promotes hot back-tier experts into GPU residency
                    overlapped with decode. ``prefetch=False`` freezes the
                    bind-time split (cold experts keep paying on-demand
                    fetch stalls — the baseline leg of the oversized-model
                    benchmark). Surfaced as ``metrics()["tiers"]``.
    trace:          unified span tracing (default False — a no-op
                    ``NULL_TRACER``; the serving hot path pays one
                    attribute check). ``trace=True`` builds a
                    ``repro.serving.obs.Tracer`` on the backend's clock
                    and threads it through every emitter: per-request
                    spans (QUEUE_WAIT / PREFILL_CHUNK / DECODE_ROUND /
                    PREFIX_HIT / SHED / FAILOVER_REPREFILL /
                    COLD_FETCH_STALL), control-plane PLACEMENT_REVIEW
                    decisions with the full Eq.-4 cost breakdown,
                    per-link TRANSFER_TASK spans, FAULT consumptions and
                    tier PREFETCH promotions. Export with
                    ``export_trace(path)`` (Chrome-trace/Perfetto JSON);
                    self-accounting in ``metrics()["obs"]``. A
                    pre-built ``Tracer`` is accepted (its clock must
                    match the backend).
    """

    def __init__(self, backend: str = "runtime", *,
                 n_servers: int | None = None, router=None, controller=None,
                 engine=None, shared_runtime: bool = True,
                 runtime_opts: dict | None = None,
                 spec: ClusterSpec | None = None,
                 profile: MoEProfile | None = None, plan=None,
                 tasks: dict | None = None, seed: int = 0,
                 ratio_bucket: float = 60.0,
                 topology: Topology | None = None,
                 fault_schedule: FaultSchedule | None = None,
                 failover: bool = True, prefetch: bool = True,
                 slo_aware: bool = False, trace=False):
        router = as_router(router)
        # one cluster-wide event sequencer + span tracer, threaded through
        # every emitter (member runtimes / the simulator, the fault
        # injector, the controller, tiers), so merged streams have a
        # stable total order and one trace covers the whole cluster.
        # trace= takes False (default, the zero-overhead NULL_TRACER),
        # True (build a Tracer on the backend's clock) or a Tracer.
        self.seq = SeqCounter()
        self.tracer = as_tracer(
            trace, "ticks" if backend == "runtime" else "seconds")
        if controller is not None:
            topology = controller.attach_topology(topology)   # one shared
            #   link model between the cluster and the control plane
        if fault_schedule is not None and topology is None:
            # liveness/bandwidth state lives on the shared Topology; a
            # schedule without one would silently do nothing
            raise ValueError(
                "fault_schedule= needs a topology= (the faults mutate the "
                "shared Topology's LinkState)")
        if backend == "runtime":
            if engine is None:
                raise ValueError("runtime backend needs engine=")
            if n_servers is None:
                n_servers = (engine.rt.ep_spec.n_ep
                             if engine.rt.ep_spec is not None else 1)
            if topology is not None and topology.n != n_servers:
                raise ValueError(
                    f"topology has {topology.n} servers, cluster has "
                    f"{n_servers}")
            self.backend = _RuntimeBackend(engine, n_servers, router,
                                           controller, shared_runtime,
                                           dict(runtime_opts or {}),
                                           topology=topology,
                                           fault_schedule=fault_schedule,
                                           failover=failover,
                                           prefetch=prefetch,
                                           slo_aware=slo_aware,
                                           tracer=self.tracer,
                                           seq=self.seq)
        elif backend == "sim":
            if spec is None and topology is not None:
                spec = topology.to_cluster_spec()
            if spec is None or profile is None:
                raise ValueError(
                    "sim backend needs spec= (or topology=) and profile=")
            if n_servers is not None and n_servers != spec.n:
                raise ValueError(
                    f"n_servers={n_servers} != spec.n={spec.n}")
            if topology is not None and topology.n != spec.n:
                raise ValueError(
                    f"topology has {topology.n} servers, spec has {spec.n}")
            n_servers = spec.n
            self.backend = _SimBackend(spec, profile, plan, controller,
                                       router, tasks, seed, ratio_bucket,
                                       topology=topology,
                                       fault_schedule=fault_schedule,
                                       failover=failover,
                                       prefetch=prefetch,
                                       slo_aware=slo_aware,
                                       tracer=self.tracer,
                                       seq=self.seq)
        else:
            raise ValueError(
                f"unknown backend {backend!r}: expected 'runtime' or 'sim'")
        self.backend_name = backend
        self.n_servers = n_servers
        self.controller = controller
        self.topology = topology
        self.handles: list[RequestHandle] = []
        # controller decision records are drained into seq-stamped cluster
        # Events eagerly (each step) so the merged event stream keeps one
        # stable total order under (time, seq)
        self._ctrl_cursor = 0
        self._cluster_events: list[Event] = []
        # metrics() is assembled from one namespaced registry instead of
        # hand-merged dicts; a provider returning None drops its section
        self.registry = Registry()
        self.registry.register("cluster", self._cluster_metrics)
        self.registry.register(
            "perf", getattr(self.backend, "perf", None) or (lambda: None))
        self.registry.register("net", self._net_metrics)
        self.registry.register("tiers", self._tiers_metrics)
        self.registry.register("faults", self._faults_metrics)
        self.registry.register("obs", self._obs_metrics)

    # -- the portable surface ------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Route a typed ``Request`` to a server and enqueue it; returns
        its ``RequestHandle`` (events, tokens, per-request metrics)."""
        h = self.backend.submit(request)
        self.handles.append(h)
        return h

    def step(self) -> bool:
        """Advance the cluster one unit of its backend clock."""
        more = self.backend.step()
        self._drain_ctrl_events()
        return more

    def run(self) -> list[RequestHandle]:
        """Serve until every submitted request finished; returns all
        handles in submission order."""
        self.backend.run()
        self._drain_ctrl_events()
        return self.handles

    def export_trace(self, path: str) -> str:
        """Write this run's span trace as Chrome-trace/Perfetto JSON
        (requires ``trace=``); returns ``path``. Deterministic: two runs
        of the same inputs (``.copy()`` the fault schedule) produce
        byte-identical files."""
        return self.tracer.export(path)

    @property
    def migrations(self) -> list:
        """Adopted-plan records from the shared controller, oldest first
        (each: the review time on the backend clock plus the Eq.-4 diag)."""
        return self.backend.migrations

    @property
    def events(self) -> list[Event]:
        """Cluster-level structured events (``rid = -1``) in clock order:
        the staged migration lifecycle of the shared control plane —
        ``MIGRATION_STARTED`` when a review adopts a plan and schedules
        its transfers, ``MIGRATION_COMPLETED`` when the transfers finish,
        ``MIGRATION_ABORTED`` when a fault invalidated them in flight —
        merged with the consumed fault-injection events
        (``SERVER_DOWN``/``SERVER_JOINED``/``LINK_DEGRADED``/
        ``LINK_RESTORED``, payload: the fault fields plus the failover
        bookkeeping — victims, tokens lost, reassignments).

        Ordering contract: every event carries the cluster-wide monotonic
        ``seq`` stamp, and the merged list is sorted by ``(time, seq)`` —
        a *stable total order* that is identical on a deterministic
        rerun, even when control-plane and fault events coincide in
        time."""
        self._drain_ctrl_events()
        out = list(self._cluster_events)
        out.extend(getattr(self.backend, "fault_events", []))
        out.sort(key=lambda e: (e.time, e.seq))
        return out

    def _drain_ctrl_events(self) -> None:
        """Convert controller decision records appended since the last
        drain into cluster ``Event``s, stamping the cluster-wide sequence
        number. Plain reviews (no adoption) are skipped — they stay
        visible in ``controller.events`` and the trace."""
        ctrl = self.controller
        if ctrl is None:
            return
        recs = ctrl.events
        while self._ctrl_cursor < len(recs):
            e = recs[self._ctrl_cursor]
            self._ctrl_cursor += 1
            if e.get("staged"):
                t = EventType.MIGRATION_STARTED
            elif e.get("reason") == "migration-complete":
                t = EventType.MIGRATION_COMPLETED
            elif e.get("reason") == "migration-aborted":
                t = EventType.MIGRATION_ABORTED
            else:
                continue
            self._cluster_events.append(
                Event(t, -1, e["time"], dict(e), self.seq()))

    def _net_metrics(self) -> dict | None:
        """The ``metrics()["net"]`` payload: per-link dispatch bytes from
        the traffic meter, staged-migration totals from the controller's
        event log, and the heterogeneous per-server budget caps."""
        meter = getattr(self.backend, "meter", None)
        if meter is None:
            return None
        out = meter.summary()
        # observe() calls skipped on a residency/counts shape mismatch
        # (runtime backend; persistent mismatch raises in step())
        out["meter_skips"] = int(getattr(self.backend, "meter_skips", 0))
        eb = self.backend._expert_bytes()
        out["per_server_mem_gb"] = [
            round(p.mem_bytes / 1e9, 3) for p in self.topology.profiles]
        out["per_server_expert_budget"] = [
            int(b) for b in self.topology.expert_budgets(eb)]
        ctrl_events = (self.controller.events
                       if self.controller is not None else [])
        staged = [e for e in ctrl_events if e.get("staged")]
        comp = [e for e in ctrl_events
                if e.get("reason") == "migration-complete"]
        out["migrations"] = {
            "staged": len(staged),
            "completed": len(comp),
            "transfer_seconds": round(
                sum(e["transfer_seconds"] for e in comp), 6),
            "transfer_bytes": round(
                sum(e["transfer_bytes"] for e in comp), 3),
        }
        return out

    def _tiers_metrics(self) -> dict | None:
        """``metrics()["tiers"]``: per-server per-tier residency,
        promotion/demotion counts, prefetch-hit ratio and on-demand-fetch
        stalls (None without a tier hierarchy)."""
        tm = getattr(self.backend, "tiers", None)
        return tm.summary() if tm is not None else None

    def _faults_metrics(self) -> dict | None:
        """``metrics()["faults"]``: injected/recovered counts, tokens
        lost and recovery time (None without a fault schedule)."""
        fm = getattr(self.backend, "faults_metrics", None)
        return fm() if fm is not None else None

    def _obs_metrics(self) -> dict | None:
        """``metrics()["obs"]``: tracer self-accounting — span counts by
        kind, dropped-event count and recording overhead (None when
        tracing is off)."""
        return self.tracer.summary() if self.tracer.enabled else None

    def _cluster_metrics(self) -> dict:
        """The registry's ``cluster`` namespace: the backend-agnostic
        per-server serving metrics (splatted at the top level of
        ``metrics()`` for compatibility)."""
        N = self.n_servers
        submitted = np.zeros(N, int)
        served = np.zeros(N, int)
        finished = np.zeros(N, int)
        redirected = np.zeros(N, int)
        lat_sum = np.zeros(N)
        lat_n = np.zeros(N, int)
        for h in self.handles:
            o = h.request.origin
            s = h.server if h.server is not None else (o if o is not None
                                                       else 0)
            oo = o if o is not None else s
            submitted[oo] += 1
            served[s] += 1
            if o is not None and s != o:
                redirected[oo] += 1
            if h.done:
                finished[s] += 1
                if h.metrics.get("shed"):
                    # shed requests resolve without service: their
                    # (near-zero) latency is not a serving latency
                    continue
                lat = h.metrics.get("latency")
                if lat is not None:
                    lat_sum[oo] += lat
                    lat_n[oo] += 1
        mean_lat = np.where(lat_n > 0, lat_sum / np.maximum(lat_n, 1), 0.0)
        out = {
            "backend": self.backend_name,
            "clock": self.backend.clock,
            "n_servers": N,
            "per_server": {
                "submitted": submitted.tolist(),
                "served": served.tolist(),
                "finished": finished.tolist(),
                "redirected": redirected.tolist(),
                "mean_latency": [round(float(v), 6) for v in mean_lat],
                "local_ratio": [round(float(v), 6)
                                for v in self.backend.local_ratio()],
            },
            "redirected_total": int(redirected.sum()),
            "sheds": int(getattr(self.backend, "sheds", 0)),
        }
        return out

    def metrics(self) -> dict:
        """Per-server serving metrics in one backend-agnostic shape:
        submitted/served/finished/redirected request counts, mean latency
        by origin (backend clock units) and the local-compute ratio.
        Assembled from ``self.registry`` (one namespaced provider tree —
        ``cluster``/``perf``/``net``/``tiers``/``faults``/``obs``); the
        ``cluster`` namespace is splatted at the top level, providers
        returning None drop their section. With a topology attached the
        ``net`` section adds per-link dispatch bytes, staged-migration
        totals and per-server budget caps; ``trace=`` adds ``obs``."""
        tree = self.registry.collect()
        out = tree.pop("cluster")
        out.update(tree)
        return out


def requests_from_workload(workload) -> list[Request]:
    """Convert a ``repro.data.traces.Workload`` into the equivalent typed
    API request stream (synthetic prompts of the right length — the sim
    backend models time from token *counts*). Pass ``tasks=workload.tasks``
    to ``EdgeCluster`` so the activation profiles carry over too."""
    return [Request(prompt=np.zeros(max(r.prompt_tokens, 1), np.int32),
                    max_new_tokens=r.decode_tokens, origin=r.server,
                    arrival=r.arrival, task=r.task)
            for r in workload.requests]

"""DEPRECATED shim — the global scheduler's review logic now lives in
``repro.core.policies.PlacementController`` and the serving loop in
``repro.serving.runtime.ServingRuntime``.

``GlobalScheduler`` is kept for the legacy batch-clocked API
(``after_batch() -> bool``): it counts served batches, asks the unified
controller to review at the configured cadence, and applies adopted plans
to the engine. New code should construct a ``PlacementController`` plus a
``ServingRuntime`` (single server) or a ``repro.serving.cluster
.EdgeCluster`` (multi-server) and submit typed ``repro.serving.api
.Request`` objects — see serving/README.md ("Serving API v1") for the
migration table. Live adoption is ``PlacementController
.review_and_apply(now, engine)``, the same code path both consumers use."""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import numpy as np

from repro.core.migration import CostModel
from repro.core.placement import PlacementPlan, build_ep_placement
from repro.core.policies import ClusterView, PlacementController, get_policy
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class GlobalScheduler:
    engine: ServingEngine
    capacity: np.ndarray  # per-EP-rank slot budget
    cost: CostModel
    interval_batches: int = 8  # review period (batches ~ minutes)
    placement_fn: Callable | None = None  # freqs -> PlacementPlan
    _batches: int = 0

    def __post_init__(self):
        warnings.warn(
            "GlobalScheduler is deprecated: construct a "
            "core.policies.PlacementController plus a "
            "serving.runtime.ServingRuntime instead (see serving/README.md)",
            DeprecationWarning,
            stacklevel=3,
        )  # 3: through the generated dataclass __init__
        spec = self.engine.rt.ep_spec
        cluster = ClusterView(
            capacity=np.asarray(self.capacity),
            slots_cap=np.full(len(self.capacity), spec.slots),
        )
        self.ctrl = PlacementController(
            policy=self.placement_fn
            if self.placement_fn is not None
            else get_policy("dancemoe"),
            cost=self.cost,
            cluster=cluster,
            interval=self.interval_batches,
            stats=self.engine.stats,
        )
        self.events = self.ctrl.events

    @property
    def current_plan(self) -> PlacementPlan | None:
        return self.ctrl.plan

    def after_batch(self) -> bool:
        """Call once per served batch; returns True if a migration ran."""
        self._batches += 1
        if self._batches % self.interval_batches:
            return False
        dec = self.ctrl.review(self._batches, force=True)
        dec.diag["batch"] = self._batches
        if dec.adopted:
            stacked = build_ep_placement(dec.plan, self.engine.rt.ep_spec.slots)
            self.engine.migrate(stacked)
        return dec.adopted

"""Global scheduler (paper Fig. 4, left): maintains the system-wide view —
activation statistics per EP rank, placement strategy, and the migration
policy — and drives the serving engine.

The runtime reports gating statistics after every batch (``counts_per_rank``
from the MoE layer); the scheduler periodically re-runs the placement
pipeline and, when Eq. (4) favors it, instructs the engine to migrate."""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.migration import CostModel, should_migrate
from repro.core.placement import PlacementPlan, build_ep_placement, \
    dancemoe_placement
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class GlobalScheduler:
    engine: ServingEngine
    capacity: np.ndarray                  # per-EP-rank slot budget
    cost: CostModel
    interval_batches: int = 8             # review period (batches ~ minutes)
    placement_fn: Callable | None = None  # freqs -> PlacementPlan
    current_plan: PlacementPlan | None = None
    events: list = dataclasses.field(default_factory=list)
    _batches: int = 0

    def _place(self, freqs):
        if self.placement_fn is not None:
            return self.placement_fn(freqs)
        slots = np.full(len(self.capacity), self.engine.rt.ep_spec.slots)
        return dancemoe_placement(freqs, self.capacity, slots)

    def after_batch(self) -> bool:
        """Call once per served batch; returns True if a migration ran."""
        self._batches += 1
        if self._batches % self.interval_batches:
            return False
        freqs = self.engine.stats.freqs()
        candidate = self._place(freqs)
        if self.current_plan is None:
            adopt, diag = True, {"reason": "initial"}
        else:
            adopt, diag = should_migrate(self.current_plan, candidate,
                                         freqs, self.cost)
        diag = dict(diag)
        diag["batch"] = self._batches
        diag["adopted"] = adopt
        self.events.append(diag)
        if adopt:
            self.current_plan = candidate
            stacked = build_ep_placement(candidate,
                                         self.engine.rt.ep_spec.slots)
            self.engine.migrate(stacked)
        return adopt

"""JAX serving engine: batched prefill + decode with the placement-aware EP
MoE layer, activation-stats collection, and zero-recompile placement
migration (the placement tables are jit arguments; migrating re-gathers the
EP weight slots from the dense master copy — the on-device analogue of the
paper's expert transfer)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stats import ActivationStats
from repro.models import moe as moe_mod
from repro.models import transformer as tr


@dataclasses.dataclass
class ServingEngine:
    rt: tr.Runtime
    params: Any                        # EP-layout params (jit arg)
    placement: Any                     # stacked EPPlacement [G, ...]
    dense_master: Any = None           # dense expert weights (for migration)
    max_len: int = 256

    def __post_init__(self):
        if self.rt.kv_quant and not self.rt.kv_quant_consistent:
            # serving semantics: prefill attends to the dequantized k/v it
            # stores, so sequential generate(), the dense-pool runtime and
            # paged chunked prefill are all token-identical under int8
            self.rt = dataclasses.replace(self.rt, kv_quant_consistent=True)
        rt = self.rt
        cfg = rt.cfg
        _, self.n_groups = cfg.layer_pattern()
        n_ep = rt.ep_spec.n_ep if rt.ep_spec else 1
        self.stats = ActivationStats(self.n_groups, n_ep, cfg.num_experts)
        self.last_local_frac: float | None = None   # most recent step's
        #   mean local-dispatch fraction (serving-side locality signal)

        def _prefill(params, tokens, placement, origin=None):
            return tr.prefill(rt, params, tokens=tokens, placement=placement,
                              cache_len=self.max_len, origin=origin)

        def _decode(params, cache, tokens, pos, placement, token_mask=None,
                    origin=None):
            return tr.decode_step(rt, params, cache, tokens, pos, placement,
                                  token_mask=token_mask, origin=origin)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._copy_block = jax.jit(tr.copy_paged_block)
        self._paged_fns: dict = {}

    # ------------------------------------------------------------------
    def paged_step_fns(self, block_size: int, max_pages: int):
        """Jitted (prefill_chunk, decode) pair for a paged KV pool. The
        chunk function consumes one block-aligned chunk of *every*
        prefilling slot per call (batched multi-slot prefill). The
        functions specialize on array shapes; the (block_size, max_pages)
        key only keeps one cached pair per pool geometry."""
        key = (block_size, max_pages)
        if key not in self._paged_fns:
            rt = self.rt

            def _chunk(params, pool, tokens, page_table, write_blocks,
                       offset, last_idx, placement, token_mask, origin=None):
                return tr.prefill_chunk(rt, params, pool, tokens, page_table,
                                        write_blocks, offset, last_idx,
                                        placement, token_mask=token_mask,
                                        origin=origin)

            def _dec(params, pool, tokens, pos, page_table, placement,
                     token_mask=None, origin=None):
                return tr.decode_step(rt, params, pool, tokens, pos,
                                      placement, token_mask=token_mask,
                                      page_table=page_table, origin=origin)

            self._paged_fns[key] = (jax.jit(_chunk), jax.jit(_dec))
        return self._paged_fns[key]

    # ------------------------------------------------------------------
    def copy_block(self, pool, src: int, dst: int):
        """Copy one physical block across every layer of a paged pool —
        the runtime's copy-on-write primitive (clone a shared tail block
        before a sharer's first write)."""
        return self._copy_block(pool, jnp.int32(src), jnp.int32(dst))

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, steps: int = 16,
                 greedy: bool = True):
        """tokens: [B, T] prompt. Returns (generated [B, steps], stats)."""
        B, T = tokens.shape
        assert T + steps <= self.max_len
        logits, cache, mstats = self._prefill(self.params, jnp.asarray(tokens),
                                              self.placement)
        # counts_per_rank are raw token counts: a T-token prefill already
        # carries T x the mass of one decode step, so no extra weighting.
        self._ingest(mstats)
        outs = []
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        local_fracs = []
        for i in range(steps):
            outs.append(cur)
            logits, cache, mstats = self._decode(
                self.params, cache, cur, jnp.int32(T + i), self.placement)
            self._ingest(mstats)
            if mstats is not None:
                local_fracs.append(float(mstats["local_frac"].mean()))
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen = jnp.concatenate(outs, axis=1)
        return np.asarray(gen), {
            "local_frac": float(np.mean(local_fracs)) if local_fracs else 1.0}

    def _ingest(self, mstats, weight: float = 1.0):
        """Feed gating statistics to the scheduler-side tracker. ``weight``
        rescales this update's counts (e.g. to down-weight stats from a
        batch containing padding-only rows); it was previously accepted but
        silently ignored."""
        if mstats is None:
            return
        counts = np.asarray(mstats["counts_per_rank"], np.float64) * weight
        self.stats.update(counts)
        if "local_frac" in mstats:
            self.last_local_frac = float(
                np.asarray(mstats["local_frac"]).mean())

    # ------------------------------------------------------------------
    def migrate(self, new_placement_stacked) -> None:
        """Adopt a new placement: re-gather EP expert slots from the dense
        master weights (if available) and swap the tables. No recompile —
        placement tables and weights are both jit arguments."""
        self.placement = jax.tree.map(jnp.asarray, new_placement_stacked)
        if self.dense_master is None:
            return
        regathered = moe_mod.regather_ep_groups(
            self.dense_master, self.placement, self.n_groups)
        moe_groups = {k: v for k, v in regathered.items()
                      if "router" in self.dense_master[k]}
        params = dict(self.params)
        params["groups"] = {**self.params["groups"], **moe_groups}
        self.params = params

"""JAX serving engine: batched prefill + decode with the placement-aware EP
MoE layer, activation-stats collection, and zero-recompile placement
migration (the placement tables are jit arguments; migrating re-gathers the
EP weight slots from the dense master copy — the on-device analogue of the
paper's expert transfer).

The paged step functions thread a device-resident *last-token buffer*
(``last_buf``, ``[max_slots + 1]`` int32 — one entry per serving slot plus
a trailing scratch entry that padding rows read and write) through every
call: the decode argmax is computed on device and scattered back into the
buffer, so the next round's inputs never depend on a host round-trip. The
runtime's zero-stall loop (``ServingRuntime(warmup=True)``) only fetches
the small ``[B]`` sampled-token vector, asynchronously, one round behind.

``warmup_paged`` ahead-of-time compiles the full compaction bucket ladder
(every power-of-two batch width the runtime's ``compact_decode`` /
``compact_prefill`` bucketing can produce) via ``jax.jit(...).lower(...)
.compile()`` with the pool and last-token buffer *donated*, so steady-state
decode re-uses its own KV buffers instead of allocating. Executables are
cached on the engine keyed like ``_paged_fns`` plus the batch width and
origin mode, and ``self.traces`` counts Python traces so a runtime can
assert the hot loop never traces after warmup.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stats import ActivationStats
from repro.models import moe as moe_mod
from repro.models import transformer as tr
from repro.serving.sampling import sample_tokens


@dataclasses.dataclass
class ServingEngine:
    rt: tr.Runtime
    params: Any  # EP-layout params (jit arg)
    placement: Any  # stacked EPPlacement [G, ...]
    dense_master: Any = None  # dense expert weights (for migration)
    max_len: int = 256

    def __post_init__(self):
        if self.rt.kv_quant and not self.rt.kv_quant_consistent:
            # serving semantics: prefill attends to the dequantized k/v it
            # stores, so sequential generate(), the dense-pool runtime and
            # paged chunked prefill are all token-identical under int8
            self.rt = dataclasses.replace(self.rt, kv_quant_consistent=True)
        rt = self.rt
        cfg = rt.cfg
        _, self.n_groups = cfg.layer_pattern()
        n_ep = rt.ep_spec.n_ep if rt.ep_spec else 1
        self.stats = ActivationStats(self.n_groups, n_ep, cfg.num_experts)
        self.last_local_frac: float | None = None  # most recent step's
        #   mean local-dispatch fraction (serving-side locality signal)
        self.traces = 0  # Python traces of the serving step fns: the
        #   counter lives in the traced bodies, so compiled executables
        #   (and cache hits) never move it — a zero delta across a serving
        #   run proves the hot loop re-used compiled code throughout

        def _prefill(params, tokens, placement, origin=None):
            self.traces += 1
            return tr.prefill(
                rt,
                params,
                tokens=tokens,
                placement=placement,
                cache_len=self.max_len,
                origin=origin,
            )

        def _decode(
            params, cache, tokens, pos, placement, token_mask=None, origin=None
        ):
            self.traces += 1
            return tr.decode_step(
                rt,
                params,
                cache,
                tokens,
                pos,
                placement,
                token_mask=token_mask,
                origin=origin,
            )

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

        def _copy_block(pool, src, dst):
            self.traces += 1
            return tr.copy_paged_block(pool, src, dst)

        self._copy_block = jax.jit(_copy_block)
        self._copy_block_raw = _copy_block
        self._paged_fns: dict = {}
        self._paged_raw: dict = {}
        self._compiled: dict = {}  # (kind, block_size, max_pages, B,
        #   tagged) -> AOT executable (kind: "chunk" | "dec"; "copy" is
        #   keyed ("copy", n_blocks, block_size))

    # ------------------------------------------------------------------
    def paged_step_fns(self, block_size: int, max_pages: int):
        """Jitted (prefill_chunk, decode) pair for a paged KV pool. The
        chunk function consumes one block-aligned chunk of *every*
        prefilling slot per call (batched multi-slot prefill). Both thread
        the last-token buffer: ``rows`` maps batch row -> slot index (the
        trailing scratch entry for padding rows), decode gathers its input
        tokens from ``last_buf`` and both scatter their on-device next
        token back into it, so consecutive rounds chain without a host
        transfer. The next token is the seeded Gumbel-max sample of
        ``repro.serving.sampling`` — exact argmax for rows at
        ``temps == 0``, a per-request ``(seed, position)``-keyed draw
        otherwise, so sampling never depends on batch composition.
        The functions specialize on array shapes; the (block_size,
        max_pages) key only keeps one cached pair per pool geometry."""
        key = (block_size, max_pages)
        if key not in self._paged_fns:
            rt = self.rt

            def _chunk(
                params,
                pool,
                last_buf,
                rows,
                tokens,
                page_table,
                write_blocks,
                offset,
                last_idx,
                placement,
                token_mask,
                temps,
                seeds,
                origin=None,
            ):
                self.traces += 1
                logits, pool, mstats = tr.prefill_chunk(
                    rt,
                    params,
                    pool,
                    tokens,
                    page_table,
                    write_blocks,
                    offset,
                    last_idx,
                    placement,
                    token_mask=token_mask,
                    origin=origin,
                )
                # seed the decode chain: rows whose final chunk just landed
                # read their first token from last_buf next round (partial
                # chunks scatter a value no decode round will ever gather).
                # The sample position is the absolute last prompt index.
                first = sample_tokens(
                    logits, temps, seeds, offset + last_idx
                ).astype(jnp.int32)
                last_buf = last_buf.at[rows].set(first)
                return last_buf, first, logits, pool, mstats

            def _dec(
                params,
                pool,
                last_buf,
                rows,
                pos,
                page_table,
                placement,
                token_mask,
                temps,
                seeds,
                origin=None,
            ):
                self.traces += 1
                cur = last_buf[rows][:, None]
                logits, pool, mstats = tr.decode_step(
                    rt,
                    params,
                    pool,
                    cur,
                    pos,
                    placement,
                    token_mask=token_mask,
                    page_table=page_table,
                    origin=origin,
                )
                nxt = sample_tokens(logits, temps, seeds, pos).astype(
                    jnp.int32
                )
                last_buf = last_buf.at[rows].set(nxt)
                return last_buf, nxt, pool, mstats

            self._paged_fns[key] = (jax.jit(_chunk), jax.jit(_dec))
            self._paged_raw[key] = (_chunk, _dec)
        return self._paged_fns[key]

    # ------------------------------------------------------------------
    def paged_executable(
        self, kind: str, block_size: int, max_pages: int, B: int, tagged: bool
    ):
        """AOT executable for one (step kind, pool geometry, batch width,
        origin mode) point of the warmed ladder, or None when that point
        was not warmed (callers fall back to the lazy jit path)."""
        return self._compiled.get((kind, block_size, max_pages, B, tagged))

    def warmup_paged(
        self,
        *,
        block_size: int,
        max_pages: int,
        max_slots: int,
        pool,
        last_buf,
        origins: str = "both",
    ) -> dict:
        """Ahead-of-time compile the paged serving ladder for one pool
        geometry: every compaction bucket width (powers of two up to
        ``max_slots``, plus ``max_slots`` itself) x {prefill chunk, decode}
        x the requested origin modes, plus the copy-on-write block clone.
        ``pool``/``last_buf`` are the runtime's live buffers — lowering
        only reads their avals; the compiled executables *donate* both, so
        steady-state rounds update the KV pool in place.

        origins: "both" (default), "tagged" (per-request origin arrays) or
        "untagged" — a runtime that knows its stream mode can halve the
        ladder.

        Returns {"seconds": wall_time, "executables": ladder_size} —
        the number of ladder executables this runtime serves from (cached
        entries included). Already-compiled ladder points (same engine,
        same geometry) are skipped, so a second runtime warms for free."""
        t0 = time.perf_counter()
        ladder = 0
        geometry = (block_size, max_pages)
        self.paged_step_fns(*geometry)  # ensure the raw fns exist
        chunk_raw, dec_raw = self._paged_raw[geometry]
        widths = []
        w = 1
        while w < max_slots:
            widths.append(w)
            w <<= 1
        widths.append(max_slots)
        tag_modes = {
            "both": (False, True),
            "tagged": (True,),
            "untagged": (False,),
        }[origins]
        for B in widths:
            rows = jnp.full((B,), max_slots, jnp.int32)
            toks = jnp.zeros((B, block_size), jnp.int32)
            cmask = jnp.zeros((B, block_size), jnp.float32)
            vec = jnp.zeros((B,), jnp.int32)
            tbl = jnp.zeros((B, max_pages), jnp.int32)
            dmask = jnp.zeros((B,), jnp.float32)
            temps = jnp.zeros((B,), jnp.float32)
            seeds = jnp.zeros((B,), jnp.uint32)
            for tagged in tag_modes:
                org = jnp.zeros((B,), jnp.int32) if tagged else None
                key = ("chunk", block_size, max_pages, B, tagged)
                if key not in self._compiled:
                    self._compiled[key] = (
                        jax.jit(chunk_raw, donate_argnums=(1, 2))
                        .lower(
                            self.params,
                            pool,
                            last_buf,
                            rows,
                            toks,
                            tbl,
                            vec,
                            vec,
                            vec,
                            self.placement,
                            cmask,
                            temps,
                            seeds,
                            org,
                        )
                        .compile()
                    )
                ladder += 1
                key = ("dec", block_size, max_pages, B, tagged)
                if key not in self._compiled:
                    self._compiled[key] = (
                        jax.jit(dec_raw, donate_argnums=(1, 2))
                        .lower(
                            self.params,
                            pool,
                            last_buf,
                            rows,
                            vec,
                            tbl,
                            self.placement,
                            dmask,
                            temps,
                            seeds,
                            org,
                        )
                        .compile()
                    )
                ladder += 1
        n_blocks = self._pool_n_blocks(pool)
        key = ("copy", n_blocks, block_size)
        if key not in self._compiled:
            self._compiled[key] = (
                jax.jit(self._copy_block_raw, donate_argnums=0)
                .lower(pool, jnp.int32(0), jnp.int32(0))
                .compile()
            )
        ladder += 1
        return {"seconds": time.perf_counter() - t0, "executables": ladder}

    @staticmethod
    def _pool_n_blocks(pool) -> int:
        """Physical block count of an ``init_paged_cache`` pool (leaf
        layout ``[n_groups, n_blocks, block_size, ...]``)."""
        leaf = jax.tree.leaves(pool)[0]
        return int(leaf.shape[1])

    # ------------------------------------------------------------------
    def copy_block(self, pool, src: int, dst: int):
        """Copy one physical block across every layer of a paged pool —
        the runtime's copy-on-write primitive (clone a shared tail block
        before a sharer's first write). Routed through the warmed donated
        executable when the pool geometry was warmed."""
        leaf = jax.tree.leaves(pool)[0]
        exe = self._compiled.get(
            ("copy", int(leaf.shape[1]), int(leaf.shape[2]))
        )
        fn = exe if exe is not None else self._copy_block
        return fn(pool, jnp.int32(src), jnp.int32(dst))

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, steps: int = 16, greedy: bool = True):
        """tokens: [B, T] prompt. Returns (generated [B, steps], stats)."""
        B, T = tokens.shape
        assert T + steps <= self.max_len
        logits, cache, mstats = self._prefill(
            self.params, jnp.asarray(tokens), self.placement
        )
        # counts_per_rank are raw token counts: a T-token prefill already
        # carries T x the mass of one decode step, so no extra weighting.
        self._ingest(mstats)
        outs = []
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        local_fracs = []
        for i in range(steps):
            outs.append(cur)
            logits, cache, mstats = self._decode(
                self.params, cache, cur, jnp.int32(T + i), self.placement
            )
            self._ingest(mstats)
            if mstats is not None:
                local_fracs.append(float(mstats["local_frac"].mean()))
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen = jnp.concatenate(outs, axis=1)
        return np.asarray(gen), {
            "local_frac": float(np.mean(local_fracs)) if local_fracs else 1.0
        }

    def _ingest(self, mstats, weight: float = 1.0):
        """Feed gating statistics to the scheduler-side tracker. ``weight``
        rescales this update's counts (e.g. to down-weight stats from a
        batch containing padding-only rows); it was previously accepted but
        silently ignored."""
        if mstats is None:
            return
        counts = np.asarray(mstats["counts_per_rank"], np.float64) * weight
        self.stats.update(counts)
        if "local_frac" in mstats:
            self.last_local_frac = float(
                np.asarray(mstats["local_frac"]).mean()
            )

    # ------------------------------------------------------------------
    def migrate(self, new_placement_stacked) -> None:
        """Adopt a new placement: re-gather EP expert slots from the dense
        master weights (if available) and swap the tables. No recompile —
        placement tables and weights are both jit arguments, and the AOT
        executables stay valid because the re-gathered arrays keep their
        shapes and dtypes."""
        self.placement = jax.tree.map(jnp.asarray, new_placement_stacked)
        if self.dense_master is None:
            return
        regathered = moe_mod.regather_ep_groups(
            self.dense_master, self.placement, self.n_groups
        )
        moe_groups = {
            k: v
            for k, v in regathered.items()
            if "router" in self.dense_master[k]
        }
        params = dict(self.params)
        params["groups"] = {**self.params["groups"], **moe_groups}
        self.params = params

"""Continuous-batching serving runtime on top of the jitted ``ServingEngine``
step functions.

``ServingEngine.generate`` serves one synchronous batch: every request in it
starts and finishes together. This runtime serves a *request stream*
instead:

* a request queue — ``submit()`` at any time, including mid-stream;
* a **paged KV-cache pool** (default) — a shared block table of
  ``n_blocks × block_size`` positions per layer plus a per-slot page list
  managed by a reference-counted ``BlockAllocator``; admission is governed
  by free *blocks*, not free ``max_len`` rows, so heterogeneous request
  streams pack the same KV memory far denser than the legacy dense pool;
* a **radix prefix cache** (``prefix_cache=True``, the default) — a token
  trie mapping block-aligned prompt prefixes to the physical blocks that
  already hold their k/v. A request whose prompt shares a cached prefix
  acquires those blocks shared (refcount + 1) and skips prefill for them
  entirely; an identical prompt skips *all* prefill (first token from the
  cached last-prompt-token logits) and gets a **copy-on-write** clone of
  the partially-filled tail block before its first decode write — a block
  with refcount > 1 is never written;
* **batched chunked prefill** — each scheduler tick advances *every*
  prefilling slot by one ``block_size``-aligned chunk in a single jitted
  ``prefill_chunk`` call (fixed ``max_slots`` batch width, one compile),
  interleaved with decode rounds, so neither a long prompt nor many short
  non-shared tails serialize the pool;
* interleaved prefill/decode — every decoding slot advances one token per
  decode round regardless of arrival time (per-row cache positions via the
  vector-``pos`` decode path);
* **compacted decode** (``compact_decode=True``, paged mode) — each decode
  round batches only the occupied slots, padded to the next power-of-two
  bucket width, instead of always paying for the full ``max_slots`` pool.

Requests enter through the typed serving API
(``repro.serving.api.Request`` -> ``enqueue() -> RequestHandle``): handles
emit structured ``ADMITTED/DEFERRED/PREFIX_HIT/TOKEN/FINISHED`` events with
per-request latency/locality metrics. The positional ``submit(...)`` +
``{rid: tokens}`` surface survives only as a ``DeprecationWarning`` shim.

The legacy dense slot pool (``paged=False``) allocates ``max_slots`` rows
of ``max_len`` positions and prefills whole prompts in one call; it remains
for architectures whose caches cannot be paged (SSM state, sliding-window
rings) and as the reference implementation for the equivalence suite.

``warmup=True`` (paged mode) turns on the **AOT-warmed zero-stall loop**:

* at construction, ``engine.warmup_paged`` ahead-of-time compiles every
  compaction bucket width of the decode and chunked-prefill ladders (with
  the pool and the device-resident last-token buffer *donated*), so no
  occupancy change ever pays a jit trace mid-stream — asserted via
  ``traces_after_warmup``;
* each decode round gathers its input tokens from the device-resident
  last-token buffer and scatters its argmax back into it, so round ``k+1``
  launches without waiting for round ``k``'s tokens to reach the host;
* the host-side work — token events, EOS / ``max_new_tokens`` stop
  detection, retirement, KV release, gating-stats ingestion — moves to a
  **backlog** of pending round records, drained at the end of the *next*
  tick from an async host copy started at launch, overlapped with the
  in-flight device step. Length stops are enforced at launch (the
  ``launched`` budget), so they never lag; EOS stops are detected at
  drain, at most **one round late** — the single extra speculative decode
  provably writes inside the slot's held pages and its token is never
  emitted. Token streams and retirement/KV-release semantics are
  identical to the synchronous loop; only their tick of emission may lag
  by one. ``flush()`` force-drains the backlog (``run()`` ends drained).

Outputs are token-identical to sequential ``generate()`` calls in both
modes — with or without the prefix cache — as long as the EP dispatch
capacities are not saturated (rows are independent in attention; the MoE
layer couples them only through capacity dropping). Prefix reuse is exact
because k/v at position ``i`` depend only on tokens ``0..i``.

The runtime also hosts the serving side of the placement control plane: it
feeds gating statistics to a ``PlacementController`` and applies adopted
plans to the engine (re-gather + table swap, no recompile). Requests
tagged with ``submit(origin=...)`` have their gating counts attributed to
that *originating server* instead of the physical row-sharding rank
(Algorithm 1's per-server f_n(e)); untagged streams keep the positional
fallback unchanged.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import PlacementController
from repro.models import transformer as tr
from repro.serving.api import EventType, Request, RequestHandle, SeqCounter
from repro.serving.engine import ServingEngine
from repro.serving.obs import NULL_TRACER, SpanKind, Tracer
from repro.serving.prefix_cache import PrefixMatch, RadixPrefixCache
from repro.serving.sampling import sample_token_host, sample_tokens


@dataclasses.dataclass
class GenRequest:
    """One queued generation request (internal admission record built from
    an API :class:`Request` by ``enqueue``)."""
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int
    origin: int | None = None     # originating server (EP rank) for stats
    eos: int | None = None        # stop token (truncates max_new_tokens)
    temperature: float = 0.0      # 0 = greedy; > 0 = seeded Gumbel-max
    seed: int = 0                 # per-request sampling PRNG seed
    deadline: float | None = None  # absolute tick the SLO expires at
    #   (submitted_at + slo; None = no SLO) — drives the slo_aware
    #   deadline-ordered admission queue and the shed rule


@dataclasses.dataclass
class _Slot:
    """State of one occupied KV-cache pool row."""
    rid: int
    pos: int                      # next cache write position
    last: int                     # last emitted token (next decode input)
    tokens: list                  # emitted tokens so far
    need: int                     # total tokens to emit (shrunk on EOS)
    origin: int | None = None     # originating server (stats attribution)
    eos: int | None = None        # stop token (None = length stop only)
    launched: int = 0             # tokens whose computation was launched;
    #   drives decode-batch composition so length stops never need a
    #   drained result (zero-stall loop: tokens lag launches by <= 1 round)
    temperature: float = 0.0      # sampling temperature (0 = greedy)
    seed: int = 0                 # sampling PRNG seed
    # paged-mode state
    pages: list = dataclasses.field(default_factory=list)
    prompt: np.ndarray | None = None   # full prompt (kept for cache insert)
    filled: int = 0                    # prompt tokens already in the pool
    final_logits: np.ndarray | None = None  # last-prompt-token logits (for
    #                                         tail insertion at retirement)
    prefix_skipped: int = 0            # prompt tokens served from the cache
    lf_sum: float = 0.0                # running local_frac over decode rounds
    lf_rounds: int = 0

    @property
    def prefilling(self) -> bool:
        return self.prompt is not None and self.filled < len(self.prompt)


@dataclasses.dataclass
class _Pending:
    """One launched-but-undrained round (the zero-stall backlog record).

    Holds the *device* result arrays of a decode round or a prefill chunk
    call — their host copies are started at launch
    (``copy_to_host_async``) and consumed one tick later, overlapped with
    the next round's device step. ``rows`` maps batch row -> (slot index,
    rid at launch); the rid guard makes drains robust to the slot having
    retired (EOS lag) or been re-assigned meanwhile."""
    kind: str                     # "decode" | "prefill"
    tick: int                     # tick the round was launched on
    rows: list                    # [(batch row, slot idx, rid)] — decode:
    #   every live row; prefill: only rows whose final chunk landed
    nxt: object = None            # decode: [B] int32 sampled tokens
    logits: object = None         # prefill: [B, V] final-position logits
    first: object = None          # prefill: [B] int32 first tokens (the
    #   same values the chunk call scattered into the last-token buffer)
    mstats: object = None         # gating stats (ingested at drain)


class BlockAllocator:
    """Reference-counted free-list allocator over the physical blocks of a
    paged KV pool.

    Block 0 is reserved as the *null block*: vacant decode rows point their
    page tables at it and park their garbage writes there, so it is never
    handed out. ``alloc`` hands out fresh blocks at refcount 1;
    ``acquire`` adds a reference to a live block (prefix sharing: a block
    may be held by several slots plus the radix cache at once); ``release``
    drops one reference and recycles the block only at refcount 0. Acquire
    or release of a non-live block is a structural error and raises.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))   # LIFO: hot reuse
        self._rc: dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def capacity_blocks(self) -> int:
        """Allocatable blocks (the null block is excluded)."""
        return self.n_blocks - 1

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` fresh blocks at refcount 1; raises when exhausted
        (callers check ``can_alloc`` first and defer admission instead)."""
        if not self.can_alloc(n):
            raise RuntimeError(
                f"paged pool exhausted: requested {n} blocks, "
                f"{len(self._free)} free")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._rc[b] = 1
        return blocks

    def acquire(self, blocks: list[int]) -> None:
        """Add one reference to each live block (shared prefix pages)."""
        for b in blocks:
            if b not in self._rc:
                raise RuntimeError(f"block {b} is not allocated")
        for b in blocks:
            self._rc[b] += 1

    def release(self, blocks: list[int]) -> int:
        """Drop one reference per block; a block is recycled only when its
        refcount reaches 0. Returns the number of blocks recycled.

        Refcounts are anonymous (sharing means a block has no single
        owner), so a release the caller does not actually hold steals
        another holder's reference rather than raising — the runtime's
        ``check_invariants`` (refcount == slot holds + cache refs, asserted
        every tick of the property suites) is the guard for that misuse
        class, replacing the old owner-tag check that sharing made
        impossible."""
        freed = 0
        for b in blocks:
            rc = self._rc.get(b)
            if rc is None:
                raise RuntimeError(f"block {b} is not allocated")
            if rc == 1:
                del self._rc[b]
                self._free.append(b)
                freed += 1
            else:
                self._rc[b] = rc - 1
        return freed

    def refcount(self, b: int) -> int:
        return self._rc.get(b, 0)

    def live(self) -> dict[int, int]:
        """Live block -> refcount (for invariant checks and tests)."""
        return dict(self._rc)


class Reservoir:
    """Bounded, deterministic subsample of an append-only float stream.

    The runtime's wall-time series (``decode_round_s``, ``ttft_s``)
    previously grew one entry per decode round / request forever — a
    leak on long-running serving. The reservoir keeps a *systematic*
    1-in-``2^k`` subsample instead: it records every ``stride``-th
    append, and when the kept list would exceed ``cap`` it drops every
    other kept sample and doubles the stride. Survivors stay evenly
    spaced over the whole stream (indices ``0, stride, 2*stride, ...``),
    so percentiles remain representative of the full history at bounded
    memory. No RNG — a replayed fault schedule stays bit-identical.

    ``count`` is the total number of appends (the true observation
    count); ``len()``/iteration expose the kept samples.
    """

    def __init__(self, cap: int = 4096):
        if cap < 2:
            raise ValueError(f"cap must be >= 2 (got {cap})")
        self.cap = cap
        self.count = 0            # total appends ever
        self.stride = 1           # keep one sample per this many appends
        self._data: list[float] = []

    def append(self, x: float) -> None:
        if self.count % self.stride == 0:
            self._data.append(float(x))
            if len(self._data) > self.cap:
                self._data = self._data[::2]
                self.stride *= 2
        self.count += 1

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self):
        return iter(self._data)

    def __bool__(self) -> bool:
        return bool(self._data)


class ServingRuntime:
    """Continuous batching over a shared KV pool.

    engine:      a ``ServingEngine`` (its jitted step functions are reused).
    max_slots:   decode batch width (one compile). In paged mode this is
                 *only* the batch width — KV memory is the block pool.
    controller:  optional ``PlacementController``; its clock is decode
                 rounds (set ``interval`` accordingly). Adopted plans are
                 applied to the engine via ``engine.migrate``.
    paged:       True = paged block pool + chunked prefill; False = legacy
                 dense per-slot rows; None (default) = paged when the
                 architecture supports it (attention caches, no sliding
                 window), dense otherwise.
    block_size:  positions per physical KV block (paged mode).
    n_blocks:    physical blocks incl. the null block. Default sizes the
                 pool to the dense pool's KV memory
                 (``max_slots * max_len`` positions) plus the null block.
    max_pages:   page-table width (max blocks one request may hold); the
                 per-step attention gather is ``max_pages * block_size``
                 positions per row, so this is the cost/length-cap knob.
                 Default: ``2 * ceil(max_len / block_size)``, clamped to
                 the pool.
    chunks_per_tick: batched prefill rounds per ``step()`` — each round
                 advances every prefilling slot one chunk in one jitted
                 call (interleaving knob).
    prefix_cache: enable the radix prefix cache (paged mode only).
    compact_decode: decode only the occupied slots each round, padded to
                 the next power-of-two bucket (paged mode only) — a pool
                 that is 1/8 occupied decodes a width-1 batch instead of
                 the full ``max_slots`` width. Bucketing keeps the jit
                 universe at ``log2(max_slots)`` decode variants; the dense
                 pool always decodes full width (its KV rows are
                 positional).
    compact_prefill: the same bucketing for the batched ``prefill_chunk``
                 call (paged mode only): only the *prefilling* slots ride
                 each chunk round, padded to the next power-of-two width,
                 instead of the fixed ``max_slots`` batch. ``prefill_rows``
                 counts the rows actually executed (the compaction metric,
                 mirroring ``decode_rows``).
    warmup:      paged mode only — AOT-compile the full compaction bucket
                 ladder at construction (``engine.warmup_paged``: donated
                 pool + last-token buffer, one executable per bucket width
                 x step kind x origin mode) and serve with the zero-stall
                 round structure: decode rounds chain on device through
                 the last-token buffer, host-side token/stop/retirement
                 work drains from a one-round-lagged async backlog (see
                 the module docstring). ``warmup_seconds`` /
                 ``executables_compiled`` / ``traces_after_warmup`` /
                 ``host_syncs`` and ``perf_metrics()`` expose the result.
    warmup_origins: which origin modes to precompile ("both" — default —
                 "tagged" or "untagged"): a caller that knows its stream
                 is origin-tagged (or knows it is not) can halve warmup.
    """

    def __init__(self, engine: ServingEngine, max_slots: int = 4,
                 controller: PlacementController | None = None, *,
                 paged: bool | None = None, block_size: int = 16,
                 n_blocks: int | None = None, max_pages: int | None = None,
                 chunks_per_tick: int = 1, prefix_cache: bool = True,
                 compact_decode: bool = True, compact_prefill: bool = True,
                 warmup: bool = False, warmup_origins: str = "both",
                 slo_aware: bool = False, tracer: Tracer | None = None,
                 seq_counter: SeqCounter | None = None,
                 tracer_server: int = -1):
        self.engine = engine
        self.max_slots = max_slots
        # observability: span emission sites guard on tracer.enabled (the
        # default NULL_TRACER), so an untraced runtime allocates nothing
        # extra. tracer_server labels this runtime's spans with its
        # cluster server id (its Perfetto track); -1 = standalone.
        # seq_counter (cluster-shared) stamps handle events with the
        # monotonic merge order; standalone runtimes get their own.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.seq = seq_counter if seq_counter is not None else SeqCounter()
        self.tracer_server = tracer_server
        self._enq_tick: dict[int, int] = {}   # rid -> enqueue tick (traced)
        # SLO-aware scheduling: admission drains the queue in deadline
        # order (EDF) instead of FIFO, and requests whose deadline cannot
        # be met even under the best case (full prefix hit, one token per
        # tick) are *shed* — SHED event + terminal empty FINISHED — so
        # doomed work never occupies a slot another request could use
        self.slo_aware = bool(slo_aware)
        self.sheds = 0                # requests shed by SLO-aware admission
        self.controller = controller
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                # start the review clock: the first (initial-adopt) review
                # must also wait a full interval of observed traffic, not
                # fire on decode round 1 with near-empty stats
                controller.last_review = 0.0
        if paged is None:
            paged = tr.supports_paging(engine.rt)
        self.paged = paged
        self.prefix_cache: RadixPrefixCache | None = None
        if paged:
            self.block_size = block_size
            if n_blocks is None:
                n_blocks = 1 + max_slots * (-(-engine.max_len // block_size))
            self.allocator = BlockAllocator(n_blocks)
            if max_pages is None:
                # per-request length cap: attention gathers max_pages*bs
                # positions per batch row every step, so don't default to
                # the whole pool — 2x the legacy row length keeps long
                # requests admissible at bounded gather cost (pass
                # max_pages=allocator.capacity_blocks for unbounded)
                max_pages = min(self.allocator.capacity_blocks,
                                2 * (-(-engine.max_len // block_size)))
            self.max_pages = max_pages
            self.chunks_per_tick = chunks_per_tick
            if prefix_cache:
                self.prefix_cache = RadixPrefixCache(block_size,
                                                     self.allocator)
            self.pool = tr.init_paged_cache(engine.rt, n_blocks, block_size)
            self.page_table = np.zeros((max_slots, self.max_pages), np.int32)
            self._chunk_fn, self._decode_fn = engine.paged_step_fns(
                block_size, self.max_pages)
            # device-resident last-token buffer: one entry per slot plus a
            # trailing scratch entry that padding batch rows read/write
            self._last_buf = jnp.zeros((max_slots + 1,), jnp.int32)
        else:
            self.pool = tr.init_cache(engine.rt, max_slots, engine.max_len)
        if warmup and not paged:
            raise ValueError(
                "warmup=True requires the paged pool (the AOT bucket "
                "ladder and the zero-stall loop are paged-mode features)")
        self.warmup = bool(warmup)
        self.warmup_seconds = 0.0
        self.executables_compiled = 0
        if warmup:
            w = engine.warmup_paged(
                block_size=self.block_size, max_pages=self.max_pages,
                max_slots=max_slots, pool=self.pool,
                last_buf=self._last_buf, origins=warmup_origins)
            self.warmup_seconds = w["seconds"]
            self.executables_compiled = w["executables"]
        # trace floor: traces_after_warmup counts engine traces past this
        # point (for warmup=False runtimes: traces since construction)
        self._traces_at_warmup = engine.traces
        self.compact_decode = compact_decode
        self.compact_prefill = compact_prefill
        self.slots: list[_Slot | None] = [None] * max_slots
        self.queue: collections.deque[GenRequest] = collections.deque()
        self.finished: dict[int, np.ndarray] = {}
        self.handles: dict[int, RequestHandle] = {}   # rid -> handle
        self.rounds = 0               # decode rounds served (controller clock)
        self.ticks = 0                # scheduler ticks (step() calls)
        self.max_concurrency = 0      # peak active slots in one decode batch
        self.max_admitted = 0         # peak concurrently admitted requests
        self.decode_rows = 0          # batch rows decoded (compaction metric)
        self.prefill_rows = 0         # chunk-call rows issued (compaction)
        self.finished_at: dict[int, int] = {}   # rid -> tick of completion
        self.deferrals = 0            # admissions deferred on free blocks
        self.prefix_hits = 0          # admissions that reused cached pages
        self.prefix_tokens_skipped = 0  # prompt tokens never prefilled
        self.prefill_calls = 0        # jitted chunk calls issued
        self.chunks_executed = 0      # per-slot chunks consumed (compute)
        self.cow_copies = 0           # copy-on-write tail clones
        self.host_syncs = 0           # blocking host waits on device data
        #   (sync loop: one per decode round / final prefill chunk; the
        #   zero-stall loop counts only drains whose async copy had not
        #   finished — its steady-state value is the stall count)
        self.decode_round_s = Reservoir()   # per-round wall time of the
        #   decode segment (launch [+ backlog drain] [+ token fetch]);
        #   bounded: a systematic subsample survives long runs
        self.ttft_s = Reservoir()      # wall-clock time to first token
        self._finished_total = 0       # results drained via pop_finished()
        self.migrations: list = []
        self._pending: collections.deque[_Pending] = collections.deque()
        self._t_enqueue: dict[int, float] = {}   # rid -> perf_counter()
        self._next_rid = 0
        self._origin_mode: str | None = None   # 'tagged' | 'untagged'

        def _write_rows(pool, new, idx):
            return jax.tree.map(
                lambda P, c: P.at[:, idx].set(c.astype(P.dtype)), pool, new)

        self._write_rows = jax.jit(_write_rows)

    # ------------------------------------------------------------------
    def _pages_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        """Blocks a request holds for its lifetime: prompt positions
        0..T-1 (whole blocks — chunked prefill writes block-aligned) plus
        decode writes at T..T+need-2."""
        bs = self.block_size
        return max(-(-prompt_len // bs),
                   -(-(prompt_len + max_new_tokens - 1) // bs))

    @property
    def capacity_tokens(self) -> int:
        """Total KV positions this runtime can hold for live requests."""
        if self.paged:
            return self.allocator.capacity_blocks * self.block_size
        return self.max_slots * self.engine.max_len

    def enqueue(self, request: Request,
                handle: RequestHandle | None = None) -> RequestHandle:
        """Enqueue one typed :class:`Request`; returns its
        :class:`RequestHandle` (structured ADMITTED/DEFERRED/PREFIX_HIT/
        TOKEN/FINISHED events, tokens, per-request metrics).

        ``request.origin`` is the EP rank / edge server the request arrived
        at — gating statistics are attributed to it (Algorithm 1's f_n(e)).

        ``handle=`` re-admits a request under an *existing* handle (cluster
        failover: a victim evicted from a crashed server keeps one
        observable lifecycle across servers). The handle is re-bound to a
        fresh internal rid; its original ``submitted_at`` is preserved so
        end-to-end latency spans the crash.

        Paged mode validates against the *total pool capacity* (a request
        merely larger than the legacy ``max_len`` is admissible — it just
        holds more pages); dense mode keeps the per-row ``max_len`` bound.
        """
        prompt = request.prompt
        max_new_tokens = request.max_new_tokens
        origin = request.origin
        n_ep = (self.engine.rt.ep_spec.n_ep
                if self.engine.rt.ep_spec is not None else 1)
        if origin is not None and not 0 <= origin < n_ep:
            # the gating-stats scatter drops out-of-range origins silently
            # (mode="drop"); reject them here so the PlacementController
            # never computes adoption decisions on invisibly missing traffic
            raise ValueError(
                f"origin {origin} out of range for {n_ep} EP rank(s)")
        mode = "untagged" if origin is None else "tagged"
        if self._origin_mode is None:
            self._origin_mode = mode
        elif self._origin_mode != mode:
            # mixing would silently credit untagged rows to server 0 when
            # batched with tagged ones (the positional fallback is
            # all-or-nothing per jitted call) — reject at submit time, the
            # same place out-of-range origins are rejected
            raise ValueError(
                f"cannot mix {mode} submit with a {self._origin_mode} "
                "stream: pass origin= on every request or on none")
        if self.paged:
            npages = self._pages_needed(len(prompt), max_new_tokens)
            if npages > min(self.allocator.capacity_blocks, self.max_pages):
                raise ValueError(
                    f"prompt({len(prompt)}) + max_new_tokens"
                    f"({max_new_tokens}) needs {npages} blocks; the paged "
                    f"pool caps a request at "
                    f"{min(self.allocator.capacity_blocks, self.max_pages)} "
                    f"blocks ({self.capacity_tokens} positions total)")
        elif len(prompt) + max_new_tokens > self.engine.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds the pool's max_len={self.engine.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        if handle is None:
            handle = RequestHandle(rid, request, clock="ticks",
                                   seq=self.seq)
            handle.submitted_at = self.ticks
        else:
            handle.rid = rid
            handle.request = request
            if handle._seqc is None:
                handle._seqc = self.seq
            if handle.submitted_at is None:
                handle.submitted_at = self.ticks
        if self.tracer.enabled:
            # QUEUE_WAIT opens here; closed (and popped) at admission or
            # shed. Keyed by the fresh rid, so a failover re-admit's wait
            # on the new server is its own span.
            self._enq_tick[rid] = self.ticks
        slo = request.slo
        # the deadline is anchored at the *original* submit tick, so a
        # failover re-admit does not get a fresh SLO budget
        deadline = (handle.submitted_at + slo) if slo is not None else None
        self.queue.append(GenRequest(
            rid, prompt, max_new_tokens, origin,
            getattr(request, "eos", None),
            temperature=float(getattr(request, "temperature", 0.0)),
            seed=int(getattr(request, "seed", 0)),
            deadline=deadline))
        self.handles[rid] = handle
        self._t_enqueue[rid] = time.perf_counter()
        return handle

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               origin: int | None = None) -> int:
        """DEPRECATED positional submit — construct a
        ``repro.serving.api.Request`` and call :meth:`enqueue` instead
        (same admission semantics; the handle's events/metrics replace the
        raw ``{rid: tokens}`` dict). Kept as a thin shim returning the
        request id; results remain readable from ``self.finished``."""
        warnings.warn(
            "ServingRuntime.submit(prompt, max_new_tokens, origin) is "
            "deprecated: build a repro.serving.api.Request and call "
            "enqueue() (see serving/README.md, 'Serving API v1')",
            DeprecationWarning, stacklevel=2)
        return self.enqueue(Request(prompt=prompt,
                                    max_new_tokens=max_new_tokens,
                                    origin=origin)).rid

    # -- event plumbing ------------------------------------------------
    def _emit(self, rid: int, type_: str, **data) -> None:
        h = self.handles.get(rid)
        if h is not None:
            h._emit(type_, self.ticks, **data)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted requests that reused cached prefix pages."""
        n = self._finished_total + len(self.finished) + self.active
        return self.prefix_hits / n if n else 0.0

    def pop_finished(self) -> dict[int, np.ndarray]:
        """Drain completed results: returns ``{rid: tokens}`` for every
        finished request and releases their bookkeeping (result arrays,
        completion ticks, handles). Long-running callers (the cluster
        backends) call this periodically so the runtime's footprint is
        bounded by the *live* request set, not the full serve history —
        ``finished`` / ``finished_at`` / ``handles`` previously grew one
        entry per request forever. Callers that never pop keep the old
        read-after-``run()`` behavior unchanged."""
        out = dict(self.finished)
        self.finished.clear()
        self._finished_total += len(out)
        for rid in out:
            self.finished_at.pop(rid, None)
            self.handles.pop(rid, None)
        return out

    def evict(self, rid: int) -> int:
        """Remove one request — queued or in flight — and return the
        number of tokens it had already emitted (the cluster's failover
        bookkeeping: tokens a re-routed victim must regenerate). An
        in-flight slot's pages are released (cache-shared blocks survive
        via their refcounts) and any still-pending backlog drain for the
        old slot is dropped by the rid guard — the same mechanism that
        absorbs EOS-lagged speculative rounds. The handle stays with the
        caller, who may re-submit it elsewhere (``enqueue(handle=...)``);
        unknown/finished rids are a no-op returning 0."""
        for k, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[k]
                self.handles.pop(rid, None)
                self._t_enqueue.pop(rid, None)
                self._enq_tick.pop(rid, None)
                return 0
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                if self.paged and s.pages:
                    self.allocator.release(s.pages)
                    self.page_table[i] = 0
                self.slots[i] = None
                self.handles.pop(rid, None)
                self._t_enqueue.pop(rid, None)
                self._enq_tick.pop(rid, None)
                return len(s.tokens)
        return 0

    @property
    def traces_after_warmup(self) -> int:
        """Engine step-fn Python traces since this runtime finished its
        warmup (since construction when ``warmup=False``). A warmed
        runtime serving alone on its engine keeps this at 0 — the retrace
        regression guard. Note the counter is engine-wide: concurrent
        unwarmed runtimes sharing the engine move it too."""
        return self.engine.traces - self._traces_at_warmup

    def perf_metrics(self) -> dict:
        """The ``metrics.perf`` section of the bench schema: warmup cost,
        retrace/stall counters and decode-round / time-to-first-token
        wall-time percentiles (milliseconds)."""
        def pct(xs):
            xs = list(xs)
            if not xs:
                return {"p50": 0.0, "p99": 0.0}
            return {"p50": round(float(np.percentile(xs, 50)) * 1e3, 6),
                    "p99": round(float(np.percentile(xs, 99)) * 1e3, 6)}
        return {
            "warmup_seconds": round(self.warmup_seconds, 6),
            "executables_compiled": self.executables_compiled,
            "traces_after_warmup": self.traces_after_warmup,
            "host_syncs": self.host_syncs,
            "rounds_timed": self.decode_round_s.count,
            "decode_round_ms": pct(self.decode_round_s),
            "ttft_ms": pct(self.ttft_s),
        }

    # ------------------------------------------------------------------
    def _free_slot_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @staticmethod
    def _origin_arg(origins):
        """[B] int32 origin array for the jitted step fns, or None when no
        request in the batch carries an explicit origin — None keeps the
        MoE layer's positional attribution fallback (and its decode
        replica routing) identical to an origin-unaware deployment.
        ``submit`` rejects mixing, so a batch is all-tagged or all-None."""
        origins = list(origins)
        if all(o is None for o in origins):
            return None
        return jnp.asarray([o or 0 for o in origins], jnp.int32)

    def _admit(self) -> int:
        if self.slo_aware:
            self._slo_schedule()
        if self.paged:
            n = self._admit_paged()
        else:
            n = self._admit_dense()
        self.max_admitted = max(self.max_admitted, self.active)
        return n

    def _slo_schedule(self) -> None:
        """SLO-aware queue pass (``slo_aware=True``): shed every queued
        request whose deadline is unmeetable even in the best case — a
        full prefix hit emitting its first token this tick and one token
        per tick after (``ticks + need - 1 > deadline``) — then reorder
        the queue earliest-deadline-first (SLO-less requests sort last,
        ties broken by rid, so the order is total and deterministic).
        Shedding is optimistic on purpose: only certainly-doomed requests
        are dropped, a merely-late-looking queue keeps its chance."""
        kept: collections.deque[GenRequest] = collections.deque()
        for r in self.queue:
            if (r.deadline is not None
                    and self.ticks + r.max_new_tokens - 1 > r.deadline):
                self._shed(r)
            else:
                kept.append(r)
        if len(kept) > 1:
            kept = collections.deque(sorted(
                kept, key=lambda r: (r.deadline if r.deadline is not None
                                     else float("inf"), r.rid)))
        self.queue = kept

    def _shed(self, r: GenRequest) -> None:
        """Drop one doomed queued request: SHED event, then the terminal
        FINISHED (``tokens=0, shed=True, slo_met=False``) so the request
        still resolves — consumers block on FINISHED, never on SHED."""
        self.sheds += 1
        if self.tracer.enabled:
            self.tracer.span(SpanKind.QUEUE_WAIT,
                             self._enq_tick.pop(r.rid, self.ticks),
                             self.ticks, rid=r.rid,
                             server=self.tracer_server, shed=True)
            self.tracer.instant(SpanKind.SHED, self.ticks, rid=r.rid,
                                server=self.tracer_server,
                                deadline=r.deadline, need=r.max_new_tokens)
        self._emit(r.rid, EventType.SHED, deadline=r.deadline,
                   need=r.max_new_tokens)
        self.finished[r.rid] = np.zeros(0, np.int32)
        self.finished_at[r.rid] = self.ticks
        self._t_enqueue.pop(r.rid, None)
        h = self.handles.get(r.rid)
        if h is None:
            return
        latency = (self.ticks - h.submitted_at
                   if h.submitted_at is not None else None)
        h._emit(EventType.FINISHED, self.ticks,
                tokens=0, origin=r.origin, server=h.server,
                latency=latency, wait=None,
                deferred_ticks=h.deferred_ticks,
                prefix_tokens_skipped=0, local_frac=None,
                slo=h.request.slo, slo_met=False, shed=True)

    def _admit_paged(self) -> int:
        """Admit queue-head requests while a slot row and enough free
        blocks exist — FIFO order by default, earliest-deadline-first
        under ``slo_aware`` (``_slo_schedule`` reorders the queue before
        this runs). The prefix cache is consulted first: shared pages are
        acquired (refcount + 1) instead of allocated, so a hit both skips
        prefill and shrinks the fresh-block bill. A head that does not fit
        — after evicting cold cache entries — *defers* (stays queued, no
        overtaking within the chosen order) until retirements return
        blocks."""
        admitted = 0
        while self.queue and self._free_slot_ids():
            r = self.queue[0]
            if not self._try_admit_one(r):
                self.deferrals += 1
                h = self.handles.get(r.rid)
                if h is not None:
                    h.deferred_ticks += 1
                    if h.deferred_ticks == 1:   # one event, not one per tick
                        self._emit(r.rid, EventType.DEFERRED,
                                   free_blocks=self.allocator.n_free)
                break
            self.queue.popleft()
            admitted += 1
        return admitted

    def _try_admit_one(self, r: GenRequest) -> bool:
        T = len(r.prompt)
        total = self._pages_needed(T, r.max_new_tokens)
        m = (self.prefix_cache.lookup(r.prompt)
             if self.prefix_cache is not None else PrefixMatch(0, []))
        shared = list(m.blocks)
        if m.tail_block is not None:
            shared.append(m.tail_block)
        # CoW: a full-prompt hit on a non-block-aligned prompt holds the
        # cached, partially-filled tail block. Its first decode write
        # (position T, only if a second token will be emitted) would land
        # in that shared block — clone it first. Full shared blocks sit
        # strictly before the write frontier and are never written.
        cow = m.tail_block is not None and r.max_new_tokens >= 2
        n_fresh = total - len(shared) + (1 if cow else 0)
        # hold the matched pages before evicting: eviction only drops the
        # *cache's* refs, so our shared pages survive it
        if shared:
            self.allocator.acquire(shared)
        if not self.allocator.can_alloc(n_fresh) and self.prefix_cache:
            self.prefix_cache.evict(n_fresh - self.allocator.n_free)
        if not self.allocator.can_alloc(n_fresh):
            if shared:
                self.allocator.release(shared)
            return False
        fresh = self.allocator.alloc(n_fresh)
        pages = list(m.blocks)
        if cow:
            dst = fresh.pop(0)
            self.pool = self.engine.copy_block(self.pool, m.tail_block, dst)
            self.allocator.release([m.tail_block])
            self.cow_copies += 1
            pages.append(dst)
        elif m.tail_block is not None:
            pages.append(m.tail_block)
        pages.extend(fresh)
        i = self._free_slot_ids()[0]
        self.page_table[i] = 0
        self.page_table[i, :len(pages)] = pages
        slot = _Slot(rid=r.rid, pos=0, last=-1, tokens=[],
                     need=r.max_new_tokens, origin=r.origin, eos=r.eos,
                     pages=pages, prompt=r.prompt, filled=m.tokens,
                     prefix_skipped=m.tokens,
                     temperature=r.temperature, seed=r.seed)
        self.slots[i] = slot
        self._emit(r.rid, EventType.ADMITTED, slot=i, server=r.origin,
                   pages=len(pages))
        if self.tracer.enabled:
            self.tracer.span(SpanKind.QUEUE_WAIT,
                             self._enq_tick.pop(r.rid, self.ticks),
                             self.ticks, rid=r.rid,
                             server=self.tracer_server, slot=i)
        if m.tokens:
            self.prefix_hits += 1
            self.prefix_tokens_skipped += m.tokens
            if self.tracer.enabled:
                self.tracer.instant(SpanKind.PREFIX_HIT, self.ticks,
                                    rid=r.rid, server=self.tracer_server,
                                    tokens_skipped=m.tokens,
                                    full_hit=m.full_hit)
            self._emit(r.rid, EventType.PREFIX_HIT, tokens_skipped=m.tokens,
                       full_hit=m.full_hit)
        if m.full_hit:
            # the whole prompt is cached: the first token is recomputed
            # from the cached last-prompt-token logits with the same
            # (seed, position)-keyed sampling rule the chunk call applies,
            # so a hit is bit-equal to running prefill
            first = sample_token_host(m.logits, r.temperature, r.seed, T - 1)
            slot.pos = T
            slot.launched = 1
            slot.final_logits = m.logits
            # seed the device decode chain too: the slot joins the decode
            # batch before any chunk call scatters a token for its row
            self._last_buf = self._last_buf.at[i].set(first)
            self._append_token(slot, first)
            self._retire_if_done(i)
        return True

    def _admit_dense(self) -> int:
        """Prefill waiting requests into free slots (batching same-length
        prompts so each distinct length compiles once). Returns #admitted."""
        admitted = 0
        while self.queue and self._free_slot_ids():
            free = self._free_slot_ids()
            T = len(self.queue[0].prompt)
            group: list[GenRequest] = []
            rest: collections.deque = collections.deque()
            while self.queue and len(group) < len(free):
                r = self.queue.popleft()
                (group if len(r.prompt) == T else rest).append(r)
            self.queue = rest + self.queue
            tokens = np.stack([r.prompt for r in group])           # [b, T]
            logits, cache, mstats = self.engine._prefill(
                self.engine.params, jnp.asarray(tokens),
                self.engine.placement,
                self._origin_arg(r.origin for r in group))
            self.engine._ingest(mstats)
            idx = jnp.asarray(free[:len(group)], jnp.int32)
            self.pool = self._write_rows(self.pool, cache, idx)
            lg = np.asarray(logits)                                # [b, V]
            for j, r in enumerate(group):
                first = sample_token_host(lg[j], r.temperature, r.seed,
                                          T - 1)
                slot = _Slot(rid=r.rid, pos=T, last=-1, tokens=[],
                             need=r.max_new_tokens, origin=r.origin,
                             eos=r.eos, launched=1,
                             temperature=r.temperature, seed=r.seed)
                self.slots[free[j]] = slot
                self._emit(r.rid, EventType.ADMITTED, slot=free[j],
                           server=r.origin)
                if self.tracer.enabled:
                    self.tracer.span(SpanKind.QUEUE_WAIT,
                                     self._enq_tick.pop(r.rid, self.ticks),
                                     self.ticks, rid=r.rid,
                                     server=self.tracer_server,
                                     slot=free[j])
                self._append_token(slot, first)
                self._retire_if_done(free[j])
            admitted += len(group)
        return admitted

    def _append_token(self, slot: _Slot, tok: int) -> None:
        """Record one drained token: handle events, time-to-first-token,
        and EOS stop detection (the stop shrinks ``need`` to the tokens
        already emitted, so ``_retire_if_done`` fires and — in the
        zero-stall loop — any extra already-launched speculative round is
        dropped by the drain-side rid guard)."""
        slot.last = tok
        slot.tokens.append(tok)
        if len(slot.tokens) == 1:
            t0 = self._t_enqueue.pop(slot.rid, None)
            if t0 is not None:
                self.ttft_s.append(time.perf_counter() - t0)
        self._emit(slot.rid, EventType.TOKEN, token=tok)
        if (slot.eos is not None and tok == slot.eos
                and len(slot.tokens) < slot.need):
            slot.need = len(slot.tokens)

    def _retire_if_done(self, i: int) -> bool:
        slot = self.slots[i]
        if slot is not None and len(slot.tokens) >= slot.need:
            self.finished[slot.rid] = np.asarray(slot.tokens, np.int32)
            self.finished_at[slot.rid] = self.ticks
            self._emit_finished(slot)
            if self.paged and slot.pages:
                if (self.prefix_cache is not None and slot.prompt is not None
                        and slot.final_logits is not None):
                    # donate the partially-filled tail block: the slot will
                    # never write it again, and stale decode entries beyond
                    # the prompt are overwritten by any sharer before its
                    # validity mask can expose them
                    T = len(slot.prompt)
                    if T % self.block_size:
                        self.prefix_cache.insert_tail(
                            slot.prompt, slot.pages[T // self.block_size],
                            slot.final_logits)
                self.allocator.release(slot.pages)
                self.page_table[i] = 0
            self.slots[i] = None
            return True
        return False

    def _emit_finished(self, slot: _Slot) -> None:
        """FINISHED carries the per-request metrics of the API contract:
        latency/wait in scheduler ticks, locality over the request's decode
        rounds, prefix reuse and the SLO verdict."""
        h = self.handles.get(slot.rid)
        if h is None:
            return
        latency = (self.ticks - h.submitted_at
                   if h.submitted_at is not None else None)
        wait = (h.admitted_at - h.submitted_at
                if h.admitted_at is not None and h.submitted_at is not None
                else None)
        slo = h.request.slo
        h._emit(EventType.FINISHED, self.ticks,
                tokens=len(slot.tokens), origin=slot.origin,
                server=h.server, latency=latency, wait=wait,
                deferred_ticks=h.deferred_ticks,
                prefix_tokens_skipped=slot.prefix_skipped,
                local_frac=(slot.lf_sum / slot.lf_rounds
                            if slot.lf_rounds else None),
                slo=slo,
                slo_met=(bool(latency <= slo)
                         if slo is not None and latency is not None
                         else None),
                shed=False)

    # ------------------------------------------------------------------
    def _prefill_round(self) -> None:
        """Advance every prefilling slot by one block-aligned chunk per
        batched jitted call, ``chunks_per_tick`` times. With
        ``compact_prefill`` only the prefilling slots ride the call,
        padded to the next power-of-two bucket width (mirroring
        ``compact_decode``; one jit variant per bucket); otherwise all
        ``max_slots`` rows do. Rows without a prefilling slot write the
        null block and are masked out of the gating statistics. When a
        slot's final chunk lands, its first token is sampled, its
        block-aligned prefix enters the radix cache, and it joins the
        decode batch from the next round on. One batched host transfer of
        the final-position logits is issued per chunk call (lazily — only
        when some slot finished); the zero-stall loop starts it
        asynchronously and consumes it at the next tick's drain."""
        bs = self.block_size
        for _ in range(self.chunks_per_tick):
            act = [i for i, s in enumerate(self.slots)
                   if s is not None and s.prefilling]
            if not act:
                return
            if self.compact_prefill:
                B = min(self.max_slots,
                        1 << max(len(act) - 1, 0).bit_length())
                row_slots: list[int | None] = act + [None] * (B - len(act))
            else:
                B = self.max_slots
                row_slots = [i if i in act else None for i in range(B)]
            rows = np.full((B,), self.max_slots, np.int32)   # pad rows ->
            #   the last-token buffer's trailing scratch entry
            toks = np.zeros((B, bs), np.int32)
            mask = np.zeros((B, bs), np.float32)
            offs = np.zeros((B,), np.int32)
            lidx = np.zeros((B,), np.int32)
            wb = np.zeros((B,), np.int32)      # idle rows -> null block 0
            tbl = np.zeros((B, self.max_pages), np.int32)
            temps = np.zeros((B,), np.float32)
            seeds = np.zeros((B,), np.uint32)
            finals: list[tuple[int, int, int]] = []   # (row, slot, rid)
            for j, i in enumerate(row_slots):
                if i is None:
                    continue
                s = self.slots[i]
                rows[j] = i
                T = len(s.prompt)
                c0 = s.filled
                valid = min(bs, T - c0)
                toks[j, :valid] = s.prompt[c0:c0 + valid]
                mask[j, :valid] = 1.0
                offs[j] = c0
                wb[j] = s.pages[c0 // bs]
                tbl[j] = self.page_table[i]
                temps[j] = s.temperature
                seeds[j] = s.seed
                final = c0 + valid >= T
                lidx[j] = (T - 1 - c0) if final else bs - 1
                s.filled += valid
                if final:
                    # launch-side bookkeeping: the slot joins this tick's
                    # decode batch (its first token is already seeded into
                    # the device last-token buffer by the chunk call)
                    s.pos = T
                    s.launched = 1
                    finals.append((j, i, s.rid))
            org = self._origin_arg(
                self.slots[i].origin if i is not None else None
                for i in row_slots)
            exe = (self.engine.paged_executable(
                       "chunk", bs, self.max_pages, B, org is not None)
                   if self.warmup else None)
            fn = exe if exe is not None else self._chunk_fn
            self._last_buf, first, logits, self.pool, mstats = fn(
                self.engine.params, self.pool, self._last_buf,
                jnp.asarray(rows), jnp.asarray(toks), jnp.asarray(tbl),
                jnp.asarray(wb), jnp.asarray(offs), jnp.asarray(lidx),
                self.engine.placement, jnp.asarray(mask),
                jnp.asarray(temps), jnp.asarray(seeds), org)
            self.prefill_calls += 1
            self.prefill_rows += B
            self.chunks_executed += len(act)
            if self.tracer.enabled:
                # batch-level span from launch-side metadata only (slot
                # counts, tick number — all host-known): tracing adds no
                # device reads, so the zero-stall loop stays zero-stall
                self.tracer.span(SpanKind.PREFILL_CHUNK, self.ticks,
                                 self.ticks + 1, server=self.tracer_server,
                                 rows=len(act), batch=B,
                                 finals=len(finals))
            if self.warmup:
                if finals:
                    self._copy_async(logits)
                    self._copy_async(first)
                self._copy_async(mstats)
                self._pending.append(_Pending(
                    "prefill", self.ticks, finals,
                    logits=logits if finals else None,
                    first=first if finals else None, mstats=mstats))
                continue
            self.engine._ingest(mstats)
            if finals:
                self.host_syncs += 1
                lg = np.asarray(logits)
                fi = np.asarray(first)
                for j, i, rid in finals:
                    self._finish_prefill(i, rid, lg[j], int(fi[j]))

    def _finish_prefill(self, i: int, rid: int, logits_row,
                        first_tok: int) -> None:
        """Drain-side completion of one slot's prefill: first token (the
        chunk call's own sampled value — the exact token it scattered into
        the device last-token buffer, so the emitted stream and the decode
        chain can never disagree), radix-cache registration, and need==1
        retirement."""
        s = self.slots[i]
        if s is None or s.rid != rid:
            return
        row = np.asarray(logits_row)
        s.final_logits = row
        self._append_token(s, int(first_tok))
        self._cache_insert(i, row)
        self._retire_if_done(i)

    def _cache_insert(self, i: int, logits_row: np.ndarray) -> None:
        """Register a freshly prefilled prompt's block-aligned prefix (and,
        for block-aligned prompts, its last-token logits) in the radix
        cache. The partial tail block is donated only at retirement — the
        slot still decodes into it."""
        if self.prefix_cache is None:
            return
        s = self.slots[i]
        T = len(s.prompt)
        nfull = T // self.block_size
        if nfull:
            self.prefix_cache.insert_prefix(s.prompt, s.pages[:nfull])
        if T % self.block_size == 0:
            self.prefix_cache.set_logits(s.prompt, logits_row)

    def _decode_round(self) -> bool:
        """Advance every decoding slot one token in one shared decode
        batch; returns whether a round was launched. With
        ``compact_decode`` (paged mode) only the occupied slots ride the
        batch, padded up to the next power-of-two bucket — the decode fn
        specializes per bucket width (AOT-compiled under ``warmup``), so a
        near-empty pool stops paying for ``max_slots`` rows of garbage
        decode. Composition is launch-driven: a slot rides while
        ``launched < need``, so length stops never wait for a drained
        token and EOS stops cost at most one speculative round."""
        act = [i for i, s in enumerate(self.slots)
               if s is not None and not s.prefilling
               and s.launched < s.need]
        if not act:
            return False
        self.max_concurrency = max(self.max_concurrency, len(act))
        if self.paged and self.compact_decode:
            B = min(self.max_slots, 1 << max(len(act) - 1, 0).bit_length())
            row_slots: list[int | None] = act + [None] * (B - len(act))
        else:
            B = self.max_slots
            row_slots = [i if i in act else None for i in range(B)]
        pos = np.zeros((B,), np.int32)
        mask = np.zeros((B,), np.float32)
        temps = np.zeros((B,), np.float32)
        seeds = np.zeros((B,), np.uint32)
        launched: list[tuple[int, int, int]] = []    # (row, slot, rid)
        for j, i in enumerate(row_slots):
            if i is None:
                continue
            s = self.slots[i]
            pos[j] = s.pos
            mask[j] = 1.0
            temps[j] = s.temperature
            seeds[j] = s.seed
            s.pos += 1
            s.launched += 1
            launched.append((j, i, s.rid))
        if self.tracer.enabled:
            # launch-side only (see _prefill_round): no extra host syncs
            self.tracer.span(SpanKind.DECODE_ROUND, self.ticks,
                             self.ticks + 1, server=self.tracer_server,
                             rows=len(act), batch=B)
        org = self._origin_arg(
            self.slots[i].origin if i is not None else None
            for i in row_slots)
        # padding/vacant rows decode garbage tokens whose outputs are
        # discarded; the token mask keeps them out of the gating statistics
        if self.paged:
            # non-decoding rows (padding, vacant OR still prefilling) get
            # an all-null page table so their garbage write lands in the
            # reserved null block instead of a live page; their last-token
            # gathers/scatters hit the buffer's trailing scratch entry
            rows = np.full((B,), self.max_slots, np.int32)
            tbl = np.zeros((B, self.max_pages), np.int32)
            for j, i in enumerate(row_slots):
                if i is not None:
                    rows[j] = i
                    tbl[j] = self.page_table[i]
            exe = (self.engine.paged_executable(
                       "dec", self.block_size, self.max_pages, B,
                       org is not None)
                   if self.warmup else None)
            fn = exe if exe is not None else self._decode_fn
            self._last_buf, nxt, self.pool, mstats = fn(
                self.engine.params, self.pool, self._last_buf,
                jnp.asarray(rows), jnp.asarray(pos), jnp.asarray(tbl),
                self.engine.placement, jnp.asarray(mask),
                jnp.asarray(temps), jnp.asarray(seeds), org)
            self.decode_rows += B
            if self.warmup:
                # zero-stall: round k+1 chains on device through the
                # last-token buffer; the host copy of this round's tokens
                # runs under the next device step and drains one tick late
                self._copy_async(nxt)
                self._copy_async(mstats)
                self._pending.append(_Pending("decode", self.ticks,
                                              launched, nxt=nxt,
                                              mstats=mstats))
                return True
            self.engine._ingest(mstats)
            self.host_syncs += 1
            self._drain_tokens(launched, np.asarray(nxt),
                               self._round_local_frac(mstats))
        else:
            cur = np.zeros((B, 1), np.int32)
            for j, i in enumerate(row_slots):
                if i is not None:
                    cur[j, 0] = self.slots[i].last
            logits, self.pool, mstats = self.engine._decode(
                self.engine.params, self.pool, jnp.asarray(cur),
                jnp.asarray(pos), self.engine.placement, jnp.asarray(mask),
                org)
            self.decode_rows += B
            self.engine._ingest(mstats)
            self.host_syncs += 1
            if np.any(temps > 0.0):
                nxt = np.asarray(sample_tokens(
                    logits, jnp.asarray(temps), jnp.asarray(seeds),
                    jnp.asarray(pos)), np.int32)
            else:
                nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
            self._drain_tokens(launched, nxt,
                               self._round_local_frac(mstats))
        self.rounds += 1
        self._maybe_review()
        return True

    # -- the zero-stall backlog ----------------------------------------
    @staticmethod
    def _copy_async(x) -> None:
        """Start the device->host copy of every leaf of ``x`` without
        blocking (the drain one tick later finds it already resident)."""
        for leaf in jax.tree.leaves(x):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()

    def _fetch(self, x) -> np.ndarray:
        """Drain-side host materialization; counts a host-sync point when
        the async copy has not finished (a genuine stall)."""
        if hasattr(x, "is_ready") and not x.is_ready():
            self.host_syncs += 1
        return np.asarray(x)

    @staticmethod
    def _round_local_frac(mstats) -> float | None:
        """The launch round's mean local-dispatch fraction, computed from
        that round's *own* gating stats. Drains previously read the
        engine's mutable ``last_local_frac`` instead — any sharer of the
        engine (another runtime, a ``generate()`` call) that ingests stats
        between launch and drain would have its round's locality
        misattributed to this one's slots."""
        if mstats is None or "local_frac" not in mstats:
            return None
        return float(np.asarray(mstats["local_frac"]).mean())

    def _drain_tokens(self, rows, nxt: np.ndarray,
                      lf: float | None) -> None:
        """Apply one decode round's tokens to the slots that launched them
        (rid-guarded: an EOS-retired or re-assigned slot drops its
        speculative token). ``lf`` is the round's own local fraction,
        captured from its gating stats at launch (``_round_local_frac``)."""
        for j, i, rid in rows:
            slot = self.slots[i]
            if slot is None or slot.rid != rid:
                continue
            if len(slot.tokens) >= slot.need:
                continue
            self._append_token(slot, int(nxt[j]))
            if lf is not None:
                slot.lf_sum += lf
                slot.lf_rounds += 1
            self._retire_if_done(i)

    def _drain_one(self, p: _Pending) -> None:
        self.engine._ingest(p.mstats)
        if p.kind == "decode":
            self._drain_tokens(p.rows, self._fetch(p.nxt),
                               self._round_local_frac(p.mstats))
            self.rounds += 1
            self._maybe_review()
        else:
            if p.rows:
                lg = self._fetch(p.logits)
                fi = self._fetch(p.first)
                for j, i, rid in p.rows:
                    self._finish_prefill(i, rid, lg[j], int(fi[j]))

    def _drain_backlog(self, before_tick: int | None = None) -> None:
        """Drain pending round records in launch order — all of them, or
        only those launched before ``before_tick`` (the steady-state call
        leaves the current tick's in-flight round pending)."""
        while self._pending and (before_tick is None
                                 or self._pending[0].tick < before_tick):
            self._drain_one(self._pending.popleft())

    def flush(self) -> None:
        """Force-drain the zero-stall backlog: after this, every launched
        round's tokens/events/retirements are applied. No-op on the
        synchronous loop. Call it before reading results when driving
        ``step()`` by hand with ``warmup=True`` (``run()`` ends drained)."""
        self._drain_backlog(None)

    def _maybe_review(self) -> None:
        ctrl = self.controller
        if ctrl is None:
            return
        dec = ctrl.review_and_apply(self.rounds, self.engine)
        if dec is not None and dec.applied:
            self.migrations.append(dec.diag)

    # ------------------------------------------------------------------
    def drop_prefix_cache(self) -> int:
        """Evict every cached prefix and return the blocks recycled (tests
        and memory-pressure escape hatch)."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.clear()

    def check_invariants(self) -> None:
        """Paged-pool structural invariants (used by the test suite):
        refcounts exactly match the holders (slots + radix cache), the
        null block is never allocated, no slot holds a page twice, and the
        next block each live slot will *write* is exclusively owned
        (refcount 1) — the no-CoW-aliasing rule."""
        if not self.paged:
            return
        live = self.allocator.live()
        assert 0 not in live, "null block was allocated"
        held: collections.Counter = collections.Counter()
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            assert len(set(s.pages)) == len(s.pages), \
                f"slot {i} holds a duplicated page: {s.pages}"
            for b in s.pages:
                held[b] += 1
                assert b in live, f"slot {i} references freed block {b}"
            if s.prefilling:
                frontier = s.pages[s.filled // self.block_size]
            elif s.launched < s.need:
                # fully-launched slots awaiting drain are skipped: their
                # pos may sit one past capacity (nothing writes there)
                frontier = s.pages[s.pos // self.block_size]
            else:
                continue
            assert live.get(frontier) == 1, (
                f"write frontier block {frontier} of rid {s.rid} is shared "
                f"(refcount {live.get(frontier)}) — CoW rule violated")
        cache_refs = (self.prefix_cache.block_refs()
                      if self.prefix_cache is not None
                      else collections.Counter())
        for b, rc in live.items():
            expect = held[b] + cache_refs[b]
            assert rc == expect, (
                f"block {b}: refcount {rc} != {held[b]} slot refs + "
                f"{cache_refs[b]} cache refs")
        assert set(held) | set(cache_refs) == set(live), \
            "allocator tracks blocks held by no slot and no cache entry"

    def step(self) -> bool:
        """One scheduler tick: admit what fits, advance chunked prefills,
        launch one decode round, then (warmup mode) drain the previous
        tick's backlog while this tick's round runs on device. Returns
        True while there is (or was) work."""
        had_work = (bool(self.queue) or self.active > 0
                    or bool(self._pending))
        self.ticks += 1
        self._admit()
        if self.paged:
            self._prefill_round()
        t0 = time.perf_counter()
        launched = self._decode_round()
        if self.warmup:
            self._drain_backlog(self.ticks)
        if launched:
            self.decode_round_s.append(time.perf_counter() - t0)
        return had_work

    def run(self) -> dict[int, np.ndarray]:
        """Serve until queue, slots and backlog drain; returns
        {rid: tokens}."""
        while self.queue or self.active or self._pending:
            self.step()
        self.flush()
        return dict(self.finished)

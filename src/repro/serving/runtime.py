"""Continuous-batching serving runtime on top of the jitted ``ServingEngine``
step functions.

``ServingEngine.generate`` serves one synchronous batch: every request in it
starts and finishes together. This runtime serves a *request stream*
instead:

* a request queue — ``submit()`` at any time, including mid-stream;
* a **paged KV-cache pool** (default) — a shared block table of
  ``n_blocks × block_size`` positions per layer plus a per-slot page list
  managed by a free-list ``BlockAllocator``; admission is governed by free
  *blocks*, not free ``max_len`` rows, so heterogeneous request streams
  pack the same KV memory far denser than the legacy dense pool;
* **chunked prefill** — admitted prompts are consumed in
  ``block_size``-aligned chunks (one jitted ``prefill_chunk`` per chunk)
  interleaved with decode rounds, so a long prompt no longer stalls the
  whole pool;
* interleaved prefill/decode — every decoding slot advances one token per
  decode round regardless of arrival time (per-row cache positions via the
  vector-``pos`` decode path).

The legacy dense slot pool (``paged=False``) allocates ``max_slots`` rows
of ``max_len`` positions and prefills whole prompts in one call; it remains
for architectures whose caches cannot be paged (SSM state, sliding-window
rings) and as the reference implementation for the equivalence suite.

Outputs are token-identical to sequential ``generate()`` calls in both
modes as long as the EP dispatch capacities are not saturated (rows are
independent in attention; the MoE layer couples them only through capacity
dropping).

The runtime also hosts the serving side of the placement control plane: it
feeds gating statistics to a ``PlacementController`` and applies adopted
plans to the engine (re-gather + table swap, no recompile).
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import build_ep_placement
from repro.core.policies import PlacementController
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class GenRequest:
    """One queued generation request."""
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int


@dataclasses.dataclass
class _Slot:
    """State of one occupied KV-cache pool row."""
    rid: int
    pos: int                      # next cache write position
    last: int                     # last emitted token (next decode input)
    tokens: list                  # emitted tokens so far
    need: int                     # total tokens to emit
    # paged-mode state
    pages: list = dataclasses.field(default_factory=list)
    prompt: np.ndarray | None = None   # full prompt (chunked prefill)
    filled: int = 0                    # prompt tokens already prefilled

    @property
    def prefilling(self) -> bool:
        return self.prompt is not None and self.filled < len(self.prompt)


class BlockAllocator:
    """Free-list allocator over the physical blocks of a paged KV pool.

    Block 0 is reserved as the *null block*: vacant decode rows point their
    page tables at it and park their garbage writes there, so it is never
    handed out. Allocation is all-or-nothing per request and every block is
    tagged with its owner so cross-slot aliasing and foreign frees are
    structural errors, not silent corruption.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))   # LIFO: hot reuse
        self._owner: dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def capacity_blocks(self) -> int:
        """Allocatable blocks (the null block is excluded)."""
        return self.n_blocks - 1

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: int) -> list[int]:
        """Pop ``n`` blocks for ``owner``; raises when exhausted (callers
        check ``can_alloc`` first and defer admission instead)."""
        if not self.can_alloc(n):
            raise RuntimeError(
                f"paged pool exhausted: requested {n} blocks, "
                f"{len(self._free)} free")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._owner[b] = owner
        return blocks

    def release(self, blocks: list[int], owner: int) -> None:
        """Return ``blocks`` to the free list; every block must belong to
        ``owner`` (double frees and foreign frees raise)."""
        for b in blocks:
            if self._owner.get(b) != owner:
                raise RuntimeError(
                    f"block {b} is not owned by request {owner} "
                    f"(owner: {self._owner.get(b)})")
            del self._owner[b]
            self._free.append(b)

    def owners(self) -> dict[int, int]:
        """Live block -> owner rid (for invariant checks and tests)."""
        return dict(self._owner)


class ServingRuntime:
    """Continuous batching over a shared KV pool.

    engine:      a ``ServingEngine`` (its jitted step functions are reused).
    max_slots:   decode batch width (one compile). In paged mode this is
                 *only* the batch width — KV memory is the block pool.
    controller:  optional ``PlacementController``; its clock is decode
                 rounds (set ``interval`` accordingly). Adopted plans are
                 applied to the engine via ``engine.migrate``.
    paged:       True = paged block pool + chunked prefill; False = legacy
                 dense per-slot rows; None (default) = paged when the
                 architecture supports it (attention caches, no sliding
                 window), dense otherwise.
    block_size:  positions per physical KV block (paged mode).
    n_blocks:    physical blocks incl. the null block. Default sizes the
                 pool to the dense pool's KV memory
                 (``max_slots * max_len`` positions) plus the null block.
    max_pages:   page-table width (max blocks one request may hold); the
                 per-step attention gather is ``max_pages * block_size``
                 positions per row, so this is the cost/length-cap knob.
                 Default: ``2 * ceil(max_len / block_size)``, clamped to
                 the pool.
    chunks_per_tick: prefill chunks consumed per prefilling slot per
                 ``step()`` (interleaving knob).
    """

    def __init__(self, engine: ServingEngine, max_slots: int = 4,
                 controller: PlacementController | None = None, *,
                 paged: bool | None = None, block_size: int = 16,
                 n_blocks: int | None = None, max_pages: int | None = None,
                 chunks_per_tick: int = 1):
        self.engine = engine
        self.max_slots = max_slots
        self.controller = controller
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                # start the review clock: the first (initial-adopt) review
                # must also wait a full interval of observed traffic, not
                # fire on decode round 1 with near-empty stats
                controller.last_review = 0.0
        if paged is None:
            paged = tr.supports_paging(engine.rt)
        self.paged = paged
        if paged:
            self.block_size = block_size
            if n_blocks is None:
                n_blocks = 1 + max_slots * (-(-engine.max_len // block_size))
            self.allocator = BlockAllocator(n_blocks)
            if max_pages is None:
                # per-request length cap: attention gathers max_pages*bs
                # positions per batch row every step, so don't default to
                # the whole pool — 2x the legacy row length keeps long
                # requests admissible at bounded gather cost (pass
                # max_pages=allocator.capacity_blocks for unbounded)
                max_pages = min(self.allocator.capacity_blocks,
                                2 * (-(-engine.max_len // block_size)))
            self.max_pages = max_pages
            self.chunks_per_tick = chunks_per_tick
            self.pool = tr.init_paged_cache(engine.rt, n_blocks, block_size)
            self.page_table = np.zeros((max_slots, self.max_pages), np.int32)
            self._chunk_fn, self._decode_fn = engine.paged_step_fns(
                block_size, self.max_pages)
        else:
            self.pool = tr.init_cache(engine.rt, max_slots, engine.max_len)
        self.slots: list[_Slot | None] = [None] * max_slots
        self.queue: collections.deque[GenRequest] = collections.deque()
        self.finished: dict[int, np.ndarray] = {}
        self.rounds = 0               # decode rounds served (controller clock)
        self.ticks = 0                # scheduler ticks (step() calls)
        self.max_concurrency = 0      # peak active slots in one decode batch
        self.max_admitted = 0         # peak concurrently admitted requests
        self.finished_at: dict[int, int] = {}   # rid -> tick of completion
        self.deferrals = 0            # admissions deferred on free blocks
        self.migrations: list = []
        self._next_rid = 0

        def _write_rows(pool, new, idx):
            return jax.tree.map(
                lambda P, c: P.at[:, idx].set(c.astype(P.dtype)), pool, new)

        self._write_rows = jax.jit(_write_rows)

    # ------------------------------------------------------------------
    def _pages_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        """Blocks a request holds for its lifetime: prompt positions
        0..T-1 (whole blocks — chunked prefill writes block-aligned) plus
        decode writes at T..T+need-2."""
        bs = self.block_size
        return max(-(-prompt_len // bs),
                   -(-(prompt_len + max_new_tokens - 1) // bs))

    @property
    def capacity_tokens(self) -> int:
        """Total KV positions this runtime can hold for live requests."""
        if self.paged:
            return self.allocator.capacity_blocks * self.block_size
        return self.max_slots * self.engine.max_len

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Enqueue one request; returns its id. ``prompt``: [T] int tokens.

        Paged mode validates against the *total pool capacity* (a request
        merely larger than the legacy ``max_len`` is admissible — it just
        holds more pages); dense mode keeps the per-row ``max_len`` bound.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.paged:
            npages = self._pages_needed(len(prompt), max_new_tokens)
            if npages > min(self.allocator.capacity_blocks, self.max_pages):
                raise ValueError(
                    f"prompt({len(prompt)}) + max_new_tokens"
                    f"({max_new_tokens}) needs {npages} blocks; the paged "
                    f"pool caps a request at "
                    f"{min(self.allocator.capacity_blocks, self.max_pages)} "
                    f"blocks ({self.capacity_tokens} positions total)")
        elif len(prompt) + max_new_tokens > self.engine.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds the pool's max_len={self.engine.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(GenRequest(rid, prompt, max_new_tokens))
        return rid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ------------------------------------------------------------------
    def _free_slot_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self) -> int:
        if self.paged:
            n = self._admit_paged()
        else:
            n = self._admit_dense()
        self.max_admitted = max(self.max_admitted, self.active)
        return n

    def _admit_paged(self) -> int:
        """Admit FIFO-head requests while a slot row and enough free blocks
        exist. A head that does not fit *defers* (stays queued, no crash,
        no overtaking) until retirements return blocks."""
        admitted = 0
        while self.queue and self._free_slot_ids():
            r = self.queue[0]
            npages = self._pages_needed(len(r.prompt), r.max_new_tokens)
            if not self.allocator.can_alloc(npages):
                self.deferrals += 1
                break
            self.queue.popleft()
            i = self._free_slot_ids()[0]
            pages = self.allocator.alloc(npages, r.rid)
            self.page_table[i] = 0
            self.page_table[i, :npages] = pages
            self.slots[i] = _Slot(rid=r.rid, pos=0, last=-1, tokens=[],
                                  need=r.max_new_tokens, pages=pages,
                                  prompt=r.prompt, filled=0)
            admitted += 1
        return admitted

    def _admit_dense(self) -> int:
        """Prefill waiting requests into free slots (batching same-length
        prompts so each distinct length compiles once). Returns #admitted."""
        admitted = 0
        while self.queue and self._free_slot_ids():
            free = self._free_slot_ids()
            T = len(self.queue[0].prompt)
            group: list[GenRequest] = []
            rest: collections.deque = collections.deque()
            while self.queue and len(group) < len(free):
                r = self.queue.popleft()
                (group if len(r.prompt) == T else rest).append(r)
            self.queue = rest + self.queue
            tokens = np.stack([r.prompt for r in group])           # [b, T]
            logits, cache, mstats = self.engine._prefill(
                self.engine.params, jnp.asarray(tokens),
                self.engine.placement)
            self.engine._ingest(mstats)
            idx = jnp.asarray(free[:len(group)], jnp.int32)
            self.pool = self._write_rows(self.pool, cache, idx)
            first = np.asarray(jnp.argmax(logits, -1), np.int32)   # [b]
            for j, r in enumerate(group):
                slot = _Slot(rid=r.rid, pos=T, last=int(first[j]),
                             tokens=[int(first[j])], need=r.max_new_tokens)
                self.slots[free[j]] = slot
                self._retire_if_done(free[j])
            admitted += len(group)
        return admitted

    def _retire_if_done(self, i: int) -> bool:
        slot = self.slots[i]
        if slot is not None and len(slot.tokens) >= slot.need:
            self.finished[slot.rid] = np.asarray(slot.tokens, np.int32)
            self.finished_at[slot.rid] = self.ticks
            if self.paged and slot.pages:
                self.allocator.release(slot.pages, slot.rid)
                self.page_table[i] = 0
            self.slots[i] = None
            return True
        return False

    # ------------------------------------------------------------------
    def _prefill_round(self) -> None:
        """Advance every prefilling slot by up to ``chunks_per_tick``
        block-aligned chunks (one B=1 jitted call per chunk). When a slot's
        final chunk lands, its first token is sampled and it joins the
        decode batch from the next round on."""
        bs = self.block_size
        for i, slot in enumerate(self.slots):
            if slot is None or not slot.prefilling:
                continue
            for _ in range(self.chunks_per_tick):
                if not slot.prefilling:
                    break
                T = len(slot.prompt)
                c0 = slot.filled
                valid = min(bs, T - c0)
                chunk = np.zeros((1, bs), np.int32)
                chunk[0, :valid] = slot.prompt[c0:c0 + valid]
                mask = np.zeros((1, bs), np.float32)
                mask[0, :valid] = 1.0
                write_blocks = np.asarray([slot.pages[c0 // bs]], np.int32)
                final = c0 + valid >= T
                last_idx = (T - 1 - c0) if final else bs - 1
                logits, self.pool, mstats = self._chunk_fn(
                    self.engine.params, self.pool, jnp.asarray(chunk),
                    jnp.asarray(self.page_table[i:i + 1]),
                    jnp.asarray(write_blocks), jnp.int32(c0),
                    jnp.int32(last_idx), self.engine.placement,
                    jnp.asarray(mask))
                self.engine._ingest(mstats)
                slot.filled += valid
                if final:
                    first = int(np.asarray(jnp.argmax(logits, -1))[0])
                    slot.pos = T
                    slot.last = first
                    slot.tokens = [first]
                    self._retire_if_done(i)
                    break

    def _decode_round(self) -> None:
        """Advance every decoding slot one token in one shared decode
        batch."""
        act = [i for i, s in enumerate(self.slots)
               if s is not None and not s.prefilling]
        if not act:
            return
        self.max_concurrency = max(self.max_concurrency, len(act))
        cur = np.zeros((self.max_slots, 1), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        mask = np.zeros((self.max_slots,), np.float32)
        for i in act:
            cur[i, 0] = self.slots[i].last
            pos[i] = self.slots[i].pos
            mask[i] = 1.0
        # vacant rows decode garbage tokens whose outputs are discarded;
        # the token mask keeps them out of the gating statistics too
        if self.paged:
            # non-decoding rows (vacant OR still prefilling) get an
            # all-null page table so their garbage write lands in the
            # reserved null block instead of a live page
            tbl = np.where(np.asarray(mask, bool)[:, None],
                           self.page_table, 0).astype(np.int32)
            logits, self.pool, mstats = self._decode_fn(
                self.engine.params, self.pool, jnp.asarray(cur),
                jnp.asarray(pos), jnp.asarray(tbl), self.engine.placement,
                jnp.asarray(mask))
        else:
            logits, self.pool, mstats = self.engine._decode(
                self.engine.params, self.pool, jnp.asarray(cur),
                jnp.asarray(pos), self.engine.placement, jnp.asarray(mask))
        self.engine._ingest(mstats)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)         # [B]
        for i in act:
            slot = self.slots[i]
            slot.pos += 1
            slot.last = int(nxt[i])
            slot.tokens.append(int(nxt[i]))
            self._retire_if_done(i)
        self.rounds += 1
        self._maybe_review()

    def _maybe_review(self) -> None:
        ctrl = self.controller
        if ctrl is None or not ctrl.review_due(self.rounds):
            return
        dec = ctrl.review(self.rounds)
        if dec.adopted and self.engine.rt.ep_spec is not None:
            stacked = build_ep_placement(dec.plan,
                                         self.engine.rt.ep_spec.slots)
            self.engine.migrate(stacked)
            self.migrations.append(dec.diag)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Paged-pool structural invariants (used by the test suite):
        no block referenced by two live slots, page tables consistent with
        the allocator's ownership map, null block never owned."""
        if not self.paged:
            return
        owners = self.allocator.owners()
        assert 0 not in owners, "null block was allocated"
        seen: dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            for b in s.pages:
                assert b not in seen, \
                    f"block {b} held by slots of rids {seen[b]} and {s.rid}"
                seen[b] = s.rid
                assert owners.get(b) == s.rid
        assert len(owners) == len(seen), \
            "allocator tracks blocks owned by no live slot"

    def step(self) -> bool:
        """One scheduler tick: admit what fits, advance chunked prefills,
        then one decode round. Returns True while there is (or was) work."""
        had_work = bool(self.queue) or self.active > 0
        self.ticks += 1
        self._admit()
        if self.paged:
            self._prefill_round()
        self._decode_round()
        return had_work

    def run(self) -> dict[int, np.ndarray]:
        """Serve until queue and slots drain; returns {rid: tokens}."""
        while self.queue or self.active:
            self.step()
        return dict(self.finished)

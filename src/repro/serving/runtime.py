"""Continuous-batching serving runtime on top of the jitted ``ServingEngine``
step functions.

``ServingEngine.generate`` serves one synchronous batch: every request in it
starts and finishes together. This runtime serves a *request stream*
instead:

* a request queue — ``submit()`` at any time, including mid-stream;
* a slot-based KV-cache pool — a fixed pool of ``max_slots`` cache rows,
  allocated once, so the decode step compiles exactly once;
* interleaved prefill/decode — arriving requests are prefilled (batched by
  prompt length) and their cache rows written into free pool slots, then
  every active slot advances one token per decode round regardless of when
  it arrived (per-row cache positions via the vector-``pos`` decode path).

Outputs are token-identical to sequential ``generate()`` calls as long as
the EP dispatch capacities are not saturated (rows are independent in
attention; the MoE layer couples them only through capacity dropping).

The runtime also hosts the serving side of the placement control plane: it
feeds gating statistics to a ``PlacementController`` and applies adopted
plans to the engine (re-gather + table swap, no recompile).
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import build_ep_placement
from repro.core.policies import PlacementController
from repro.models import transformer as tr
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class GenRequest:
    """One queued generation request."""
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int


@dataclasses.dataclass
class _Slot:
    """State of one occupied KV-cache pool row."""
    rid: int
    pos: int                      # next cache write position
    last: int                     # last emitted token (next decode input)
    tokens: list                  # emitted tokens so far
    need: int                     # total tokens to emit


class ServingRuntime:
    """Continuous batching over a fixed KV-slot pool.

    engine:      a ``ServingEngine`` (its jitted prefill/decode are reused).
    max_slots:   decode batch width == KV pool rows (one compile).
    controller:  optional ``PlacementController``; its clock is decode
                 rounds (set ``interval`` accordingly). Adopted plans are
                 applied to the engine via ``engine.migrate``.
    """

    def __init__(self, engine: ServingEngine, max_slots: int = 4,
                 controller: PlacementController | None = None):
        self.engine = engine
        self.max_slots = max_slots
        self.controller = controller
        if controller is not None:
            if controller.stats is None:
                controller.stats = engine.stats
            if controller.last_review is None:
                # start the review clock: the first (initial-adopt) review
                # must also wait a full interval of observed traffic, not
                # fire on decode round 1 with near-empty stats
                controller.last_review = 0.0
        self.pool = tr.init_cache(engine.rt, max_slots, engine.max_len)
        self.slots: list[_Slot | None] = [None] * max_slots
        self.queue: collections.deque[GenRequest] = collections.deque()
        self.finished: dict[int, np.ndarray] = {}
        self.rounds = 0               # decode rounds served (controller clock)
        self.max_concurrency = 0      # peak active slots in one decode batch
        self.migrations: list = []
        self._next_rid = 0

        def _write_rows(pool, new, idx):
            return jax.tree.map(
                lambda P, c: P.at[:, idx].set(c.astype(P.dtype)), pool, new)

        self._write_rows = jax.jit(_write_rows)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Enqueue one request; returns its id. ``prompt``: [T] int tokens."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > self.engine.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds the pool's max_len={self.engine.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(GenRequest(rid, prompt, max_new_tokens))
        return rid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ------------------------------------------------------------------
    def _free_slot_ids(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self) -> int:
        """Prefill waiting requests into free slots (batching same-length
        prompts so each distinct length compiles once). Returns #admitted."""
        admitted = 0
        while self.queue and self._free_slot_ids():
            free = self._free_slot_ids()
            T = len(self.queue[0].prompt)
            group: list[GenRequest] = []
            rest: collections.deque = collections.deque()
            while self.queue and len(group) < len(free):
                r = self.queue.popleft()
                (group if len(r.prompt) == T else rest).append(r)
            self.queue = rest + self.queue
            tokens = np.stack([r.prompt for r in group])           # [b, T]
            logits, cache, mstats = self.engine._prefill(
                self.engine.params, jnp.asarray(tokens),
                self.engine.placement)
            self.engine._ingest(mstats)
            idx = jnp.asarray(free[:len(group)], jnp.int32)
            self.pool = self._write_rows(self.pool, cache, idx)
            first = np.asarray(jnp.argmax(logits, -1), np.int32)   # [b]
            for j, r in enumerate(group):
                slot = _Slot(rid=r.rid, pos=T, last=int(first[j]),
                             tokens=[int(first[j])], need=r.max_new_tokens)
                self.slots[free[j]] = slot
                self._retire_if_done(free[j])
            admitted += len(group)
        return admitted

    def _retire_if_done(self, i: int) -> bool:
        slot = self.slots[i]
        if slot is not None and len(slot.tokens) >= slot.need:
            self.finished[slot.rid] = np.asarray(slot.tokens, np.int32)
            self.slots[i] = None
            return True
        return False

    # ------------------------------------------------------------------
    def _decode_round(self) -> None:
        """Advance every active slot one token in one shared decode batch."""
        act = [i for i, s in enumerate(self.slots) if s is not None]
        if not act:
            return
        self.max_concurrency = max(self.max_concurrency, len(act))
        cur = np.zeros((self.max_slots, 1), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        mask = np.zeros((self.max_slots,), np.float32)
        for i in act:
            cur[i, 0] = self.slots[i].last
            pos[i] = self.slots[i].pos
            mask[i] = 1.0
        # vacant rows decode garbage tokens whose outputs are discarded;
        # the token mask keeps them out of the gating statistics too
        logits, self.pool, mstats = self.engine._decode(
            self.engine.params, self.pool, jnp.asarray(cur),
            jnp.asarray(pos), self.engine.placement, jnp.asarray(mask))
        self.engine._ingest(mstats)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)         # [B]
        for i in act:
            slot = self.slots[i]
            slot.pos += 1
            slot.last = int(nxt[i])
            slot.tokens.append(int(nxt[i]))
            self._retire_if_done(i)
        self.rounds += 1
        self._maybe_review()

    def _maybe_review(self) -> None:
        ctrl = self.controller
        if ctrl is None or not ctrl.review_due(self.rounds):
            return
        dec = ctrl.review(self.rounds)
        if dec.adopted and self.engine.rt.ep_spec is not None:
            stacked = build_ep_placement(dec.plan,
                                         self.engine.rt.ep_spec.slots)
            self.engine.migrate(stacked)
            self.migrations.append(dec.diag)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler tick: admit what fits, then one decode round.
        Returns True while there is (or was) work."""
        had_work = bool(self.queue) or self.active > 0
        self._admit()
        self._decode_round()
        return had_work

    def run(self) -> dict[int, np.ndarray]:
        """Serve until queue and slots drain; returns {rid: tokens}."""
        while self.queue or self.active:
            self.step()
        return dict(self.finished)

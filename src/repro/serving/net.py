"""Heterogeneous topology + communication subsystem (``repro.serving.net``).

Prism's headline claims are about *inter-server communication* over
*heterogeneous* edge hardware, so the interconnect and the per-server
budgets are first-class objects here instead of two scalars on
``ClusterSpec``:

* :class:`ServerProfile` — one edge server's memory/compute caps. The
  memory fields *bound* what the rest of the stack may allocate there:
  ``expert_budget(expert_bytes)`` caps the placement algorithms
  (Algorithm 1's M_n / m_e) and ``kv_block_budget(block_bytes)`` caps the
  serving runtime's paged KV pool on that server. Optional
  ``host_mem_bytes``/``disk_mem_bytes`` open a host-RAM (and modeled
  disk) **expert tier** behind the GPU residency, priced by
  ``host_bw``/``disk_bw`` (see ``repro.serving.tiers``).
* :class:`Topology` — N profiles plus a per-link ``[N, N]`` bandwidth
  (bytes/s) and latency (seconds) matrix. Links may be asymmetric (an
  uplink-constrained WAN hop) and non-uniform (the testbed's 500 Mbps LAN
  next to a 25 Mbps WAN-ish link). ``transfer_seconds`` is the one cost
  primitive everything else prices with.
* :class:`TrafficMeter` — converts the per-origin ``[n_ep, E]`` gating
  attribution the MoE layer already produces into per-(src, dst)-link
  dispatch **bytes** each round: every activation a server routes to a
  remote replica pays ``hidden_bytes`` on the forward link and again on
  the return link. Both ``EdgeCluster`` backends feed it the same counts,
  so modeled cross-server traffic is comparable across worlds.
* :class:`CommCostModel` — the Eq.-4 cost model, link-aware: ``C(P)``
  prices each (origin, expert) activation at the *cheapest resident
  replica's link* instead of a uniform remote penalty, and ``T_mig``
  is the makespan of the staged transfer schedule below.
* :func:`plan_transfers` / :func:`schedule_transfers` — an adopted plan
  becomes per-expert :class:`TransferTask`s: each newly placed expert is
  fetched from its cheapest current holder (or loaded from local storage
  when nowhere resident), transfers on one link are serialized, distinct
  links proceed in parallel, and serving overlaps the whole schedule.
  :class:`StagedMigration` is the in-flight record the
  ``PlacementController`` polls — the plan switches only when the
  schedule's makespan has elapsed (no more instantaneous adoption).

Scheduling is deterministic by construction (tasks are ordered by
(layer, destination, expert); no RNG, no wall clock), so reruns of either
backend complete migrations at identical modeled times.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.placement import PlacementPlan, iter_added_experts


# ---------------------------------------------------------------------------
# Server profiles and the link-cost topology
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerProfile:
    """One edge server's capacity caps (the heterogeneity unit).

    ``mem_bytes`` is the GPU expert-weight budget in bytes (Algorithm 1's
    M_n); ``kv_mem_bytes`` the KV-cache budget (bytes) the serving runtime
    may page into; ``compute_speed`` effective FLOP/s; ``io_speed`` local
    weight-load bytes/s (NVMe/host RAM — the migration fallback when an
    expert is resident nowhere).

    **Expert tiers** (optional, all ``None`` = flat GPU-only server):
    ``host_mem_bytes`` / ``disk_mem_bytes`` open a host-RAM (and modeled
    disk) expert tier *behind* the GPU residency. Tier capacities are
    **inclusive**: host must be >= ``mem_bytes`` and disk >= host — the
    deeper tier always holds a superset, so demotion is free (the host
    copy still exists) and only promotion moves bytes. ``host_bw`` /
    ``disk_bw`` price the host<->device and disk<->host links in bytes/s
    (PCIe-ish vs NVMe-ish); a tiered server must carry them so the cost
    model can compare "fetch from my host tier" against "invoke the
    remote replica"."""
    name: str
    mem_bytes: float = 16e9
    kv_mem_bytes: float = 4e9
    compute_speed: float = 60e12
    io_speed: float = 8e9
    host_mem_bytes: float | None = None
    disk_mem_bytes: float | None = None
    host_bw: float | None = None
    disk_bw: float | None = None

    def __post_init__(self):
        for field in ("host_mem_bytes", "disk_mem_bytes"):
            v = getattr(self, field)
            if v is not None and v <= 0:
                raise ValueError(
                    f"{self.name}: {field}={v} — a tier is either absent "
                    "(None) or has positive capacity; zero-capacity tiers "
                    "are not allowed")
        if self.disk_mem_bytes is not None and self.host_mem_bytes is None:
            raise ValueError(
                f"{self.name}: a disk tier requires a host tier "
                "(disk_mem_bytes set but host_mem_bytes is None)")
        if (self.host_mem_bytes is not None
                and self.host_mem_bytes < self.mem_bytes):
            raise ValueError(
                f"{self.name}: tier capacities must nest — host_mem_bytes "
                f"({self.host_mem_bytes:.3g}) < GPU mem_bytes "
                f"({self.mem_bytes:.3g}); the host tier holds a superset "
                "of GPU residency")
        if (self.disk_mem_bytes is not None
                and self.disk_mem_bytes < self.host_mem_bytes):
            raise ValueError(
                f"{self.name}: tier capacities must nest — disk_mem_bytes "
                f"({self.disk_mem_bytes:.3g}) < host_mem_bytes "
                f"({self.host_mem_bytes:.3g})")

    @property
    def tiered(self) -> bool:
        """True when this server has a host-RAM expert tier."""
        return self.host_mem_bytes is not None

    def expert_budget(self, expert_bytes: float) -> int:
        """Expert slots this server's GPU weight memory can hold
        (M_n / m_e)."""
        return int(self.mem_bytes // expert_bytes)

    def tiered_expert_budget(self, expert_bytes: float) -> int:
        """Expert slots the *deepest* tier can hold. On a tiered server a
        placement plan may legally assign this many experts; only
        ``expert_budget`` of them are GPU-resident at any moment."""
        deepest = self.mem_bytes
        if self.host_mem_bytes is not None:
            deepest = self.host_mem_bytes
        if self.disk_mem_bytes is not None:
            deepest = self.disk_mem_bytes
        return int(deepest // expert_bytes)

    def tier_slots(self, expert_bytes: float) -> tuple[int, int, int]:
        """(gpu, host, disk) *cumulative* expert-slot capacities. Tiers
        are inclusive, so each entry is the total number of experts that
        tier and everything above it can hold (0-size for absent tiers
        means "same as the tier above")."""
        gpu = self.expert_budget(expert_bytes)
        host = (int(self.host_mem_bytes // expert_bytes)
                if self.host_mem_bytes is not None else gpu)
        disk = (int(self.disk_mem_bytes // expert_bytes)
                if self.disk_mem_bytes is not None else host)
        return gpu, host, disk

    def kv_block_budget(self, block_bytes: float) -> int:
        """Paged KV blocks this server's cache memory can hold (>= 1)."""
        return max(int(self.kv_mem_bytes // block_bytes), 1)


@dataclasses.dataclass
class LinkState:
    """Mutable liveness/degradation overlay on a frozen :class:`Topology`.

    The topology's profiled bandwidth/latency matrices describe the
    *healthy* fabric and never change; fault injection flips these
    switches instead (``repro.serving.faults.apply_fault``). Every cost
    primitive below reads the overlay, so the controller, the transfer
    planner and both backends see one consistent view of the fabric the
    moment a fault lands.

    up:        [N] bool — server liveness (False = crashed).
    bw_factor: [N, N] in (0, 1] — per-link bandwidth multiplier
               (1 = healthy, < 1 = degraded).
    """
    up: np.ndarray
    bw_factor: np.ndarray

    @staticmethod
    def fresh(n: int) -> "LinkState":
        return LinkState(np.ones(n, bool), np.ones((n, n)))

    def copy(self) -> "LinkState":
        return LinkState(self.up.copy(), self.bw_factor.copy())


@dataclasses.dataclass(frozen=True)
class Topology:
    """N servers + a per-link cost model.

    bandwidth: [N, N] bytes/s; entry (i, j) is the i -> j link. The
               diagonal is ignored (local traffic never crosses a link).
    latency:   [N, N] seconds per transfer/invocation on the link.

    Both matrices may be asymmetric. Off-diagonal bandwidth must be finite
    and positive so every remote link costs strictly more than local
    compute (nearest-replica routing then never prefers a remote tie).

    ``state`` is the mutable :class:`LinkState` overlay (server liveness,
    link degradation). It is attached at construction and *shared*: the
    ``PlacementController`` enforces one Topology object per cluster, so
    a fault applied by a backend is immediately visible to every cost
    computation.
    """
    profiles: tuple[ServerProfile, ...]
    bandwidth: np.ndarray
    latency: np.ndarray

    def __post_init__(self):
        n = len(self.profiles)
        bw = np.asarray(self.bandwidth, float)
        lat = np.asarray(self.latency, float)
        if bw.shape != (n, n) or lat.shape != (n, n):
            raise ValueError(
                f"bandwidth/latency must be [{n}, {n}] matrices, got "
                f"{bw.shape} / {lat.shape}")
        off = ~np.eye(n, dtype=bool)
        if n > 1 and (~np.isfinite(bw[off]) | (bw[off] <= 0)).any():
            raise ValueError(
                "off-diagonal link bandwidth must be finite and positive")
        if (lat < 0).any():
            raise ValueError("link latency must be >= 0")
        for p in self.profiles:
            if p.tiered and not (p.host_bw is not None
                                 and np.isfinite(p.host_bw)
                                 and p.host_bw > 0):
                raise ValueError(
                    f"{p.name}: tiered profile must price the host<->device "
                    f"link — host_bw={p.host_bw} is not finite and positive")
            if p.disk_mem_bytes is not None and not (
                    p.disk_bw is not None and np.isfinite(p.disk_bw)
                    and p.disk_bw > 0):
                raise ValueError(
                    f"{p.name}: disk tier must price the disk<->host link — "
                    f"disk_bw={p.disk_bw} is not finite and positive")
        object.__setattr__(self, "bandwidth", bw)
        object.__setattr__(self, "latency", lat)
        object.__setattr__(self, "state", LinkState.fresh(n))

    @property
    def n(self) -> int:
        return len(self.profiles)

    @property
    def alive(self) -> np.ndarray:
        """[N] bool server-liveness view (the LinkState overlay)."""
        return self.state.up

    def effective_bandwidth(self) -> np.ndarray:
        """[N, N] profiled bandwidth x the degradation overlay."""
        return self.bandwidth * self.state.bw_factor

    # -- constructors --------------------------------------------------
    @staticmethod
    def uniform(profiles, bandwidth: float = 500e6 / 8,
                rtt: float = 2e-3) -> "Topology":
        """Every pair of servers linked at the same bandwidth/latency (the
        legacy ``ClusterSpec`` interconnect model). ``rtt`` is the
        *round-trip* latency (the legacy per-remote-call charge), split
        evenly across the two legs so ``round_trip_seconds`` reproduces
        it exactly. ``profiles`` is a sequence of :class:`ServerProfile`
        or an int server count."""
        if isinstance(profiles, int):
            profiles = tuple(ServerProfile(f"server{i}")
                             for i in range(profiles))
        profiles = tuple(profiles)
        n = len(profiles)
        bw = np.full((n, n), float(bandwidth))
        lat = np.full((n, n), float(rtt) / 2.0)
        np.fill_diagonal(lat, 0.0)
        return Topology(profiles, bw, lat)

    @staticmethod
    def from_cluster_spec(spec) -> "Topology":
        """Lift a simulator ``ClusterSpec`` (uniform interconnect) into a
        topology. The legacy spec has no separate KV budget, so the whole
        server memory doubles as the KV cap."""
        profiles = tuple(
            ServerProfile(s.name, mem_bytes=s.mem_bytes,
                          kv_mem_bytes=s.mem_bytes,
                          compute_speed=s.compute_speed, io_speed=s.io_speed)
            for s in spec.servers)
        return Topology.uniform(profiles, bandwidth=spec.bandwidth,
                                rtt=spec.rtt)

    def to_cluster_spec(self):
        """Project back onto the simulator's ``ClusterSpec`` (per-server
        compute/io/memory; the scalar interconnect fields fall back to the
        slowest link so legacy consumers stay conservative)."""
        from repro.serving.cluster import ClusterSpec, ServerSpec
        servers = tuple(
            ServerSpec(p.name, mem_bytes=p.mem_bytes,
                       compute_speed=p.compute_speed, io_speed=p.io_speed)
            for p in self.profiles)
        off = ~np.eye(self.n, dtype=bool)
        bw = float(self.bandwidth[off].min()) if self.n > 1 else 500e6 / 8
        round_trip = self.latency + self.latency.T
        rtt = float(round_trip[off].max()) if self.n > 1 else 0.0
        return ClusterSpec(servers=servers, bandwidth=bw, rtt=rtt)

    # -- link costs ----------------------------------------------------
    # All three primitives price against the *effective* bandwidth
    # (profiled x degradation overlay), so a LINK_DEGRADED fault is
    # reflected in migration planning and Eq.-4 costs the moment it lands.
    def transfer_seconds(self, src: int, dst: int, nbytes: float) -> float:
        """Modeled seconds to move ``nbytes`` over the src -> dst link
        (0 for local)."""
        if src == dst:
            return 0.0
        bw = self.bandwidth[src, dst] * self.state.bw_factor[src, dst]
        return float(nbytes / bw + self.latency[src, dst])

    def link_seconds(self, nbytes: float) -> np.ndarray:
        """[N, N] one-way transfer seconds for ``nbytes`` on every link
        (diag 0) — bulk weight moves, which only ride the forward link."""
        out = nbytes / self.effective_bandwidth() + self.latency
        np.fill_diagonal(out, 0.0)
        return out

    def round_trip_seconds(self, nbytes: float) -> np.ndarray:
        """[N, N] request + response transfer seconds: entry (i, j) moves
        ``nbytes`` over the i -> j link and ``nbytes`` back over j -> i
        (diag 0). The invocation-cost primitive — on asymmetric
        topologies the slow return leg prices at ITS OWN link, not the
        forward one."""
        one_way = nbytes / self.effective_bandwidth() + self.latency
        out = one_way + one_way.T
        np.fill_diagonal(out, 0.0)
        return out

    def distance(self, nbytes: float = 1024.0) -> np.ndarray:
        """A link-cost matrix usable as ``mesh_distance`` for
        nearest-replica routing (``placement_from_tables``): relative
        round-trip ordering of links at a nominal per-invocation
        payload."""
        return self.round_trip_seconds(nbytes)

    # -- budgets -------------------------------------------------------
    def expert_budgets(self, expert_bytes: float) -> np.ndarray:
        """[N] per-server expert-slot budgets (Algorithm 1's capacity)."""
        return np.array([p.expert_budget(expert_bytes)
                         for p in self.profiles])

    def kv_block_budgets(self, block_bytes: float) -> np.ndarray:
        """[N] per-server paged-KV block budgets."""
        return np.array([p.kv_block_budget(block_bytes)
                         for p in self.profiles])

    @property
    def tiered(self) -> bool:
        """True when any profile carries a host-RAM expert tier."""
        return any(p.tiered for p in self.profiles)

    def tiered_expert_budgets(self, expert_bytes: float) -> np.ndarray:
        """[N] per-server deepest-tier expert budgets — what Algorithm 1
        may assign when the tier hierarchy backs GPU residency."""
        return np.array([p.tiered_expert_budget(expert_bytes)
                         for p in self.profiles])

    def tier_slot_capacities(self, expert_bytes: float) -> np.ndarray:
        """[N, 3] cumulative (gpu, host, disk) expert-slot capacities."""
        return np.array([p.tier_slots(expert_bytes)
                         for p in self.profiles])

    def host_fetch_seconds(self, server: int, nbytes: float) -> float:
        """Modeled seconds to pull ``nbytes`` from ``server``'s host tier
        into its GPU (the on-demand-fetch / promotion cost). Falls back to
        ``io_speed`` for untiered servers (plain local load)."""
        p = self.profiles[server]
        bw = p.host_bw if p.host_bw is not None else p.io_speed
        return float(nbytes / bw)

    def disk_fetch_seconds(self, server: int, nbytes: float) -> float:
        """Modeled seconds to stage ``nbytes`` disk -> host -> GPU on
        ``server`` (both legs, serialized)."""
        p = self.profiles[server]
        if p.disk_bw is None:
            return self.host_fetch_seconds(server, nbytes)
        return float(nbytes / p.disk_bw) + self.host_fetch_seconds(
            server, nbytes)


def route_targets(residency_l: np.ndarray, link_cost: np.ndarray
                  ) -> np.ndarray:
    """Cheapest resident replica per (origin server, expert) for one layer.

    residency_l: [N, E] (> 0 where the expert is resident).
    link_cost:   [N, N] per-invocation link cost (diagonal 0).
    Returns targets [N, E] int; an origin holding the expert always serves
    it locally. Raises when an expert is resident nowhere (coverage)."""
    res = np.asarray(residency_l) > 0
    N, E = res.shape
    uncovered = ~res.any(axis=0)
    if uncovered.any():
        raise ValueError(
            f"experts {np.where(uncovered)[0].tolist()} resident nowhere "
            "(placement coverage violated)")
    targets = np.empty((N, E), int)
    for src in range(N):
        masked = np.where(res, link_cost[src][:, None], np.inf)   # [N, E]
        targets[src] = np.argmin(masked, axis=0)
        targets[src] = np.where(res[src], src, targets[src])
    return targets


# ---------------------------------------------------------------------------
# Dispatch traffic metering
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficMeter:
    """Per-link dispatch byte accounting from the gating attribution.

    The MoE layer already attributes every gating decision to the server
    the request *originated* at (the ``[n_ep, E]``-per-layer counts both
    backends accumulate). The meter converts those counts into link
    traffic under the active placement: a token whose origin routes expert
    ``e`` to a remote replica sends one ``hidden_bytes`` activation over
    the (origin -> replica) link and receives one back on the (replica ->
    origin) link; local activations meter nothing. Replica choice is the
    cheapest resident link (:func:`route_targets`) — the same
    nearest-replica rule the runtime's ``expert_to_target`` tables encode.
    """
    topology: Topology
    hidden_bytes: float
    link_bytes: np.ndarray = None          # [N, N] cumulative bytes
    link_invocations: np.ndarray = None    # [N, N] forward remote dispatches
    rounds: int = 0
    _snapshot: np.ndarray | None = None    # last observed cumulative counts

    def __post_init__(self):
        n = self.topology.n
        if self.link_bytes is None:
            self.link_bytes = np.zeros((n, n))
        if self.link_invocations is None:
            self.link_invocations = np.zeros((n, n))

    def seed(self, total_counts: np.ndarray) -> None:
        """Set the ``observe`` baseline to an existing cumulative counts
        matrix, so activation history from before this meter existed
        (e.g. a warmed-up engine's lifetime stats) is not booked as
        dispatched traffic."""
        self._snapshot = np.asarray(total_counts, float).copy()

    def record(self, delta_counts: np.ndarray, residency: np.ndarray
               ) -> np.ndarray:
        """Meter one round of gating counts.

        delta_counts: [L, N, E] new activations per (layer, origin, expert).
        residency:    [L, N, E] the active plan's residency.
        Returns this round's [N, N] link-byte matrix (also accumulated)."""
        delta = np.asarray(delta_counts, float)
        res = np.asarray(residency)
        L, N, E = delta.shape
        if res.shape != delta.shape or N != self.topology.n:
            raise ValueError(
                f"counts {delta.shape} / residency {res.shape} do not match "
                f"the {self.topology.n}-server topology")
        tokens = np.zeros((N, N))
        src_idx = np.repeat(np.arange(N), E)
        # per-call, not cached at construction: the topology's LinkState
        # overlay is mutable (fault injection), and replica choice must
        # track the fabric the dispatch actually crossed
        cost = self.topology.round_trip_seconds(self.hidden_bytes)
        for l in range(L):
            tgt = route_targets(res[l], cost)                 # [N, E]
            np.add.at(tokens, (src_idx, tgt.reshape(-1)),
                      delta[l].reshape(-1))
        np.fill_diagonal(tokens, 0.0)                         # local = free
        round_bytes = (tokens + tokens.T) * self.hidden_bytes  # fwd + return
        self.link_bytes += round_bytes
        self.link_invocations += tokens
        self.rounds += 1
        return round_bytes

    def observe(self, total_counts: np.ndarray, residency: np.ndarray
                ) -> np.ndarray:
        """Meter the *delta* since the previous ``observe`` of a cumulative
        counts matrix. ``total_counts`` must be a plain (non-decayed)
        accumulator of true activation volumes — an EMA-tracked
        ``ActivationStats`` would systematically under-meter (the decay
        eats into every delta) and count any pre-primed history as
        dispatched traffic. Both backends keep a dedicated plain
        accumulator for exactly this call."""
        total = np.asarray(total_counts, float)
        if self._snapshot is None or self._snapshot.shape != total.shape:
            self._snapshot = np.zeros_like(total)
        delta = total - self._snapshot
        self._snapshot = total.copy()
        if not (delta > 0).any():
            self.rounds += 1
            return np.zeros_like(self.link_bytes)
        return self.record(np.maximum(delta, 0.0), residency)

    @property
    def cross_server_bytes(self) -> float:
        """Total bytes that crossed any inter-server link."""
        return float(self.link_bytes.sum())

    def summary(self) -> dict:
        """JSON-able metering snapshot (the ``metrics()['net']`` payload)."""
        return {
            "rounds": self.rounds,
            "link_bytes": [[round(float(v), 3) for v in row]
                           for row in self.link_bytes],
            "cross_server_bytes": round(self.cross_server_bytes, 3),
            "remote_invocations": round(float(
                self.link_invocations.sum()), 3),
        }


# ---------------------------------------------------------------------------
# Bandwidth-aware staged migration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransferTask:
    """One expert's weights moving to one server (src == dst: local IO
    load — the expert was resident nowhere). ``start``/``end`` are modeled
    seconds relative to the migration's adoption.

    ``via`` selects the link the bytes ride: ``None`` infers the classic
    behavior (inter-server link, or local ``io_speed`` load when
    src == dst); ``"host"`` is a tier promotion over the destination's
    host<->device link; ``"disk"`` stages disk -> host -> GPU."""
    layer: int
    expert: int
    src: int
    dst: int
    nbytes: float
    start: float = 0.0
    end: float = 0.0
    via: str | None = None


def plan_transfers(old: PlacementPlan, new: PlacementPlan,
                   topology: Topology, expert_bytes: float
                   ) -> list[TransferTask]:
    """Per-expert transfer tasks realizing ``new`` from ``old``: every
    newly placed (layer, server, expert) entry fetches the weights from
    the cheapest *live* current holder's link (local IO when no live
    holder exists — a crashed server cannot source a copy, and its
    resident replicas are lost with it). Degraded links are not excluded,
    but ``link_seconds`` prices them at effective bandwidth, so a healthy
    holder wins whenever one exists. Removals are free (weights are
    dropped, not moved)."""
    res_old = old.residency()                       # [L, N, E]
    cost = topology.link_seconds(expert_bytes)
    up = topology.state.up
    tasks: list[TransferTask] = []
    for l, n, e in iter_added_experts(old, new):
        holders = np.where((res_old[l, :, e] > 0) & up)[0]
        if len(holders):
            src = int(holders[np.argmin(cost[holders, n])])
        else:
            src = n                                  # local storage load
        tasks.append(TransferTask(l, e, src, n, expert_bytes))
    return tasks


def schedule_transfers(tasks: list[TransferTask], topology: Topology,
                       start: float = 0.0) -> float:
    """Schedule tasks over the modeled links: one link moves one expert at
    a time (serialized), distinct links (and local IO loads, serialized
    per destination) proceed in parallel, serving overlaps everything.
    Mutates each task's ``start``/``end``; returns the makespan's finish
    time. Deterministic: tasks are processed in (layer, dst, expert)
    order and nothing consults a clock or RNG."""
    link_free: dict[tuple, float] = {}
    finish = start
    for t in sorted(tasks, key=lambda t: (t.layer, t.dst, t.expert)):
        if t.via == "host":
            dur = topology.host_fetch_seconds(t.dst, t.nbytes)
            key = ("host", t.dst)
        elif t.via == "disk":
            dur = topology.disk_fetch_seconds(t.dst, t.nbytes)
            key = ("host", t.dst)
        elif t.src == t.dst:
            dur = t.nbytes / topology.profiles[t.dst].io_speed
            key = (t.src, t.dst)
        else:
            dur = topology.transfer_seconds(t.src, t.dst, t.nbytes)
            key = (t.src, t.dst)
        t.start = max(start, link_free.get(key, start))
        t.end = t.start + dur
        link_free[key] = t.end
        finish = max(finish, t.end)
    return finish


def trace_transfers(tracer, tasks: list[TransferTask], now: float = 0.0,
                    clock_rate: float = 1.0) -> None:
    """Record one ``TRANSFER_TASK`` span per scheduled transfer on
    ``tracer`` (``repro.serving.obs.Tracer``). Tasks carry start/end in
    modeled seconds relative to the adoption instant
    (:func:`schedule_transfers`); ``now`` is the adoption time on the
    caller's clock and ``clock_rate`` its seconds-per-clock-unit, so the
    spans land on the owning backend's timeline (each on its destination
    server's track)."""
    if tracer is None or not tracer.enabled:
        return
    for t in tasks:
        tracer.span("TRANSFER_TASK", now + t.start / clock_rate,
                    now + t.end / clock_rate, server=t.dst,
                    layer=t.layer, expert=t.expert, src=t.src, dst=t.dst,
                    nbytes=t.nbytes, via=t.via)


@dataclasses.dataclass
class StagedMigration:
    """An adopted-but-not-yet-active plan in flight over the links.

    ``started``/``eta`` are in the owning controller's *clock* units
    (ticks or seconds); ``seconds`` is the modeled transfer makespan in
    seconds (identical across backends for the same plans + topology)."""
    plan: PlacementPlan
    tasks: list[TransferTask]
    started: float
    eta: float
    seconds: float

    @property
    def nbytes(self) -> float:
        """Bytes moved over inter-server links (local IO loads excluded)."""
        return float(sum(t.nbytes for t in self.tasks if t.src != t.dst))


# ---------------------------------------------------------------------------
# Link-aware Eq.-4 cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CommCostModel:
    """Eq.-4 pricing over a real topology (drop-in for
    ``core.migration.CostModel`` wherever a ``PlacementController`` takes
    ``cost=``).

    ``C(P)``: each (origin, expert) activation pays the *cheapest resident
    replica's* per-invocation link cost (2 activation transfers + link
    latency + overhead) instead of a uniform remote penalty — a plan that
    keeps traffic off the slow WAN link now prices lower than one that
    merely keeps it off *any* link. ``T_mig``: the staged transfer
    schedule's makespan (:func:`schedule_transfers`), so Eq. 4 charges a
    migration exactly what the executor will spend."""
    topology: Topology
    expert_bytes: float
    activation_bytes: float
    per_call_overhead: float = 0.0
    tokens_per_horizon: float = 1e4

    def invocation_seconds(self) -> np.ndarray:
        """[N, N] cost of one remote expert invocation per link pair:
        the activation rides the forward link out and the *reverse* link
        back (each priced at its own bandwidth/latency), plus the fixed
        overhead (diag 0)."""
        out = (self.topology.round_trip_seconds(self.activation_bytes)
               + self.per_call_overhead)
        np.fill_diagonal(out, 0.0)
        return out

    def comm_cost_seconds(self, plan: PlacementPlan,
                          freqs: np.ndarray) -> float:
        """C(P) over the horizon: expected per-token-layer invocation cost
        under cheapest-replica routing x token-layer volume."""
        inv = self.invocation_seconds()
        res = plan.residency()
        L, N, _ = res.shape
        total = 0.0
        src = np.arange(N)[:, None]
        for l in range(L):
            tgt = route_targets(res[l], inv)         # [N, E]
            total += float((freqs[l] * inv[src, tgt]).sum())
        return total / L * self.tokens_per_horizon

    def migration_seconds(self, old: PlacementPlan,
                          new: PlacementPlan) -> float:
        """T_mig as the staged schedule's makespan (Eq. 3, link-aware)."""
        tasks = plan_transfers(old, new, self.topology, self.expert_bytes)
        return schedule_transfers(tasks, self.topology)

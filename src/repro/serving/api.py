"""Serving API v1: the typed request/event contract shared by every serving
surface in the repo.

One request shape — :class:`Request` — and one observable lifecycle —
:class:`RequestHandle` emitting structured :class:`Event` records — replace
the positional ``submit(prompt, max_new_tokens, origin=...)`` call and the
raw ``{rid: tokens}`` result dicts. Both execution worlds consume it
identically:

* the **runtime** backend (``ServingRuntime`` over the jitted JAX engines,
  clock = scheduler ticks / decode rounds), and
* the **sim** backend (the event-driven ``EdgeSimulator`` time model,
  clock = seconds),

selected via ``EdgeCluster(backend=...)`` (see ``repro.serving.cluster``),
so a policy, benchmark or example written against this contract runs
unchanged against either.

Event lifecycle of one request::

    submit ──► ADMITTED ──► TOKEN* ──► FINISHED
        │          ▲                      ▲
        ├─ DEFERRED┘   (+ PREFIX_HIT at admission when cached pages matched)
        └─ SHED ──────────────────────────┘

``FINISHED`` carries the per-request metrics (latency in the backend's
clock, queue wait, locality, SLO verdict). The sim backend does not emit
``TOKEN`` events (it models time, not tokens). Under SLO-aware scheduling
(``EdgeCluster(slo_aware=True)`` / ``ServingRuntime(slo_aware=True)``) a
request whose deadline has become unmeetable is *shed*: it gets a ``SHED``
event followed immediately by a terminal ``FINISHED`` with ``tokens=0``,
``shed=True`` and ``slo_met=False`` — shed requests still resolve, they
just resolve empty.

This module is dependency-light (numpy only) on purpose: it is the contract
both backends import, never the other way around.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


class EventType:
    """Lifecycle event names (plain strings for cheap logging/JSON)."""
    ADMITTED = "ADMITTED"        # assigned a slot / started service
    DEFERRED = "DEFERRED"        # admission deferred (pool pressure); FIFO
    PREFIX_HIT = "PREFIX_HIT"    # admission reused cached prefix pages
    TOKEN = "TOKEN"              # one generated token (runtime backend)
    SHED = "SHED"                # dropped by SLO-aware admission: the
    #                              deadline became unmeetable; a terminal
    #                              FINISHED(tokens=0, shed=True) follows
    FINISHED = "FINISHED"        # done; carries the per-request metrics

    ALL = (ADMITTED, DEFERRED, PREFIX_HIT, TOKEN, SHED, FINISHED)

    # cluster-level events (rid = -1): the staged-migration lifecycle of
    # the shared placement control plane, surfaced by
    # ``EdgeCluster.events`` (payload: eta, transfer count/bytes/seconds)
    MIGRATION_STARTED = "MIGRATION_STARTED"      # plan adopted, transfers
    #                                              scheduled on the links
    MIGRATION_COMPLETED = "MIGRATION_COMPLETED"  # transfers done, plan live
    MIGRATION_ABORTED = "MIGRATION_ABORTED"      # in-flight transfers lost a
    #                                              source/link; plan dropped

    # fault-injection lifecycle (rid = -1): one record per consumed
    # FaultSchedule event (payload: the FaultEvent fields), plus the
    # failover bookkeeping the backends attach (victims re-routed,
    # tokens lost)
    SERVER_DOWN = "SERVER_DOWN"          # server crashed; experts/KV lost
    SERVER_JOINED = "SERVER_JOINED"      # server (re)joined empty
    LINK_DEGRADED = "LINK_DEGRADED"      # link bandwidth multiplied down
    LINK_RESTORED = "LINK_RESTORED"      # link back to profiled bandwidth

    CLUSTER = (MIGRATION_STARTED, MIGRATION_COMPLETED, MIGRATION_ABORTED,
               SERVER_DOWN, SERVER_JOINED, LINK_DEGRADED, LINK_RESTORED)


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured lifecycle event.

    ``time`` is in the emitting backend's clock (scheduler ticks for the
    runtime backend, seconds for the simulator); ``data`` is the typed
    payload (token id, deferral depth, the FINISHED metrics dict, ...).
    ``seq`` is the emitting backend's monotonic emission index — the
    tie-breaker that makes merged event streams (``EdgeCluster.events``
    interleaves per-request, migration and fault events) a *stable total
    order*: sort by ``(time, seq)``, never by insertion. -1 marks events
    from legacy emitters that predate sequencing.
    """
    type: str
    rid: int
    time: float
    data: dict = dataclasses.field(default_factory=dict)
    seq: int = -1


class SeqCounter:
    """Shared monotonic event-sequence source. One counter per backend
    (``EdgeCluster`` threads a single instance through its servers and
    its own fault/migration emitters), so equal-time events still have
    one deterministic order on rerun."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def __call__(self) -> int:
        v = self.value
        self.value += 1
        return v


@dataclasses.dataclass
class Request:
    """One typed generation request.

    prompt:          [T] int token ids (coerced to a 1-D int32 array).
    max_new_tokens:  tokens to generate (>= 1).
    origin:          edge server the request *arrived* at — drives routing
                     and the per-origin gating-stats attribution
                     (Algorithm 1's f_n(e)). ``None`` = unattributed.
    temperature:     sampling temperature (>= 0). 0.0 = greedy argmax
                     (bit-identical to serving API v1); > 0 = Gumbel-max
                     temperature sampling keyed by ``seed`` and the token
                     position, so reruns of the same request are
                     bit-identical (top-k/top-p are follow-up work).
    slo:             optional latency budget in the serving backend's clock
                     (ticks or seconds); FINISHED reports ``slo_met``
                     against the backend clock (FINISHED.time - submit
                     time). Under SLO-aware scheduling the backends also
                     *act* on it: deadline-ordered admission and
                     shed-on-overload (see :class:`EventType.SHED`).
    arrival:         arrival time in seconds (sim backend; the runtime
                     backend serves in submission order).
    task:            task-profile name (sim backend: selects the activation
                     distribution its time model samples from).
    eos:             optional stop-token id: generation ends early when the
                     model emits it (the EOS token itself is kept in the
                     output, matching ``max_new_tokens`` truncation of the
                     same stream). Under the runtime's zero-stall loop the
                     stop is detected at most one decode round late — the
                     token stream is unaffected.
    seed:            per-request PRNG seed for temperature sampling
                     (ignored at temperature 0.0). Two requests with the
                     same prompt, temperature and seed draw identical
                     token streams; distinct seeds decorrelate them.
    """
    prompt: np.ndarray
    max_new_tokens: int
    origin: int | None = None
    temperature: float = 0.0
    slo: float | None = None
    arrival: float | None = None
    task: str | None = None
    eos: int | None = None
    seed: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if len(self.prompt) < 1:
            raise ValueError("prompt must contain at least one token")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0 (got {self.temperature}); "
                "0.0 means greedy argmax")
        if not 0 <= int(self.seed) < 2 ** 31:
            raise ValueError(f"seed must be in [0, 2**31) (got {self.seed})")
        if self.slo is not None and self.slo <= 0:
            raise ValueError(f"slo must be positive (got {self.slo})")
        if self.origin is not None and self.origin < 0:
            raise ValueError(f"origin must be >= 0 (got {self.origin})")
        if self.eos is not None and self.eos < 0:
            raise ValueError(f"eos must be >= 0 (got {self.eos})")


class RequestHandle:
    """Observable lifecycle of one submitted :class:`Request`.

    Backends append :class:`Event` records via :meth:`_emit`; consumers read
    ``events``, ``tokens`` (runtime backend), ``done`` and ``metrics`` (the
    FINISHED payload), or call :meth:`result` for the generated tokens.
    """

    def __init__(self, rid: int, request: Request, clock: str = "ticks",
                 seq: "SeqCounter | None" = None):
        self.rid = rid
        self.request = request
        self.clock = clock                 # "ticks" | "seconds"
        self._seqc = seq                   # shared backend event sequencer
        self.events: list[Event] = []
        self.server: int | None = None     # server the request was routed to
        self.submitted_at: float | None = None
        self.admitted_at: float | None = None
        self.deferred_ticks = 0            # scheduler ticks spent deferred
        self._tokens: list[int] = []
        self._finished: dict | None = None

    # -- backend side ------------------------------------------------------
    def _emit(self, type_: str, time: float, **data) -> Event:
        ev = Event(type_, self.rid, time, data,
                   self._seqc() if self._seqc is not None else -1)
        self.events.append(ev)
        if type_ == EventType.ADMITTED:
            self.admitted_at = time
            # first writer wins: a cluster router assigns the serving
            # server at submit time; the runtime's ADMITTED event (which
            # reports the *origin*) must not clobber that routing decision
            if self.server is None and data.get("server") is not None:
                self.server = int(data["server"])
        elif type_ == EventType.TOKEN:
            self._tokens.append(int(data["token"]))
        elif type_ == EventType.FINISHED:
            self._finished = data
        return ev

    # -- consumer side -----------------------------------------------------
    @property
    def done(self) -> bool:
        """True once the FINISHED event has been recorded."""
        return self._finished is not None

    @property
    def tokens(self) -> np.ndarray:
        """Generated tokens so far ([0] before any TOKEN event; the sim
        backend never emits tokens — use ``metrics`` there)."""
        return np.asarray(self._tokens, np.int32)

    @property
    def metrics(self) -> dict:
        """The FINISHED payload (latency, wait, locality, slo_met, ...);
        empty until the request finishes."""
        return dict(self._finished) if self._finished is not None else {}

    def result(self) -> np.ndarray:
        """The full generated token array; raises if not ``done`` yet."""
        if not self.done:
            raise RuntimeError(
                f"request {self.rid} has not finished; drive the runtime or "
                "cluster (step()/run()) before reading the result")
        return self.tokens

    def __repr__(self) -> str:  # debugging aid, not a stable format
        state = "done" if self.done else (
            "active" if self.admitted_at is not None else "queued")
        return (f"RequestHandle(rid={self.rid}, {state}, "
                f"events={len(self.events)}, clock={self.clock!r})")


# ---------------------------------------------------------------------------
# Routing: server selection, lifted out of the simulator so both backends
# (and EdgeCluster) share one pluggable policy
# ---------------------------------------------------------------------------

@runtime_checkable
class Router(Protocol):
    """Pick the serving server for a request.

    ``origin`` is the arrival server (or None); ``loads`` is a [N] array of
    earliest-start estimates — ``max(timeline.free, arrival)`` in the
    simulator, queue+active backlog in the runtime backend.
    """

    def route(self, origin: int | None, loads: np.ndarray) -> int:
        ...


@dataclasses.dataclass(frozen=True)
class HomeRouter:
    """Serve at the arrival server (the paper's default); requests without
    an origin fall back to the least-loaded server."""

    def route(self, origin: int | None, loads: np.ndarray) -> int:
        if origin is not None:
            return int(origin)
        return int(np.argmin(loads))


@dataclasses.dataclass(frozen=True)
class LeastLoadedRouter:
    """Redirect every request to the server that can start it earliest
    (the simulator's ``redirect=True`` baseline)."""

    def route(self, origin: int | None, loads: np.ndarray) -> int:
        return int(np.argmin(loads))


def as_router(router: "Router | str | None") -> Router:
    """Normalize: Router object | name ("home" / "least-loaded") | None."""
    if router is None:
        return HomeRouter()
    if isinstance(router, str):
        try:
            return {"home": HomeRouter,
                    "least-loaded": LeastLoadedRouter}[router]()
        except KeyError:
            raise KeyError(f"unknown router {router!r}; "
                           "available: 'home', 'least-loaded'") from None
    if isinstance(router, Router):
        return router
    raise TypeError(f"not a router: {router!r}")

"""Event-driven multi-server MoE inference simulator (paper Sec. IV).

Decomposed into the paper's five components, each a small class that can be
reused or swapped independently:

  1. ``ArrivalSource``      — prompt sequence generator (Poisson arrivals +
     token volumes, from ``repro.data.traces``).
  2. request routing        — per-layer expert activations sampled from the
     request's task profile (``TimeModel.sample_layer_counts``) + server
     selection via the serving API's pluggable routers
     (``repro.serving.api.HomeRouter`` / ``LeastLoadedRouter`` — the same
     objects the runtime-backed ``EdgeCluster`` uses; the simulator-local
     ``Router`` class survives only as a ``DeprecationWarning`` shim).
  3. ``TimeModel``          — linear comm/comp estimator from the cluster
     spec (bandwidth, RTT, FLOP rates, IO speed).
  4. Eq.-1 time stamps      — a layer completes when its slowest expert
     invocation returns (``TimeModel.collab_layer``), on top of the
     dense-path time.
  5. ``Timeline``           — per-server FIFO occupancy plus asynchronous
     remote-compute load on target servers; migration adds per-server
     weight-loading pauses (Eq. 3) — unless a ``repro.serving.net
     .Topology`` is attached, in which case remote invocations price
     their actual (origin -> replica) link and adopted plans migrate via
     the bandwidth-aware *staged* executor (transfers scheduled over the
     modeled links, overlapped with serving; the plan switches only when
     they complete).

Placement and migration run through the unified control plane
(``repro.core.policies.PlacementController``): the simulator feeds it
per-request activation counts and asks it to review periodically — exactly
the calls the JAX serving runtime makes, so policy/controller behaviour is
identical in both worlds. A static ``PlacementPlan`` or the legacy
``MigrationController`` shim are still accepted.

Also implements the paper's Table-I baselines: single-server memory
offloading ("MoE-Infinity"-style), with and without request redirection.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.migration import MigrationController
from repro.core.placement import PlacementPlan
from repro.core.policies import PlacementController
from repro.core.stats import ActivationStats
from repro.data.traces import Request, Workload
from repro.serving.api import HomeRouter, LeastLoadedRouter, as_router
from repro.serving.cluster import ClusterSpec, MoEProfile


# ---------------------------------------------------------------------------
# Components
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArrivalSource:
    """Component 1: yields requests in arrival order.

    Accepts a materialized ``Workload`` or any iterable of requests in
    arrival order — a streaming generator (e.g.
    ``repro.serving.workload.WorkloadStream``) is consumed lazily, so a
    million-request scenario never exists in memory at once."""

    workload: "Workload | object"

    def __iter__(self):
        reqs = getattr(self.workload, "requests", self.workload)
        return iter(reqs)


def slo_admission(server: int, loads: np.ndarray, deadline: float) -> tuple[str, int]:
    """The time model's SLO-aware admission rule, shared with the cluster
    sim backend (``EdgeCluster(slo_aware=True)``).

    ``loads`` is the [N] earliest-start estimate (``EdgeSimulator.loads``:
    ``max(timeline.free, arrival)``, ``inf`` for dead servers); ``server``
    the router's choice. Returns one of

    * ``("serve", server)`` — the chosen server can start by the deadline;
    * ``("redirect", n)`` — it cannot, but the earliest-start server ``n``
      can: serve there instead (deadline-aware deferral, the seconds-clock
      analogue of the runtime's deadline-ordered queue);
    * ``("shed", -1)`` — no live server can start by the deadline: the
      request is doomed and admitting it would only delay others.
    """
    best = int(np.argmin(loads))
    if float(loads[best]) > deadline:
        return ("shed", -1)
    if 0 <= server < len(loads) and float(loads[server]) <= deadline:
        return ("serve", server)
    return ("redirect", best)


@dataclasses.dataclass
class Timeline:
    """Component 5: per-server occupancy. ``free[n]`` is the time server n
    finishes its current FIFO backlog; remote expert calls add asynchronous
    compute load to their target server."""

    free: np.ndarray  # [N]

    @staticmethod
    def create(n: int) -> "Timeline":
        return Timeline(free=np.zeros(n))

    def start_time(self, server: int, arrival: float) -> float:
        return max(arrival, float(self.free[server]))

    def occupy(self, server: int, until: float) -> None:
        self.free[server] = until

    def add_async(self, targets: np.ndarray, comp: np.ndarray) -> None:
        np.add.at(self.free, targets, comp)

    def pause(self, delays: np.ndarray) -> None:
        """Stall every server (Eq.-3 weight loading)."""
        self.free += delays


@dataclasses.dataclass
class Router:
    """DEPRECATED simulator-local router — the routing policies now live in
    ``repro.serving.api`` (``HomeRouter`` / ``LeastLoadedRouter``) so the
    runtime-backed ``EdgeCluster`` and the simulator share them. This shim
    keeps the old ``route(req, timeline)`` signature."""

    redirect: bool = False

    def __post_init__(self):
        warnings.warn(
            "serving.simulator.Router is deprecated: use "
            "repro.serving.api.HomeRouter / LeastLoadedRouter (or pass "
            "router= to EdgeSimulator / EdgeCluster)",
            DeprecationWarning,
            stacklevel=3,
        )

    def route(self, req: Request, timeline: Timeline) -> int:
        loads = np.maximum(timeline.free, req.arrival)
        if self.redirect:
            return LeastLoadedRouter().route(req.server, loads)
        return HomeRouter().route(req.server, loads)


class TimeModel:
    """Components 3 + 4: the linear per-token-batch comm/comp estimator and
    the Eq.-1 per-layer completion semantics.

    ``topology`` (a ``repro.serving.net.Topology``) replaces the uniform
    ``cluster.bandwidth``/``rtt`` interconnect with per-link costs: remote
    expert invocations price the actual (origin -> replica) link and the
    replica choice minimizes earliest completion (queue + link), so a slow
    WAN-ish link is avoided when a nearer replica exists. Without it the
    legacy uniform model is bit-identical to before."""

    def __init__(self, cluster: ClusterSpec, profile: MoEProfile, topology=None):
        self.cluster, self.profile = cluster, profile
        self.topology = topology
        self.speeds = np.array([s.compute_speed for s in cluster.servers])
        self.io = np.array([s.io_speed for s in cluster.servers])
        # optional repro.serving.tiers.TierManager: experts parked in a
        # back tier pay a modeled host/disk fetch stall (locally and as a
        # surcharge on remote candidates). None = flat GPU pricing,
        # bit-identical to the pre-tier model.
        self.tiers = None

    def sample_layer_counts(self, rng, probs, tokens: int) -> np.ndarray:
        """Component 2: per-layer expert activations for one request."""
        return rng.multinomial(tokens * self.profile.top_k, probs)  # [L, E]

    def dense_time(self, tokens: int, server: int) -> float:
        return tokens * self.profile.dense_flops_per_token / self.speeds[server]

    def _tier_table(self, layer: int | None) -> np.ndarray | None:
        """[N, E] tier assignment for this layer, or None when no
        TierManager is attached (flat pricing)."""
        tm = self.tiers
        if tm is None or layer is None or tm.tier is None or layer >= tm.tier.shape[0]:
            return None
        return tm.tier[layer]

    def collab_layer(
        self,
        counts: np.ndarray,
        res_l: np.ndarray,
        server: int,
        timeline: Timeline,
        layer: int | None = None,
    ) -> tuple[float, float, float]:
        """Eq. 1 for one layer under a placement residency ``res_l``
        [N, E]: local experts compute at the home server; remote experts go
        to the nearest-idle replica (comm + comp, async load on the
        target). With a :class:`~repro.serving.tiers.TierManager` attached
        (``layer=`` identifies the row of its tier table), an expert a
        server holds only in a back tier pays that tier's on-demand fetch
        stall before computing — locally and, as a surcharge, on remote
        replica candidates. Returns (layer time, local hits, total
        activations)."""
        pf = self.profile
        tier_l = self._tier_table(layer)
        active = counts > 0
        local = active & (res_l[server] > 0)
        remote = active & ~local
        comp_b = counts * pf.expert_flops_per_token
        worst = (
            float((comp_b * local).max() / self.speeds[server]) if local.any() else 0.0
        )
        if tier_l is not None and local.any():
            back = local & (tier_l[server] > 0)
            if back.any():
                if tier_l[server][back].max() > 1:
                    stall = self.topology.disk_fetch_seconds(server, pf.expert_bytes)
                else:
                    stall = self.topology.host_fetch_seconds(server, pf.expert_bytes)
                worst = max(
                    worst, float(comp_b[back].max() / self.speeds[server]) + stall
                )
        hits = float(counts[local].sum())
        tot = float(counts[active].sum())
        if remote.any():
            free_m = np.where(
                res_l.T[remote] > 0, timeline.free[None], np.inf
            )  # [R, N]
            if self.topology is not None:
                # per-link pricing: candidate replica n costs its queue
                # plus the (server -> n) dispatch and the (n -> server)
                # return for this batch — each leg at its own link (they
                # differ on asymmetric topologies)
                per_tok = (
                    pf.hidden_bytes_per_token / self.topology.bandwidth[server]
                    + pf.hidden_bytes_per_token / self.topology.bandwidth[:, server]
                )  # [N]
                lat2 = (
                    self.topology.latency[server] + self.topology.latency[:, server]
                )  # [N]
                comm_m = (
                    counts[remote][:, None] * per_tok[None, :] + lat2[None, :]
                )  # [R, N]
                if tier_l is not None:
                    # a candidate holding the expert only in a back tier
                    # must fetch it first — surcharge its column
                    t_re = tier_l.T[remote]  # [R, N]
                    fetch_n = np.array(
                        [
                            self.topology.host_fetch_seconds(i, pf.expert_bytes)
                            for i in range(res_l.shape[0])
                        ]
                    )
                    disk_n = np.array(
                        [
                            self.topology.disk_fetch_seconds(i, pf.expert_bytes)
                            for i in range(res_l.shape[0])
                        ]
                    )
                    comm_m = comm_m + np.where(
                        t_re == 1,
                        fetch_n[None, :],
                        np.where(t_re == 2, disk_n[None, :], 0.0),
                    )
                tgt = np.argmin(free_m + comm_m, axis=-1)
                comm = comm_m[np.arange(len(tgt)), tgt]
            else:
                tgt = np.argmin(free_m, axis=-1)
                comm = (
                    2 * counts[remote] * pf.hidden_bytes_per_token
                    / self.cluster.bandwidth
                    + self.cluster.rtt
                )
            comp = comp_b[remote] / self.speeds[tgt]
            timeline.add_async(tgt, comp)  # async load
            worst = max(worst, float((comm + comp).max()))
        return worst, hits, tot

    def offload_service(
        self, layer_counts: np.ndarray, server: int, cache_mask_n: np.ndarray
    ) -> tuple[float, float, float]:
        """Single-server offloading: cached experts compute locally, misses
        load weights from host RAM (MoE-Infinity baseline)."""
        pf = self.profile
        comp = layer_counts * pf.expert_flops_per_token / self.speeds[server]
        miss = (layer_counts > 0) & ~cache_mask_n
        t_le = comp + miss * (pf.expert_bytes / self.io[server])
        service = t_le.max(-1).sum()
        hits = float((layer_counts * cache_mask_n).sum())
        tot = float(layer_counts.sum())
        return service, hits, tot

    def migration_pause(
        self, old_res: np.ndarray, new_res: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Eq. 3: per-server stall for newly placed expert weights.
        Returns (delays [N] seconds, experts added per server [N])."""
        added = np.maximum(new_res - old_res, 0).sum(0).sum(-1)  # [N]
        return added * self.profile.expert_bytes / self.io, added


@dataclasses.dataclass
class LocalRatioTracker:
    """Bucketed local-compute-ratio time series."""

    bucket: float
    samples: list = dataclasses.field(default_factory=list)
    hits: float = 0.0
    tot: float = 0.0
    next_bucket: float = dataclasses.field(init=False)

    def __post_init__(self):
        self.next_bucket = self.bucket

    def add(self, hits: float, tot: float) -> None:
        self.hits += hits
        self.tot += tot

    def roll(self, now: float) -> None:
        while now >= self.next_bucket:
            self.samples.append((self.next_bucket, self.hits / max(self.tot, 1.0)))
            self.hits = self.tot = 0.0
            self.next_bucket += self.bucket

    def flush(self) -> None:
        """Emit the trailing partial bucket (previously dropped)."""
        if self.tot > 0:
            self.samples.append((self.next_bucket, self.hits / max(self.tot, 1.0)))
            self.hits = self.tot = 0.0


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    latencies: np.ndarray  # per request
    servers: np.ndarray  # per request (arrival/home server)
    finish_times: np.ndarray
    local_ratio_t: list  # (time, ratio) samples
    migrations: list  # diagnostics dicts
    stats: ActivationStats
    routed: np.ndarray | None = None  # per request: serving server
    hits_by_server: np.ndarray | None = None  # [N] local activations served
    tot_by_server: np.ndarray | None = None  # [N] total activations served

    def avg_latency_per_server(self, n: int) -> np.ndarray:
        return np.array(
            [
                self.latencies[self.servers == i].mean()
                if (self.servers == i).any()
                else 0.0
                for i in range(n)
            ]
        )

    @property
    def avg_latency(self) -> float:
        return float(self.latencies.mean())


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class EdgeSimulator:
    def __init__(
        self,
        cluster: ClusterSpec,
        profile: MoEProfile,
        workload: Workload,
        plan: PlacementPlan | None = None,
        controller=None,
        mode: str = "collab",
        redirect: bool = False,
        seed: int = 0,
        ratio_bucket: float = 60.0,
        router=None,
        topology=None,
    ):
        """mode: 'collab' (distributed expert calls under `plan`) or
        'offload' (each server caches its own top experts; misses load
        weights from host RAM — the MoE-Infinity-style baseline).
        controller: a ``PlacementController`` (or the deprecated
        ``MigrationController`` shim).
        redirect: route each request to the least-loaded server first
        (sugar for ``router=LeastLoadedRouter()``).
        router: a ``repro.serving.api.Router`` (overrides ``redirect``).
        topology: optional ``repro.serving.net.Topology`` — per-link
        comm costs in the time model and bandwidth-aware *staged*
        migration (an adopted plan activates only after its modeled
        transfers finish, replacing the instantaneous Eq.-3 pause).
        Defaults to the controller's topology when it has one."""
        assert mode in ("collab", "offload")
        if mode == "collab" and plan is None and controller is None:
            raise ValueError("collab mode needs a plan or a controller")
        self.cluster, self.profile, self.workload = cluster, profile, workload
        self.plan = plan
        self.controller = self._unwrap(controller)
        if self.controller is not None:
            # one shared link model; the profile knows m_e for transfers
            topology = self.controller.attach_topology(
                topology, expert_bytes=profile.expert_bytes
            )
        self.topology = topology
        self.mode = mode
        self.rng = np.random.default_rng(seed)
        self.source = ArrivalSource(workload)
        self.router = (
            as_router(router)
            if router is not None
            else LeastLoadedRouter()
            if redirect
            else HomeRouter()
        )
        self.time_model = TimeModel(cluster, profile, topology=topology)
        self.ratio_bucket = ratio_bucket
        self._started = False
        # fault model: with a topology attached, crashed servers (its
        # LinkState's ``up`` flags) are dropped from the serving residency
        # so their experts stop being dispatch targets. The no-failover
        # measurement baseline turns this off — it models a cluster
        # oblivious to the crash (see EdgeCluster ``failover=``).
        self.mask_dead_residency = True

    @staticmethod
    def _unwrap(controller) -> PlacementController | None:
        if controller is None:
            return None
        if isinstance(controller, MigrationController):
            return controller.ctrl
        return controller

    # ------------------------------------------------------------------
    def _offload_caches(self) -> list[list[set]]:
        """Per-server per-layer cached expert sets for offload mode (each
        server keeps its own most-frequent experts, split evenly across
        layers)."""
        cl, pf = self.cluster, self.profile
        exp_freq = self.workload.freqs_by_server(cl.n)  # [L, N, E]
        cap = cl.expert_capacity(pf.expert_bytes)
        per_layer = np.maximum(cap // pf.num_layers, 1)
        caches = []
        for n in range(cl.n):
            layers = []
            for l in range(pf.num_layers):
                k = min(int(per_layer[n]), pf.num_experts)
                layers.append(set(np.argsort(-exp_freq[l, n])[:k]))
            caches.append(layers)
        return caches

    # ------------------------------------------------------------------
    # Incremental core: ``start()`` -> ``serve_request()`` per request (in
    # arrival order) -> ``finish()``. ``run()`` composes them over the
    # workload; the EdgeCluster "sim" backend drives them request-by-
    # request from the typed serving API instead.
    # ------------------------------------------------------------------
    def start(self) -> None:
        """Initialize the mutable run state (timeline, trackers, initial
        placement review, offload caches). Idempotent per run."""
        if self._started:
            return
        cl, pf = self.cluster, self.profile
        N, L, E = cl.n, pf.num_layers, pf.num_experts
        self._timeline = Timeline.create(N)
        self._ratio = LocalRatioTracker(self.ratio_bucket)
        ctrl = self.controller
        if ctrl is not None and ctrl.stats is None:
            ctrl.stats = ActivationStats(L, N, E)
        self._stats = ctrl.stats if ctrl is not None else ActivationStats(L, N, E)
        self._plan = self.plan
        if ctrl is not None:
            self._plan = ctrl.review(0.0).plan  # initial placement
        self._res = (
            self._plan.residency() if self._plan is not None else None
        )  # [L, N, E]
        if self.mode == "offload":
            caches = self._offload_caches()
            self._cache_mask = np.zeros((N, L, E), bool)
            for n in range(N):
                for l in range(L):
                    self._cache_mask[n, l, list(caches[n][l])] = True
        self._latencies: list = []
        self._servers: list = []
        self._routed: list = []
        self._finishes: list = []
        self._migrations: list = []
        self._hits_by_server = np.zeros(N)
        self._tot_by_server = np.zeros(N)
        # plain cumulative per-origin activation counts for the traffic
        # meter — deliberately NOT the controller's ActivationStats, which
        # may be EMA-decayed (metering needs true volumes, and must not
        # count pre-primed historical stats as dispatched traffic)
        self._dispatch_counts = np.zeros((L, N, E))
        self._started = True

    def serve_request(self, r: Request) -> dict:
        """Serve one request (callers must present requests in arrival
        order). Returns its timing/locality record — the payload the
        EdgeCluster sim backend turns into ADMITTED/FINISHED events."""
        self.start()
        cl, pf, tm = self.cluster, self.profile, self.time_model
        L = pf.num_layers
        timeline, ratio, ctrl = self._timeline, self._ratio, self.controller
        n = self.router.route(r.server, self.loads(r.arrival))
        start = timeline.start_time(n, r.arrival)
        tokens = r.prompt_tokens + r.decode_tokens
        probs = self.workload.tasks[r.task].probs
        layer_counts = tm.sample_layer_counts(self.rng, probs, tokens)
        dense_t = tm.dense_time(tokens, n)
        req_hits = req_tot = 0.0
        if self.mode == "offload":
            service, hits, tot = tm.offload_service(
                layer_counts, n, self._cache_mask[n]
            )
            service += L * dense_t
            ratio.add(hits, tot)
            req_hits, req_tot = hits, tot
        else:
            res = self._effective_res()
            service = 0.0
            for l in range(L):
                worst, hits, tot = tm.collab_layer(
                    layer_counts[l], res[l], n, timeline, layer=l
                )
                ratio.add(hits, tot)
                req_hits += hits
                req_tot += tot
                service += dense_t + worst
        done = start + service
        timeline.occupy(n, done)
        self._latencies.append(done - r.arrival)
        self._servers.append(r.server)
        self._routed.append(n)
        self._finishes.append(done)
        self._hits_by_server[n] += req_hits
        self._tot_by_server[n] += req_tot
        self._stats.update_server(r.server, layer_counts)
        self._dispatch_counts[:, r.server, :] += layer_counts
        ratio.roll(done)

        migrated = False
        if ctrl is not None:
            migrated = self.poll_migration(done)
            dec = ctrl.review(done)
            if dec.adopted and dec.staged:
                self._migrations.append(
                    {
                        "time": done,
                        "staged": True,
                        "eta": dec.diag["eta"],
                        "transfers": dec.diag["transfers"],
                        "transfer_bytes": dec.diag["transfer_bytes"],
                    }
                )
            elif dec.adopted and not dec.staged:
                new_res = dec.plan.residency()
                delays, added = tm.migration_pause(self._res, new_res)  # Eq.3
                timeline.pause(delays)
                self._migrations.append(
                    {"time": done, "added_per_server": added.tolist()}
                )
                self._plan, self._res = dec.plan, new_res
                migrated = True
        return {
            "origin": r.server,
            "server": n,
            "start": start,
            "done": done,
            "latency": done - r.arrival,
            "hits": req_hits,
            "tot": req_tot,
            "migrated": migrated,
        }

    def poll_migration(self, now: float) -> bool:
        """Complete the controller's in-flight staged migration once its
        transfers have landed (``now >= eta``): the pending plan becomes
        the serving residency with no stall — the link schedule already
        charged the move, overlapped with serving, replacing the
        instantaneous Eq.-3 pause. Called per served request and by the
        fault path (which fast-forwards stalled requests to the recovery
        plan's eta). Returns whether a switch happened."""
        ctrl = self.controller
        if ctrl is None:
            return False
        comp = ctrl.poll(now)
        if comp is None:
            return False
        new_res = comp.plan.residency()
        added = np.maximum(new_res - self._res, 0).sum(0).sum(-1)
        self._migrations.append(
            {
                "time": now,
                "completed": True,
                "staged_at": comp.started,
                "eta": comp.eta,
                "transfer_seconds": comp.seconds,
                "transfer_bytes": comp.nbytes,
                "added_per_server": added.tolist(),
            }
        )
        self._plan, self._res = comp.plan, new_res
        return True

    def adopt_plan(self, plan) -> None:
        """Switch the serving residency to ``plan`` immediately (the fault
        path's instant adoption, when recovery needs no transfers)."""
        self.start()
        self._plan, self._res = plan, plan.residency()

    def _effective_res(self) -> np.ndarray:
        """The serving residency minus crashed servers: a dead server's
        experts are not dispatch targets. Bit-identical to ``_res`` while
        every server is up (or without a topology / with
        ``mask_dead_residency`` off)."""
        res = self._res
        if res is None or not self.mask_dead_residency or self.topology is None:
            return res
        up = np.asarray(self.topology.state.up)
        if up.all():
            return res
        return res * up.astype(res.dtype)[None, :, None]

    def uncovered_live_experts(self) -> bool:
        """True when some expert has no replica on any live server — a
        crash amputated its only holder(s); requests stall until the
        recovery migration restores coverage."""
        res = self._effective_res()
        if res is None or res is self._res:
            return False
        return bool((res.sum(1) <= 0).any())

    def loads(self, arrival: float = 0.0) -> np.ndarray:
        """[N] earliest-start estimate per server (the router's input);
        crashed servers report ``inf`` so no router picks them."""
        self.start()
        loads = np.maximum(self._timeline.free, arrival)
        if self.topology is not None:
            up = np.asarray(self.topology.state.up)
            if not up.all():
                loads = np.where(up, loads, np.inf)
        return loads

    def local_ratio_by_server(self) -> np.ndarray:
        """[N] local-compute ratio of the traffic each server has served so
        far (live view; 1.0 for servers with no traffic yet)."""
        self.start()
        return np.where(
            self._tot_by_server > 0,
            self._hits_by_server / np.maximum(self._tot_by_server, 1.0),
            1.0,
        )

    def finish(self) -> SimResult:
        self.start()
        self._ratio.flush()
        return SimResult(
            latencies=np.array(self._latencies),
            servers=np.array(self._servers),
            finish_times=np.array(self._finishes),
            local_ratio_t=self._ratio.samples,
            migrations=self._migrations,
            stats=self._stats,
            routed=np.array(self._routed, int),
            hits_by_server=self._hits_by_server.copy(),
            tot_by_server=self._tot_by_server.copy(),
        )

    def run(self) -> SimResult:
        # a full pass always starts from a fresh timeline (run() was
        # reentrant before the incremental refactor and must stay so);
        # incremental callers drive start()/serve_request()/finish()
        self._started = False
        self.start()
        for r in self.source:
            self.serve_request(r)
        return self.finish()

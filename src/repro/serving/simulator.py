"""Event-driven multi-server MoE inference simulator (paper Sec. IV).

Five components, as in the paper's simulator description:
  1. Prompt sequence generator  — Poisson arrivals + token volumes
     (``repro.data.traces``).
  2. Prompt routing generator   — samples per-layer expert activations from
     the request's task profile and routes them under a placement plan.
  3. Comm/comp time estimator   — linear per-token-batch model from the
     cluster spec (bandwidth, RTT, FLOP rates, IO speed).
  4. Time-stamp calculator      — per-layer Eq.-1 semantics: a layer
     completes when its slowest expert invocation returns
     (max over experts of comm + comp), on top of the dense-path time.
  5. System timeline scheduler  — per-server FIFO occupancy plus
     asynchronous remote-compute load on target servers; optional periodic
     migration (Eq. 4) with per-server weight-loading pauses (Eq. 3).

Also implements the paper's Table-I baselines: single-server memory
offloading ("MoE-Infinity"-style), with and without request redirection.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.migration import MigrationController
from repro.core.placement import PlacementPlan
from repro.core.stats import ActivationStats
from repro.data.traces import Workload, sample_expert_counts
from repro.serving.cluster import ClusterSpec, MoEProfile


@dataclasses.dataclass
class SimResult:
    latencies: np.ndarray            # per request
    servers: np.ndarray              # per request
    finish_times: np.ndarray
    local_ratio_t: list              # (time, ratio) samples
    migrations: list                 # diagnostics dicts
    stats: ActivationStats

    def avg_latency_per_server(self, n: int) -> np.ndarray:
        return np.array([self.latencies[self.servers == i].mean()
                         if (self.servers == i).any() else 0.0
                         for i in range(n)])

    @property
    def avg_latency(self) -> float:
        return float(self.latencies.mean())


class EdgeSimulator:
    def __init__(self, cluster: ClusterSpec, profile: MoEProfile,
                 workload: Workload, plan: PlacementPlan | None = None,
                 controller: MigrationController | None = None,
                 mode: str = "collab", redirect: bool = False,
                 seed: int = 0, ratio_bucket: float = 60.0):
        """mode: 'collab' (distributed expert calls under `plan`) or
        'offload' (each server caches its own top experts; misses load
        weights from host RAM — the MoE-Infinity-style baseline).
        redirect: route each request to the least-loaded server first."""
        assert mode in ("collab", "offload")
        if mode == "collab" and plan is None and controller is None:
            raise ValueError("collab mode needs a plan or a controller")
        self.cluster, self.profile, self.workload = cluster, profile, workload
        self.plan, self.controller = plan, controller
        self.mode, self.redirect = mode, redirect
        self.rng = np.random.default_rng(seed)
        self.ratio_bucket = ratio_bucket

    # ------------------------------------------------------------------
    def _offload_caches(self) -> list[set]:
        """Per-server per-layer cached expert sets for offload mode (each
        server keeps its own most-frequent experts, split evenly across
        layers)."""
        cl, pf = self.cluster, self.profile
        exp_freq = self.workload.freqs_by_server(cl.n)   # [L, N, E]
        cap = cl.expert_capacity(pf.expert_bytes)
        per_layer = np.maximum(cap // pf.num_layers, 1)
        caches = []
        for n in range(cl.n):
            layers = []
            for l in range(pf.num_layers):
                k = min(int(per_layer[n]), pf.num_experts)
                layers.append(set(np.argsort(-exp_freq[l, n])[:k]))
            caches.append(layers)
        return caches

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cl, pf, wl = self.cluster, self.profile, self.workload
        N, L, E = cl.n, pf.num_layers, pf.num_experts
        speeds = np.array([s.compute_speed for s in cl.servers])
        io = np.array([s.io_speed for s in cl.servers])

        stats = ActivationStats(L, N, E)
        plan = self.plan
        if self.controller is not None:
            plan, _ = self.controller.maybe_migrate(0.0, stats.freqs())
        res = plan.residency() if plan is not None else None  # [L, N, E]

        caches = self._offload_caches() if self.mode == "offload" else None
        free = np.zeros(N)              # server occupancy timeline
        latencies, servers, finishes = [], [], []
        migrations = []
        loc_hits = loc_tot = 0.0
        ratio_samples = []
        next_bucket = self.ratio_bucket

        if self.mode == "offload":
            cache_mask = np.zeros((N, L, E), bool)
            for n in range(N):
                for l in range(L):
                    cache_mask[n, l, list(caches[n][l])] = True

        for r in wl.requests:
            n = r.server
            if self.redirect:
                n = int(np.argmin(np.maximum(free, r.arrival)))
            start = max(r.arrival, free[n])
            tokens = r.prompt_tokens + r.decode_tokens
            probs = wl.tasks[r.task].probs
            # component 2: per-layer expert activations for this request
            layer_counts = self.rng.multinomial(
                tokens * pf.top_k, probs)                   # [L, E]
            dense_t = tokens * pf.dense_flops_per_token / speeds[n]
            service = 0.0
            if self.mode == "offload":
                comp = layer_counts * pf.expert_flops_per_token / speeds[n]
                miss = (layer_counts > 0) & ~cache_mask[n]
                t_le = comp + miss * (pf.expert_bytes / io[n])
                service = L * dense_t + t_le.max(-1).sum()
                loc_hits += (layer_counts * cache_mask[n]).sum()
                loc_tot += layer_counts.sum()
            else:
                for l in range(L):
                    counts = layer_counts[l]
                    active = counts > 0
                    local = active & (res[l, n] > 0)
                    remote = active & ~local
                    comp_b = counts * pf.expert_flops_per_token
                    worst = float((comp_b * local).max() / speeds[n]) \
                        if local.any() else 0.0
                    loc_hits += counts[local].sum()
                    loc_tot += counts[active].sum()
                    if remote.any():
                        # nearest-idle replica per remote expert (Eq. 1)
                        free_m = np.where(res[l].T[remote] > 0, free[None],
                                          np.inf)            # [R, N]
                        tgt = np.argmin(free_m, axis=-1)
                        comm = (2 * counts[remote]
                                * pf.hidden_bytes_per_token / cl.bandwidth
                                + cl.rtt)
                        comp = comp_b[remote] / speeds[tgt]
                        np.add.at(free, tgt, comp)            # async load
                        worst = max(worst, float((comm + comp).max()))
                    service += dense_t + worst
            free[n] = start + service
            done = start + service
            latencies.append(done - r.arrival)
            servers.append(r.server)
            finishes.append(done)
            stats.update_server(r.server, layer_counts)

            while done >= next_bucket:
                ratio_samples.append((next_bucket,
                                      loc_hits / max(loc_tot, 1.0)))
                loc_hits = loc_tot = 0.0
                next_bucket += self.ratio_bucket

            if self.controller is not None:
                plan2, adopted = self.controller.maybe_migrate(
                    done, stats.freqs())
                if adopted:
                    # per-server weight-loading pause (Eq. 3)
                    old_res, new_res = res, plan2.residency()
                    added = np.maximum(new_res - old_res, 0).sum(0).sum(-1)
                    free += added * pf.expert_bytes / io
                    migrations.append({"time": done,
                                       "added_per_server": added.tolist()})
                    plan, res = plan2, new_res

        return SimResult(latencies=np.array(latencies),
                         servers=np.array(servers),
                         finish_times=np.array(finishes),
                         local_ratio_t=ratio_samples,
                         migrations=migrations, stats=stats)

"""Event-driven multi-server MoE inference simulator (paper Sec. IV).

Decomposed into the paper's five components, each a small class that can be
reused or swapped independently:

  1. ``ArrivalSource``      — prompt sequence generator (Poisson arrivals +
     token volumes, from ``repro.data.traces``).
  2. request routing        — per-layer expert activations sampled from the
     request's task profile (``TimeModel.sample_layer_counts``) + server
     selection (``Router``: home server or least-loaded redirect).
  3. ``TimeModel``          — linear comm/comp estimator from the cluster
     spec (bandwidth, RTT, FLOP rates, IO speed).
  4. Eq.-1 time stamps      — a layer completes when its slowest expert
     invocation returns (``TimeModel.collab_layer``), on top of the
     dense-path time.
  5. ``Timeline``           — per-server FIFO occupancy plus asynchronous
     remote-compute load on target servers; migration adds per-server
     weight-loading pauses (Eq. 3).

Placement and migration run through the unified control plane
(``repro.core.policies.PlacementController``): the simulator feeds it
per-request activation counts and asks it to review periodically — exactly
the calls the JAX serving runtime makes, so policy/controller behaviour is
identical in both worlds. A static ``PlacementPlan`` or the legacy
``MigrationController`` shim are still accepted.

Also implements the paper's Table-I baselines: single-server memory
offloading ("MoE-Infinity"-style), with and without request redirection.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.migration import MigrationController
from repro.core.placement import PlacementPlan
from repro.core.policies import PlacementController
from repro.core.stats import ActivationStats
from repro.data.traces import Request, Workload
from repro.serving.cluster import ClusterSpec, MoEProfile


# ---------------------------------------------------------------------------
# Components
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArrivalSource:
    """Component 1: yields requests in arrival order."""
    workload: Workload

    def __iter__(self):
        return iter(self.workload.requests)


@dataclasses.dataclass
class Timeline:
    """Component 5: per-server occupancy. ``free[n]`` is the time server n
    finishes its current FIFO backlog; remote expert calls add asynchronous
    compute load to their target server."""
    free: np.ndarray                        # [N]

    @staticmethod
    def create(n: int) -> "Timeline":
        return Timeline(free=np.zeros(n))

    def start_time(self, server: int, arrival: float) -> float:
        return max(arrival, float(self.free[server]))

    def occupy(self, server: int, until: float) -> None:
        self.free[server] = until

    def add_async(self, targets: np.ndarray, comp: np.ndarray) -> None:
        np.add.at(self.free, targets, comp)

    def pause(self, delays: np.ndarray) -> None:
        """Stall every server (Eq.-3 weight loading)."""
        self.free += delays


@dataclasses.dataclass
class Router:
    """Server selection: the request's home server, or (``redirect``) the
    server that can start it earliest."""
    redirect: bool = False

    def route(self, req: Request, timeline: Timeline) -> int:
        if self.redirect:
            return int(np.argmin(np.maximum(timeline.free, req.arrival)))
        return req.server


class TimeModel:
    """Components 3 + 4: the linear per-token-batch comm/comp estimator and
    the Eq.-1 per-layer completion semantics."""

    def __init__(self, cluster: ClusterSpec, profile: MoEProfile):
        self.cluster, self.profile = cluster, profile
        self.speeds = np.array([s.compute_speed for s in cluster.servers])
        self.io = np.array([s.io_speed for s in cluster.servers])

    def sample_layer_counts(self, rng, probs, tokens: int) -> np.ndarray:
        """Component 2: per-layer expert activations for one request."""
        return rng.multinomial(tokens * self.profile.top_k, probs)  # [L, E]

    def dense_time(self, tokens: int, server: int) -> float:
        return tokens * self.profile.dense_flops_per_token \
            / self.speeds[server]

    def collab_layer(self, counts: np.ndarray, res_l: np.ndarray,
                     server: int, timeline: Timeline
                     ) -> tuple[float, float, float]:
        """Eq. 1 for one layer under a placement residency ``res_l``
        [N, E]: local experts compute at the home server; remote experts go
        to the nearest-idle replica (comm + comp, async load on the
        target). Returns (layer time, local hits, total activations)."""
        pf = self.profile
        active = counts > 0
        local = active & (res_l[server] > 0)
        remote = active & ~local
        comp_b = counts * pf.expert_flops_per_token
        worst = float((comp_b * local).max() / self.speeds[server]) \
            if local.any() else 0.0
        hits = float(counts[local].sum())
        tot = float(counts[active].sum())
        if remote.any():
            free_m = np.where(res_l.T[remote] > 0, timeline.free[None],
                              np.inf)                     # [R, N]
            tgt = np.argmin(free_m, axis=-1)
            comm = (2 * counts[remote] * pf.hidden_bytes_per_token
                    / self.cluster.bandwidth + self.cluster.rtt)
            comp = comp_b[remote] / self.speeds[tgt]
            timeline.add_async(tgt, comp)                 # async load
            worst = max(worst, float((comm + comp).max()))
        return worst, hits, tot

    def offload_service(self, layer_counts: np.ndarray, server: int,
                        cache_mask_n: np.ndarray
                        ) -> tuple[float, float, float]:
        """Single-server offloading: cached experts compute locally, misses
        load weights from host RAM (MoE-Infinity baseline)."""
        pf = self.profile
        comp = layer_counts * pf.expert_flops_per_token / self.speeds[server]
        miss = (layer_counts > 0) & ~cache_mask_n
        t_le = comp + miss * (pf.expert_bytes / self.io[server])
        service = t_le.max(-1).sum()
        hits = float((layer_counts * cache_mask_n).sum())
        tot = float(layer_counts.sum())
        return service, hits, tot

    def migration_pause(self, old_res: np.ndarray, new_res: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Eq. 3: per-server stall for newly placed expert weights.
        Returns (delays [N] seconds, experts added per server [N])."""
        added = np.maximum(new_res - old_res, 0).sum(0).sum(-1)   # [N]
        return added * self.profile.expert_bytes / self.io, added


@dataclasses.dataclass
class LocalRatioTracker:
    """Bucketed local-compute-ratio time series."""
    bucket: float
    samples: list = dataclasses.field(default_factory=list)
    hits: float = 0.0
    tot: float = 0.0
    next_bucket: float = dataclasses.field(init=False)

    def __post_init__(self):
        self.next_bucket = self.bucket

    def add(self, hits: float, tot: float) -> None:
        self.hits += hits
        self.tot += tot

    def roll(self, now: float) -> None:
        while now >= self.next_bucket:
            self.samples.append((self.next_bucket,
                                 self.hits / max(self.tot, 1.0)))
            self.hits = self.tot = 0.0
            self.next_bucket += self.bucket

    def flush(self) -> None:
        """Emit the trailing partial bucket (previously dropped)."""
        if self.tot > 0:
            self.samples.append((self.next_bucket,
                                 self.hits / max(self.tot, 1.0)))
            self.hits = self.tot = 0.0


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    latencies: np.ndarray            # per request
    servers: np.ndarray              # per request
    finish_times: np.ndarray
    local_ratio_t: list              # (time, ratio) samples
    migrations: list                 # diagnostics dicts
    stats: ActivationStats

    def avg_latency_per_server(self, n: int) -> np.ndarray:
        return np.array([self.latencies[self.servers == i].mean()
                         if (self.servers == i).any() else 0.0
                         for i in range(n)])

    @property
    def avg_latency(self) -> float:
        return float(self.latencies.mean())


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class EdgeSimulator:
    def __init__(self, cluster: ClusterSpec, profile: MoEProfile,
                 workload: Workload, plan: PlacementPlan | None = None,
                 controller=None, mode: str = "collab",
                 redirect: bool = False, seed: int = 0,
                 ratio_bucket: float = 60.0):
        """mode: 'collab' (distributed expert calls under `plan`) or
        'offload' (each server caches its own top experts; misses load
        weights from host RAM — the MoE-Infinity-style baseline).
        controller: a ``PlacementController`` (or the deprecated
        ``MigrationController`` shim).
        redirect: route each request to the least-loaded server first."""
        assert mode in ("collab", "offload")
        if mode == "collab" and plan is None and controller is None:
            raise ValueError("collab mode needs a plan or a controller")
        self.cluster, self.profile, self.workload = cluster, profile, workload
        self.plan = plan
        self.controller = self._unwrap(controller)
        self.mode = mode
        self.rng = np.random.default_rng(seed)
        self.source = ArrivalSource(workload)
        self.router = Router(redirect=redirect)
        self.time_model = TimeModel(cluster, profile)
        self.ratio_bucket = ratio_bucket

    @staticmethod
    def _unwrap(controller) -> PlacementController | None:
        if controller is None:
            return None
        if isinstance(controller, MigrationController):
            return controller.ctrl
        return controller

    # ------------------------------------------------------------------
    def _offload_caches(self) -> list[list[set]]:
        """Per-server per-layer cached expert sets for offload mode (each
        server keeps its own most-frequent experts, split evenly across
        layers)."""
        cl, pf = self.cluster, self.profile
        exp_freq = self.workload.freqs_by_server(cl.n)   # [L, N, E]
        cap = cl.expert_capacity(pf.expert_bytes)
        per_layer = np.maximum(cap // pf.num_layers, 1)
        caches = []
        for n in range(cl.n):
            layers = []
            for l in range(pf.num_layers):
                k = min(int(per_layer[n]), pf.num_experts)
                layers.append(set(np.argsort(-exp_freq[l, n])[:k]))
            caches.append(layers)
        return caches

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cl, pf = self.cluster, self.profile
        N, L, E = cl.n, pf.num_layers, pf.num_experts
        tm = self.time_model
        timeline = Timeline.create(N)
        ratio = LocalRatioTracker(self.ratio_bucket)

        ctrl = self.controller
        if ctrl is not None and ctrl.stats is None:
            ctrl.stats = ActivationStats(L, N, E)
        stats = ctrl.stats if ctrl is not None else ActivationStats(L, N, E)
        plan = self.plan
        if ctrl is not None:
            plan = ctrl.review(0.0).plan            # initial placement
        res = plan.residency() if plan is not None else None  # [L, N, E]

        if self.mode == "offload":
            caches = self._offload_caches()
            cache_mask = np.zeros((N, L, E), bool)
            for n in range(N):
                for l in range(L):
                    cache_mask[n, l, list(caches[n][l])] = True

        latencies, servers, finishes = [], [], []
        migrations = []

        for r in self.source:
            n = self.router.route(r, timeline)
            start = timeline.start_time(n, r.arrival)
            tokens = r.prompt_tokens + r.decode_tokens
            probs = self.workload.tasks[r.task].probs
            layer_counts = tm.sample_layer_counts(self.rng, probs, tokens)
            dense_t = tm.dense_time(tokens, n)
            if self.mode == "offload":
                service, hits, tot = tm.offload_service(layer_counts, n,
                                                        cache_mask[n])
                service += L * dense_t
                ratio.add(hits, tot)
            else:
                service = 0.0
                for l in range(L):
                    worst, hits, tot = tm.collab_layer(layer_counts[l],
                                                       res[l], n, timeline)
                    ratio.add(hits, tot)
                    service += dense_t + worst
            done = start + service
            timeline.occupy(n, done)
            latencies.append(done - r.arrival)
            servers.append(r.server)
            finishes.append(done)
            stats.update_server(r.server, layer_counts)
            ratio.roll(done)

            if ctrl is not None:
                dec = ctrl.review(done)
                if dec.adopted:
                    new_res = dec.plan.residency()
                    delays, added = tm.migration_pause(res, new_res)  # Eq. 3
                    timeline.pause(delays)
                    migrations.append({"time": done,
                                       "added_per_server": added.tolist()})
                    plan, res = dec.plan, new_res

        ratio.flush()
        return SimResult(latencies=np.array(latencies),
                         servers=np.array(servers),
                         finish_times=np.array(finishes),
                         local_ratio_t=ratio.samples,
                         migrations=migrations, stats=stats)

"""Streaming million-user workload engine (tentpole of the workload PR).

Edge serving is driven by *populations*, not request lists: diurnal load
cycles, flash crowds pinned to a region, heavy-tailed prompt/output
lengths, and task mixes that drift mid-run. This module generates that
traffic as a **stream** — :class:`WorkloadStream` is a restartable
iterator of typed :class:`repro.serving.api.Request` objects that never
materializes the full trace, so a million-request scenario costs O(1)
memory and the *same seed always replays the same stream bit-for-bit*
(every draw comes from one ``np.random.default_rng(seed)`` consumed in a
fixed order; iterating twice re-creates the generator).

The arrival process is a non-homogeneous Poisson process sampled by
*thinning*: candidates are drawn from a homogeneous process at the
scenario's peak rate and accepted with probability ``rate(t) / peak``,
which keeps the stream lazy, exact and seed-stable. On top of the
arrivals:

* **diurnal cycle** — ``rate(t)`` swings sinusoidally around
  ``base_rate`` with ``diurnal_amplitude`` over ``diurnal_period``;
* **flash crowds** — each :class:`FlashCrowd` multiplies the rate inside
  its window and pins most of that burst's requests to one origin and
  (optionally) one task profile, the scenario the Eq.-4 placement review
  must chase;
* **regional skew** — origins are drawn Zipf-like
  (``P(origin=k) ∝ (k+1)^-origin_skew``);
* **heavy-tailed lengths** — prompt/output lengths are clipped
  lognormals;
* **task drift** — at ``task_shift_at`` the per-origin task profile
  flips from ``task{o}`` to ``task{o+n}``, the mid-run activation shift.

:func:`drive` feeds a stream into an :class:`~repro.serving.cluster
.EdgeCluster` under a bounded backlog (submit-ahead window), and
:func:`goodput_report` turns the finished handles into the SLO economy:
goodput (SLO-attained tokens/sec), attainment, shed counts, and p50/p99
TTFT / inter-token latency split by scenario phase
(``flash`` / ``peak`` / ``offpeak``).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serving.api import EventType, Request


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """One regional burst: between ``start`` and ``start + duration`` the
    arrival rate is multiplied by ``multiplier`` and a ``fraction`` of
    the burst's requests are pinned to ``origin`` (with ``task``
    overriding their task profile when set — a crowd that all wants the
    same thing is what moves the gating distribution)."""

    start: float
    duration: float
    multiplier: float = 4.0
    origin: int = 0
    fraction: float = 0.8
    task: str | None = None

    def __post_init__(self):
        if self.duration <= 0:
            raise ValueError(f"duration must be > 0 (got {self.duration})")
        if self.multiplier < 1.0:
            raise ValueError(
                f"multiplier must be >= 1 (got {self.multiplier})")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"fraction must be in [0, 1] (got {self.fraction})")

    def active(self, t: float) -> bool:
        return self.start <= t < self.start + self.duration


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declarative scenario description consumed by
    :class:`WorkloadStream`.

    duration:          scenario length in arrival-clock seconds.
    base_rate:         mean arrival rate (requests/s) before modulation.
    n_origins:         number of edge servers requests can arrive at.
    origin_skew:       Zipf exponent of the origin distribution (0 =
                       uniform; larger = more regional concentration).
    diurnal_period:    seconds per diurnal cycle.
    diurnal_amplitude: relative swing of the cycle in [0, 1); rate(t)
                       spans ``base_rate * (1 ± amplitude)``.
    crowds:            flash-crowd windows layered on the cycle.
    prompt_len:        (median, sigma, min, max) of the clipped lognormal
                       prompt-length distribution.
    output_len:        same shape for ``max_new_tokens``.
    task_shift_at:     when set, the per-origin task profile flips from
                       ``task{o}`` to ``task{o + n_origins}`` at this
                       time — the mid-run activation-distribution shift.
    slo:               per-request latency budget stamped on every
                       request (backend clock; None = no SLO).
    temperature:       sampling temperature stamped on every request
                       (each request still gets its own PRNG seed).
    seed:              the stream's PRNG seed; same seed = same stream.
    """

    duration: float = 120.0
    base_rate: float = 2.0
    n_origins: int = 3
    origin_skew: float = 1.0
    diurnal_period: float = 60.0
    diurnal_amplitude: float = 0.5
    crowds: tuple[FlashCrowd, ...] = ()
    prompt_len: tuple[float, float, int, int] = (96.0, 0.6, 8, 512)
    output_len: tuple[float, float, int, int] = (16.0, 0.5, 4, 64)
    task_shift_at: float | None = None
    slo: float | None = None
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.duration <= 0:
            raise ValueError(f"duration must be > 0 (got {self.duration})")
        if self.base_rate <= 0:
            raise ValueError(
                f"base_rate must be > 0 (got {self.base_rate})")
        if self.n_origins < 1:
            raise ValueError(
                f"n_origins must be >= 1 (got {self.n_origins})")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError(
                "diurnal_amplitude must be in [0, 1) "
                f"(got {self.diurnal_amplitude})")
        for c in self.crowds:
            if not 0 <= c.origin < self.n_origins:
                raise ValueError(
                    f"crowd origin {c.origin} out of range for "
                    f"{self.n_origins} origin(s)")

    # -- the rate function the thinning sampler accepts against ---------
    def rate(self, t: float) -> float:
        """Instantaneous arrival rate (requests/s) at time ``t``."""
        r = self.base_rate * (1.0 + self.diurnal_amplitude
                              * math.sin(2.0 * math.pi * t
                                         / self.diurnal_period))
        for c in self.crowds:
            if c.active(t):
                r *= c.multiplier
        return r

    @property
    def peak_rate(self) -> float:
        """Upper bound of ``rate(t)`` — the thinning envelope."""
        peak = self.base_rate * (1.0 + self.diurnal_amplitude)
        if self.crowds:
            peak *= max(c.multiplier for c in self.crowds)
        return peak

    def phase_of(self, t: float) -> str:
        """Scenario phase at ``t``: ``flash`` inside any crowd window,
        else ``peak``/``offpeak`` by the diurnal cycle's sign."""
        for c in self.crowds:
            if c.active(t):
                return "flash"
        if math.sin(2.0 * math.pi * t / self.diurnal_period) >= 0.0:
            return "peak"
        return "offpeak"


class WorkloadStream:
    """Restartable lazy stream of typed requests for one
    :class:`WorkloadSpec`.

    Iterating yields :class:`repro.serving.api.Request` objects in
    arrival order without ever holding more than one in memory. Every
    ``iter()`` restarts the underlying PRNG, so two passes over the same
    stream (or two streams built from the same spec) are bit-identical —
    the replay contract the benchmark asserts.
    """

    def __init__(self, spec: WorkloadSpec):
        self.spec = spec

    def __iter__(self):
        spec = self.spec
        rng = np.random.default_rng(spec.seed)
        peak = spec.peak_rate
        # Zipf-like origin distribution: P(k) ∝ (k+1)^-skew
        w = (np.arange(spec.n_origins) + 1.0) ** -spec.origin_skew
        origin_p = w / w.sum()
        pm, ps, plo, phi = spec.prompt_len
        om, os_, olo, ohi = spec.output_len
        t = 0.0
        while True:
            t += rng.exponential(1.0 / peak)
            if t >= spec.duration:
                return
            if rng.random() >= spec.rate(t) / peak:
                continue                       # thinned-out candidate
            origin = int(rng.choice(spec.n_origins, p=origin_p))
            task = None
            for c in spec.crowds:
                # the crowd draw is consumed even when it misses, so the
                # stream downstream of a window does not depend on how
                # many crowd requests were pinned
                if c.active(t) and rng.random() < c.fraction:
                    origin = c.origin
                    task = c.task
            if task is None:
                o = origin
                if (spec.task_shift_at is not None
                        and t >= spec.task_shift_at):
                    o += spec.n_origins
                task = f"task{o}"
            p_len = int(np.clip(round(float(pm)
                                      * math.exp(ps * rng.standard_normal())),
                                plo, phi))
            o_len = int(np.clip(round(float(om)
                                      * math.exp(os_ * rng.standard_normal())),
                                olo, ohi))
            yield Request(
                prompt=rng.integers(0, 2 ** 15, size=p_len, dtype=np.int32),
                max_new_tokens=o_len, origin=origin,
                temperature=spec.temperature, slo=spec.slo,
                arrival=round(t, 9), task=task,
                seed=int(rng.integers(2 ** 31 - 1)))

    def phase_of(self, t: float) -> str:
        return self.spec.phase_of(t)


# ---------------------------------------------------------------------------
# Feeding a cluster under bounded memory
# ---------------------------------------------------------------------------

def _backlog(cluster) -> int:
    """Requests the backend is holding but has not finished serving."""
    b = cluster.backend
    pend = getattr(b, "_pending", None)
    if pend is not None:                       # sim backend: arrival heap
        return len(pend)
    return sum(len(r.queue) + r.active for r in b.runtimes)


def drive(cluster, stream, max_pending: int = 256) -> list:
    """Feed ``stream`` into ``cluster`` under a bounded backlog.

    Submits requests in arrival order; whenever the backend's backlog
    reaches ``max_pending`` the cluster is stepped until it drains below
    the cap, so the driver's memory footprint is O(max_pending) no
    matter how long the stream is. Returns the handles in submission
    order (``cluster.run()`` finishes the tail)."""
    if max_pending < 1:
        raise ValueError(f"max_pending must be >= 1 (got {max_pending})")
    handles = []
    for req in stream:
        handles.append(cluster.submit(req))
        while _backlog(cluster) >= max_pending:
            if not cluster.step():
                break
    cluster.run()
    return handles


# ---------------------------------------------------------------------------
# Goodput accounting
# ---------------------------------------------------------------------------

def _pct(xs: list) -> dict:
    if not xs:
        return {"p50": 0.0, "p99": 0.0}
    return {"p50": round(float(np.percentile(xs, 50)), 6),
            "p99": round(float(np.percentile(xs, 99)), 6)}


def _ttft_itl(h) -> tuple[float, list] | None:
    """(TTFT, [inter-token gaps]) for one finished handle, in its
    backend's clock. Runtime handles carry real TOKEN timestamps; sim
    handles model the split — service time spread uniformly over the
    prompt+decode tokens, TTFT = wait + (prompt+1) token times."""
    sub = h.submitted_at if h.submitted_at is not None else 0.0
    tok = [e.time for e in h.events if e.type == EventType.TOKEN]
    if tok:
        return tok[0] - sub, list(np.diff(tok))
    m = h.metrics
    wait, latency = m.get("wait"), m.get("latency")
    tokens = int(m.get("tokens") or 0)
    if wait is None or latency is None or tokens <= 0:
        return None
    T = len(h.request.prompt)
    itl = max(latency - wait, 0.0) / max(T + tokens, 1)
    return wait + itl * (T + 1), [itl] * max(tokens - 1, 0)


def goodput_report(handles, span: float | None = None,
                   phase_of=None) -> dict:
    """SLO economy of one serving run.

    handles:  the cluster's request handles (finished ones are counted;
              shed ones count as sheds, never as attained tokens).
    span:     clock span to rate goodput over; defaults to last FINISHED
              time minus first submit time.
    phase_of: optional ``time -> phase name`` map (e.g.
              ``WorkloadSpec.phase_of``) keyed on each request's submit
              time; adds a per-phase breakdown.

    Goodput counts only tokens of finished, un-shed requests whose SLO
    verdict is not ``False`` — a request with no SLO is unconditionally
    good, a late one contributes nothing (its tokens were wasted work).
    """
    finished = sheds = met = with_slo = 0
    good_tokens = total_tokens = 0
    t_lo = t_hi = None
    ttfts: list = []
    itls: list = []
    phases: dict = {}
    for h in handles:
        if not h.done:
            continue
        finished += 1
        m = h.metrics
        sub = h.submitted_at if h.submitted_at is not None else 0.0
        end = h.events[-1].time if h.events else sub
        t_lo = sub if t_lo is None else min(t_lo, sub)
        t_hi = end if t_hi is None else max(t_hi, end)
        ph = None
        if phase_of is not None:
            ph = phase_of(sub)
            phases.setdefault(ph, {
                "requests": 0, "sheds": 0, "slo_met": 0, "with_slo": 0,
                "attained_tokens": 0, "_ttft": [], "_itl": []})
            phases[ph]["requests"] += 1
        if m.get("shed"):
            sheds += 1
            with_slo += 1
            if ph is not None:
                phases[ph]["sheds"] += 1
                phases[ph]["with_slo"] += 1
            continue
        tokens = int(m.get("tokens", len(h.tokens)) or 0)
        total_tokens += tokens
        verdict = m.get("slo_met")
        if verdict is not None:
            with_slo += 1
            if ph is not None:
                phases[ph]["with_slo"] += 1
        if verdict is not False:               # met, or no SLO attached
            good_tokens += tokens
            if verdict is True:
                met += 1
                if ph is not None:
                    phases[ph]["slo_met"] += 1
            if ph is not None:
                phases[ph]["attained_tokens"] += tokens
        ti = _ttft_itl(h)
        if ti is not None:
            ttfts.append(ti[0])
            itls.extend(ti[1])
            if ph is not None:
                phases[ph]["_ttft"].append(ti[0])
                phases[ph]["_itl"].extend(ti[1])
    if span is None:
        span = (t_hi - t_lo) if (t_lo is not None and t_hi > t_lo) else 1.0
    out = {
        "requests": len(handles),
        "finished": finished,
        "sheds": sheds,
        "slo_met": met,
        "slo_attainment": round(met / with_slo, 6) if with_slo else 1.0,
        "total_tokens": int(total_tokens),
        "goodput_tokens_per_s": round(good_tokens / span, 6),
        "span": round(float(span), 6),
        "ttft": _pct(ttfts),
        "itl": _pct(itls),
    }
    for ph, d in phases.items():
        d["ttft"] = _pct(d.pop("_ttft"))
        d["itl"] = _pct(d.pop("_itl"))
        d["slo_attainment"] = (round(d["slo_met"] / d["with_slo"], 6)
                               if d["with_slo"] else 1.0)
        del d["with_slo"]
    if phase_of is not None:
        out["phases"] = phases
    return out

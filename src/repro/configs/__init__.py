from repro.configs.base import (ModelConfig, InputShape, INPUT_SHAPES,
                                get_config, list_configs, register)

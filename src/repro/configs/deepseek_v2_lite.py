"""DeepSeek-V2-Lite [arXiv:2405.04434] — the paper's second testbed model.

26 layers, 64 routed experts with top-8 routing (simplified: standard GQA
attention instead of MLA; the placement study concerns the expert layers).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite", family="moe",
    num_layers=26, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    num_experts=64, top_k=8, moe_every=1,
    rope_theta=1e4, sliding_window=8192,
    source="arXiv:2405.04434",
))

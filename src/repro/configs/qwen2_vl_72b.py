"""Qwen2-VL-72B [arXiv:2409.12191] — VLM backbone, M-RoPE, GQA kv=8.

Vision frontend (ViT + projector) is a STUB per the assignment: the decode
backbone consumes precomputed patch embeddings supplied by ``input_specs``.
M-RoPE runs in text mode (temporal/height/width sections share positions).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    rope_theta=1e6, use_qkv_bias=True, frontend="vision",
    sliding_window=8192,  # enables long_500k decode (beyond-paper variant)
    source="arXiv:2409.12191",
))

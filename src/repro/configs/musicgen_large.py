"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

The EnCodec audio codec frontend is a STUB per the assignment: the decoder
consumes precomputed frame embeddings (the sum of the 4 codebook embeddings
under the delay pattern) supplied by ``input_specs``.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    frontend="audio", sliding_window=8192,
    source="arXiv:2306.05284",
))

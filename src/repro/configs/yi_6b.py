"""Yi-6B [arXiv:2403.04652] — llama-arch GQA kv=4."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        head_dim=128,
        rope_theta=5e6,
        sliding_window=8192,
        source="arXiv:2403.04652",
    )
)

"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no-bias."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, head_dim=128,
    rope_theta=75e6, tie_embeddings=True, sliding_window=8192,
    source="hf:CohereForAI/c4ai-command-r-v01",
))

"""Falcon-Mamba-7B [arXiv:2410.05355] — attention-free mamba1, d_state=16."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_version=1,
    source="arXiv:2410.05355",
))

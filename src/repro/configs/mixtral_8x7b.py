"""Mixtral-8x7B [arXiv:2401.04088] — the paper's primary testbed model."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        num_experts=8,
        top_k=2,
        moe_every=1,
        rope_theta=1e6,
        sliding_window=8192,
        source="arXiv:2401.04088",
    )
)

"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE with 128 routed experts, top-1 routing, interleaved dense/MoE FFN
(moe_every=2), early-fusion multimodal (text path exercised here).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=128, top_k=1, moe_every=2,
    rope_theta=5e5, sliding_window=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))

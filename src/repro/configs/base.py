"""Model / run configuration for the DanceMoE reproduction framework.

One flexible decoder-only stack covers every assigned architecture family:
dense, MoE, SSM (mamba1/mamba2), hybrid (mamba2 + shared attention), and the
VLM / audio backbones (whose modality frontends are stubbed — ``input_specs``
feeds pre-computed patch/frame embeddings of the right shape).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

# Block kinds used by the layer pattern (scan groups).
ATTN = "attn"            # self-attention sublayer
MLP = "mlp"              # dense FFN sublayer
MOE = "moe"              # mixture-of-experts FFN sublayer
MAMBA1 = "mamba1"        # mamba-1 selective-scan block (token+channel mixing)
MAMBA2 = "mamba2"        # mamba-2 (SSD) block
SHARED_ATTN = "shared_attn"  # zamba2-style shared-weight attention block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE FFN every k-th layer (others dense)
    # --- SSM ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # mamba2 head dim
    ssm_version: int = 0           # 1 or 2
    attn_every: int = 0            # hybrid: shared attn block every k SSM layers
    # --- attention details ---
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full attention
    use_qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- modality frontend stub ---
    frontend: str = "none"         # none | vision | audio
    # --- misc ---
    norm_eps: float = 1e-5
    source: str = ""               # citation for the assigned config

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def padded_heads(self, ep: int) -> int:
        """q heads padded up so the head dim shards evenly over `ep` ranks.

        Padding is realised with zero rows in the qkv/o projections, so the
        model function is exactly preserved (pad heads contribute nothing).
        """
        h = self.num_heads
        hp = int(math.ceil(h / ep) * ep)
        # expanded-kv grouping needs hp % num_kv_heads == 0
        while self.num_kv_heads and hp % self.num_kv_heads:
            hp += ep
        return hp

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def layer_pattern(self) -> tuple[tuple[str, ...], int]:
        """Return (block kinds within one scan group, number of groups).

        The model is a ``lax.scan`` over `n_groups` stacked parameter groups;
        each group applies the listed sublayers in order. All groups share a
        single structure so the HLO stays compact at 80 layers.
        """
        if self.family in ("dense", "vlm", "audio"):
            return (ATTN, MLP), self.num_layers
        if self.family == "moe":
            if self.moe_every == 1:
                return (ATTN, MOE), self.num_layers
            pat: list[str] = []
            for i in range(self.moe_every):
                pat += [ATTN, MOE if (i == self.moe_every - 1) else MLP]
            assert self.num_layers % self.moe_every == 0
            return tuple(pat), self.num_layers // self.moe_every
        if self.family == "ssm":
            kind = MAMBA1 if self.ssm_version == 1 else MAMBA2
            return (kind,), self.num_layers
        if self.family == "hybrid":
            assert self.attn_every > 0 and self.num_layers % self.attn_every == 0
            kind = MAMBA1 if self.ssm_version == 1 else MAMBA2
            return (SHARED_ATTN,) + (kind,) * self.attn_every, \
                self.num_layers // self.attn_every
        raise ValueError(f"unknown family {self.family}")

    @property
    def has_attention(self) -> bool:
        pat, _ = self.layer_pattern()
        return ATTN in pat or SHARED_ATTN in pat

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is supported natively (SSM/hybrid with
        shared-attn treated via full cache) or via sliding window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops and memory)."""
        pat, n_groups = self.layer_pattern()
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        per_group = 0
        for kind in pat:
            if kind in (ATTN,):
                qd = self.num_heads * hd
                kvd = self.num_kv_heads * hd
                per_group += d * (qd + 2 * kvd) + qd * d + d  # qkv + o + norm
            elif kind == MLP:
                per_group += 3 * d * self.d_ff + d
            elif kind == MOE:
                per_group += self.num_experts * 3 * d * self.d_ff
                per_group += d * self.num_experts + d  # router + norm
            elif kind in (MAMBA1, MAMBA2):
                di, n = self.d_inner, self.ssm_state
                per_group += d * 2 * di            # in_proj
                per_group += di * self.ssm_conv    # conv
                if kind == MAMBA1:
                    per_group += di * (2 * n) + di * (di // 16) * 2 + di  # B,C,dt
                else:
                    nh = self.ssm_heads
                    per_group += d * (2 * n + nh) + nh * 2  # BC+dt proj, A,D
                per_group += di * d + d            # out proj + norm
        total += per_group * n_groups
        if self.family == "hybrid":
            # shared attention weights counted once, not per group
            qd = self.num_heads * hd
            kvd = self.num_kv_heads * hd
            shared = d * (qd + 2 * kvd) + qd * d + d
            total -= shared * n_groups
            total += shared
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        pat, n_groups = self.layer_pattern()
        moe_layers = pat.count(MOE) * n_groups
        expert_p = 3 * self.d_model * self.d_ff
        inactive = moe_layers * (self.num_experts - self.top_k) * expert_p
        return self.param_count() - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (<=2 layers,
        d_model<=512, <=4 experts)."""
        pat, _ = self.layer_pattern()
        group = len(pat)
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        heads = 4 if self.num_heads else 0
        kw: dict[str, Any] = dict(
            name=self.name + "-smoke",
            family=self.family,
            num_layers=group if self.family == "hybrid" else
                       (2 * self.moe_every if self.family == "moe" else 2),
            d_model=256,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64 if self.num_heads else 0,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            moe_every=self.moe_every,
            ssm_state=self.ssm_state,
            ssm_conv=self.ssm_conv,
            ssm_expand=self.ssm_expand,
            ssm_head_dim=32 if self.ssm_version == 2 else 64,
            ssm_version=self.ssm_version,
            attn_every=self.attn_every,
            rope_theta=self.rope_theta,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            tie_embeddings=self.tie_embeddings,
            frontend=self.frontend,
            source=self.source,
        )
        if self.family == "hybrid":
            kw["num_layers"] = self.attn_every  # one scan group
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import importlib
    for mod in (
        "starcoder2_3b", "qwen2_vl_72b", "tinyllama_1_1b", "falcon_mamba_7b",
        "zamba2_2_7b", "musicgen_large", "command_r_plus_104b",
        "llama4_maverick_400b", "yi_6b", "phi3_5_moe_42b",
        "mixtral_8x7b", "deepseek_v2_lite",
    ):
        importlib.import_module(f"repro.configs.{mod}")

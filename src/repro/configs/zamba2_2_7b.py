"""Zamba2-2.7B [arXiv:2411.15242] — mamba2 backbone + shared attention block.

The shared attention block (one weight set, applied every `attn_every`
mamba2 layers) follows the Zamba2 design; d_state=64 SSD heads.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_version=2,
    attn_every=6,
    source="arXiv:2411.15242",
))

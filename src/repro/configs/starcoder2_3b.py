"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA kv=2, RoPE."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        head_dim=128,
        rope_theta=1e5,
        use_qkv_bias=True,
        sliding_window=4096,
        source="arXiv:2402.19173",
    )
)

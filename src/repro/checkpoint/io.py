"""Checkpointing: flat-key .npz for arrays + msgpack sidecar for metadata
(step, config, placement tables). No orbax dependency — works offline."""
from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import msgpack
import numpy as np


def _flatten(tree, prefix="") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict) -> Any:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str | Path, params, *, step: int = 0,
                    extra: dict | None = None, opt_state=None) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten({"params": jax.device_get(params)})
    if opt_state is not None:
        flat.update(_flatten({"opt": jax.device_get(opt_state)}))
    np.savez(str(path) + ".npz", **flat)
    meta = {"step": step, "extra": extra or {},
            "keys": sorted(flat)}
    Path(str(path) + ".meta").write_bytes(msgpack.packb(meta))
    return path


def load_checkpoint(path: str | Path):
    data = np.load(str(path) + ".npz")
    meta = msgpack.unpackb(Path(str(path) + ".meta").read_bytes())
    tree = _unflatten({k: data[k] for k in data.files})
    return (tree.get("params"), tree.get("opt"), meta)

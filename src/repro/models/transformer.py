"""Decoder stack assembly: scan-over-layer-groups transformer covering all
assigned families (dense / moe / ssm / hybrid / vlm / audio backbones).

Entry points (all pure functions of a ``Runtime``):
  init_params  — parameter pytree (group params stacked for lax.scan)
  loss_fn      — causal-LM loss + MoE aux losses + activation stats
  prefill      — full-sequence forward, returns last-token logits + cache
  decode_step  — one token against the cache (the serve_step of the dry-run)
  init_cache   — allocate the decode cache (full or sliding-window ring)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, MLP, MOE, MAMBA1, MAMBA2, SHARED_ATTN,
                                ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import embed_init, mlp_params, mlp_apply, rms_norm, \
    softmax_xent


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Model + distribution context (static: part of the jit closure)."""
    cfg: ModelConfig
    mesh: Any = None                  # jax Mesh (None = single device tests)
    moe_impl: str = "dense"           # 'dense' | 'ep'
    ep_spec: Any = None               # EPSpec when moe_impl == 'ep'
    dtype: Any = jnp.float32
    use_kernel: bool = False
    window: int = 0                   # >0: sliding-window attention active
    loss_chunk: int = 2048
    cache_seq_sharded: bool = False   # long-context: shard KV cache over seq
    scan_layers: bool = True          # False: unroll (exact cost_analysis)
    layout: str = "tp"                # tp | sp (seq-parallel residual) |
                                      # cp (replicated weights, ctx-parallel)
    remat_policy: str = "none"        # none | dots (save matmul/psum outputs)
    kv_quant: bool = False            # int8 KV cache (beyond-paper)
    kv_quant_consistent: bool = False  # prefill attends to dequantized k/v
                                      # (serve-consistent: full and paged
                                      # chunked prefill agree bit-wise)

    @property
    def ep(self) -> int:
        """Model-axis width (for head padding)."""
        return self.mesh.shape["model"] if self.mesh is not None else 1

    @property
    def ep_axes(self) -> tuple[str, ...]:
        return self.ep_spec.axes if self.ep_spec is not None else ("model",)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_params(rt: Runtime, kind: str, key):
    cfg, dt = rt.cfg, rt.dtype
    if kind in (ATTN, SHARED_ATTN):
        return attn.attn_params(key, cfg, rt.ep, dt)
    if kind == MLP:
        p = mlp_params(key, cfg.d_model, cfg.d_ff, dt)
        p["norm"] = jnp.ones((cfg.d_model,), dt)
        return p
    if kind == MOE:
        if rt.moe_impl == "ep":
            return moe_mod.moe_params_ep(key, cfg, rt.ep_spec, dt)
        return moe_mod.moe_params_dense(key, cfg, dt)
    if kind == MAMBA1:
        return ssm.mamba1_params(key, cfg, dt)
    if kind == MAMBA2:
        return ssm.mamba2_params(key, cfg, dt)
    raise ValueError(kind)


def init_params(rt: Runtime, key) -> dict:
    cfg = rt.cfg
    pattern, n_groups = cfg.layer_pattern()
    k_embed, k_head, k_shared, k_groups = jax.random.split(key, 4)
    params: dict = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), rt.dtype),
        "final_norm": jnp.ones((cfg.d_model,), rt.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(
            k_head, (cfg.d_model, cfg.vocab_size), rt.dtype)
    if SHARED_ATTN in pattern:
        params["shared_attn"] = _block_params(rt, SHARED_ATTN, k_shared)
    groups: dict = {}
    for i, kind in enumerate(pattern):
        if kind == SHARED_ATTN:
            continue
        keys = jax.random.split(jax.random.fold_in(k_groups, i), n_groups)
        per = [_block_params(rt, kind, keys[g]) for g in range(n_groups)]
        groups[f"b{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params["groups"] = groups
    return params


# ---------------------------------------------------------------------------
# Group application
# ---------------------------------------------------------------------------

def _sp_active(rt: Runtime, mode: str) -> bool:
    """Sequence-parallel residual stream (layout 'sp'): activations between
    blocks stay sharded over the model axis on the sequence dim; each
    TP sublayer all-gathers its input once and reduce-scatters its output —
    half the bytes of the baseline per-sublayer all-reduce, and the EP MoE
    dispatch layout becomes a free reshape. Serving steps (decode, paged
    chunk prefill) keep the replicated residual path."""
    return rt.layout == "sp" and rt.mesh is not None \
        and mode not in ("decode", "chunk")


def _sp_gather(rt: Runtime, x):
    from repro.models import sharding as sh
    b = tuple(a for a in rt.mesh.axis_names if a != "model")
    return sh.constrain(rt.mesh, x, P(b, None, None))


def _sp_scatter(rt: Runtime, x):
    from repro.models import sharding as sh
    b = tuple(a for a in rt.mesh.axis_names if a != "model")
    return sh.constrain(rt.mesh, x, P(b, "model", None))


def _apply_block(rt: Runtime, kind: str, p, h, *, mode, cache, pos,
                 placement, token_mask=None, paged=None, origin=None):
    cfg = rt.cfg
    window = rt.window
    sp = _sp_active(rt, mode)
    if kind in (ATTN, SHARED_ATTN):
        h_in = _sp_gather(rt, h) if sp else h
        out, c = attn.attn_apply(
            p, cfg, h_in, ep=rt.ep, mode=mode, cache=cache, pos=pos,
            window=window, norm_eps=cfg.norm_eps,
            use_kernel=rt.use_kernel and mode not in ("decode", "chunk"),
            mesh=rt.mesh,
            cache_seq_sharded=rt.cache_seq_sharded, residual=not sp,
            gather_kv=rt.layout in ("cp", "fsdp"), paged=paged,
            quant_consistent=rt.kv_quant_consistent)
        if sp:
            out = h + _sp_scatter(rt, out)          # reduce-scatter the delta
        return out, c
    if kind == MLP:
        x = rms_norm(h, p["norm"], cfg.norm_eps)
        if sp:
            x = _sp_gather(rt, x)
        delta = mlp_apply(p, x)
        if sp:
            delta = _sp_scatter(rt, delta)
        return h + delta, None
    if kind == MOE:
        if rt.moe_impl == "ep":
            out, stats = moe_mod.moe_apply_ep(
                p, cfg, h, mesh=rt.mesh, spec=rt.ep_spec,
                placement=placement, mode=mode, use_kernel=rt.use_kernel,
                norm_eps=cfg.norm_eps,
                # serving steps (decode/chunk) use the masked dispatch
                # branch — the seq-sharded fast path ignores token_mask
                seq_sharded_out=(rt.layout in ("sp", "cp", "fsdp")
                                 and mode not in ("decode", "chunk")),
                token_mask=token_mask, origin=origin)
        else:
            out, stats = moe_mod.moe_apply_dense(p, cfg, h,
                                                 norm_eps=cfg.norm_eps)
        return out, stats
    if kind == MAMBA1:
        return ssm.mamba1_apply(p, cfg, h, mode=mode, cache=cache,
                                norm_eps=cfg.norm_eps,
                                use_kernel=rt.use_kernel and mode == "train")
    if kind == MAMBA2:
        return ssm.mamba2_apply(p, cfg, h, mode=mode, cache=cache,
                                norm_eps=cfg.norm_eps)
    raise ValueError(kind)


def _apply_group(rt: Runtime, pattern, gp, shared_p, h, *, mode, gcache,
                 pos, placement, token_mask=None, paged=None, origin=None):
    """Apply one scan group. Returns (h, new_gcache, moe_stats)."""
    new_cache = {}
    moe_stats = None
    for i, kind in enumerate(pattern):
        p = shared_p if kind == SHARED_ATTN else gp[f"b{i}"]
        c = gcache.get(f"b{i}") if gcache is not None else None
        h, extra = _apply_block(rt, kind, p, h, mode=mode, cache=c, pos=pos,
                                placement=placement, token_mask=token_mask,
                                paged=paged, origin=origin)
        if kind == MOE:
            moe_stats = extra  # <=1 MoE sublayer per group in all configs
        elif extra is not None:
            new_cache[f"b{i}"] = extra
    return h, new_cache, moe_stats


def _zero_moe_stats(rt: Runtime):
    cfg = rt.cfg
    n_ep = rt.ep_spec.n_ep if (rt.moe_impl == "ep" and rt.ep_spec) else 1
    return {"counts": jnp.zeros((cfg.num_experts,), jnp.float32),
            "counts_per_rank": jnp.zeros((n_ep, cfg.num_experts), jnp.float32),
            "aux_loss": jnp.float32(0.0),
            "local_frac": jnp.float32(0.0)}


def stack_placement(placement, n_groups: int):
    """Broadcast a single EPPlacement to the per-layer stacked form
    [n_groups, ...] consumed by the scan (per-layer tables may also be built
    directly by the placement algorithms)."""
    import jax.numpy as _jnp
    return jax.tree.map(
        lambda a: _jnp.broadcast_to(a, (n_groups,) + a.shape), placement)


def _run_stack(rt: Runtime, params, h, *, mode, cache, pos, placement,
               token_mask=None, paged=None, origin=None):
    """Scan the layer groups. Returns (h, new_cache, stacked_moe_stats).

    ``placement`` (EP MoE only): EPPlacement pytree with a leading
    [n_groups] dim — each scan step consumes its own layer's tables, which
    is how Algorithm 1's layer-wise expert-count allocation reaches the
    runtime. ``token_mask`` ([B] in decode, [B, T] in chunk mode) excludes
    vacant continuous-batching rows / prompt padding from the gating
    statistics. ``origin`` ([B] or [B, T] int32) attributes each token's
    gating counts to the EP rank its request originated at (Algorithm 1's
    per-server f_n(e)); without it counts fall back to the physical rank.
    ``paged`` (decode/chunk): the page-table info shared by all layers —
    every layer indexes the same physical block ids into its own pool."""
    cfg = rt.cfg
    pattern, n_groups = cfg.layer_pattern()
    shared_p = params.get("shared_attn")
    has_moe = MOE in pattern
    use_pl = has_moe and rt.moe_impl == "ep"
    if use_pl and placement is None:
        raise ValueError("EP MoE requires a placement")
    if rt.layout in ("sp", "cp", "fsdp") and rt.mesh is not None \
            and mode not in ("decode", "chunk"):
        h = _sp_scatter(rt, h)          # residual stream: seq over model

    def body(carry, xs):
        hh = carry
        gp, gcache, gpl = xs
        hh, new_gcache, mstats = _apply_group(
            rt, pattern, gp, shared_p, hh, mode=mode, gcache=gcache,
            pos=pos, placement=gpl, token_mask=token_mask, paged=paged,
            origin=origin)
        if mstats is None:
            mstats = _zero_moe_stats(rt)
        return hh, (new_gcache, mstats)

    if mode == "train":
        if rt.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_saveable
        elif rt.remat_policy == "dots+kv":
            policy = jax.checkpoint_policies.save_from_both_policies(
                jax.checkpoint_policies.dots_saveable,
                jax.checkpoint_policies.save_only_these_names("kv_gathered"))
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body_fn = jax.checkpoint(body, policy=policy)
    else:
        body_fn = body
    pl_xs = placement if use_pl else None
    if not rt.scan_layers:
        caches_l, mstats_l = [], []
        for g in range(n_groups):
            take = lambda t: jax.tree.map(lambda a: a[g], t) \
                if t is not None else None
            h, (gc, ms) = body_fn(h, (take(params["groups"]), take(cache),
                                      take(pl_xs)))
            caches_l.append(gc)
            mstats_l.append(ms)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_l) \
            if caches_l and caches_l[0] else None
        mstats = jax.tree.map(lambda *xs: jnp.stack(xs), *mstats_l)
    elif cache is None:
        h, (new_caches, mstats) = lax.scan(
            lambda c, xs: body_fn(c, (xs[0], None, xs[1])),
            h, (params["groups"], pl_xs))
    else:
        h, (new_caches, mstats) = lax.scan(
            lambda c, xs: body_fn(c, xs),
            h, (params["groups"], cache, pl_xs))
    if not has_moe:
        mstats = None
    return h, (new_caches if cache is not None or mode == "prefill" else None), mstats


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _embed(rt: Runtime, params, tokens):
    return params["embed"][tokens].astype(rt.dtype)


def _logits(rt: Runtime, params, h):
    h = rms_norm(h, params["final_norm"], rt.cfg.norm_eps)
    w = params["embed"].T if rt.cfg.tie_embeddings else params["lm_head"]
    return h @ w


def _chunked_xent(rt: Runtime, params, h, targets):
    """Cross-entropy with per-chunk logit remat (never materialises the full
    [B,T,V] logits)."""
    B, T, D = h.shape
    # NOTE (§Perf, refuted hypothesis): computing the loss unchunked on
    # (data x model)-sharded rows looked like it would remove the per-chunk
    # dynamic-slice all-gathers (~4 GB), but measured WORSE (43.3 vs 29.2 GB
    # collectives, +130 ms compute) — the flatten of two sharded dims
    # introduced a bigger reshard than the chunk scan. Chunked path kept.
    rows = h.reshape(B * T, D)
    tgt = targets.reshape(B * T)
    chunk = min(rt.loss_chunk, B * T)
    n = B * T // chunk
    rows = rows[:n * chunk].reshape(n, chunk, D)
    tgt_c = tgt[:n * chunk].reshape(n, chunk)

    @jax.checkpoint
    def chunk_loss(r, t):
        lg = _logits(rt, params, r)
        return softmax_xent(lg, t).sum()

    def body(acc, xs):
        r, t = xs
        return acc + chunk_loss(r, t), None

    total, _ = lax.scan(body, jnp.float32(0.0), (rows, tgt_c))
    return total / (n * chunk)


def loss_fn(rt: Runtime, params, tokens, targets, placement=None,
            aux_weight: float = 0.01):
    """tokens/targets: [B, T] int32. Returns (loss, metrics)."""
    h = _embed(rt, params, tokens)
    h, _, mstats = _run_stack(rt, params, h, mode="train", cache=None,
                              pos=None, placement=placement)
    if rt.layout in ("sp", "cp", "fsdp") and rt.mesh is not None:
        # one gather of h before the loss: the chunk scan then slices a
        # batch-only-sharded rows array (free) instead of re-gathering a
        # (batch x model)-sharded one per chunk (measured 5.4 GB/step)
        h = _sp_gather(rt, h)
        from repro.models import sharding as _shd
        b = tuple(a for a in rt.mesh.axis_names if a != "model")
        targets = _shd.constrain(rt.mesh, targets, P(b, None))
    ce = _chunked_xent(rt, params, h, targets)
    metrics = {"ce_loss": ce}
    loss = ce
    if mstats is not None:
        aux = mstats["aux_loss"].mean()
        loss = loss + aux_weight * aux
        metrics.update(aux_loss=aux,
                       local_frac=mstats["local_frac"].mean(),
                       expert_counts=mstats["counts_per_rank"])
    metrics["loss"] = loss
    return loss, metrics


def _constrain_outputs(rt: Runtime, logits, cache):
    if rt.mesh is None:
        return logits, cache
    from repro.models import sharding as sh
    b = tuple(a for a in rt.mesh.axis_names if a != "model")
    logits = sh.constrain(rt.mesh, logits, P(b, "model"))
    if cache is not None:
        specs = sh.cache_pspecs(rt, seq_sharded=rt.cache_seq_sharded)
        cache = sh.constrain(rt.mesh, cache, specs)
    return logits, cache


def prefill(rt: Runtime, params, tokens=None, embeds=None, placement=None,
            cache_len: int | None = None, origin=None):
    """Returns (last-token logits [B, V], cache, moe_stats). ``origin``:
    optional [B] int32 — the EP rank each request originated at (gating
    stats attribution; defaults to the physical row-sharding rank)."""
    h = _embed(rt, params, tokens) if embeds is None else embeds.astype(rt.dtype)
    B, T = h.shape[:2]
    cache = init_cache(rt, B, cache_len if cache_len is not None else T)
    h, new_cache, mstats = _run_stack(rt, params, h, mode="prefill",
                                      cache=cache, pos=None,
                                      placement=placement, origin=origin)
    logits = _logits(rt, params, h[:, -1])
    logits, new_cache = _constrain_outputs(rt, logits, new_cache)
    return logits, new_cache, mstats


def decode_step(rt: Runtime, params, cache, tokens, pos, placement=None,
                token_mask=None, page_table=None, origin=None):
    """tokens: [B, 1] int32; pos: scalar int32 (whole batch at one
    position) or [B] int32 vector (continuous batching: per-row positions).
    token_mask: optional [B] float validity — 0-rows (vacant pool slots)
    are excluded from the MoE gating statistics.
    page_table: optional [B, P] int32 — ``cache`` is then a paged block
    pool (``init_paged_cache``) and each row reads/writes through its pages.
    origin: optional [B] int32 originating EP rank per row (stats
    attribution).
    Returns (logits [B, V], new_cache, moe_stats).

    Donation-safe: ``new_cache`` is a pure functional ``.at[].set()``
    update of ``cache`` with identical shapes/dtypes per leaf, so callers
    may jit/AOT-compile with the cache donated (``donate_argnums``) and
    XLA aliases the update in place — the serving engine's zero-stall
    decode path relies on this (no per-step pool allocation). Never return
    a leaf whose shape/dtype differs from its input."""
    h = _embed(rt, params, tokens)
    paged = {"page_table": page_table} if page_table is not None else None
    h, new_cache, mstats = _run_stack(rt, params, h, mode="decode",
                                      cache=cache, pos=pos,
                                      placement=placement,
                                      token_mask=token_mask, paged=paged,
                                      origin=origin)
    logits = _logits(rt, params, h[:, -1])
    if page_table is not None:
        # paged pools have block-major shapes the dense cache pspecs don't
        # describe; serving runs single-host, so constrain logits only
        logits, _ = _constrain_outputs(rt, logits, None)
        return logits, new_cache, mstats
    logits, new_cache = _constrain_outputs(rt, logits, new_cache)
    return logits, new_cache, mstats


def prefill_chunk(rt: Runtime, params, cache, tokens, page_table,
                  write_blocks, offset, last_idx, placement=None,
                  token_mask=None, origin=None):
    """Batched paged chunked prefill: consume one ``block_size``-aligned
    chunk of up to ``B`` *different* prompts (one per serving slot) into a
    paged pool in a single call, so short non-shared prompt tails don't
    serialize behind each other.

    tokens: [B, bs] int32 — row ``b`` is one whole-block chunk of slot
    ``b``'s prompt (the tail beyond the true prompt is padding — mask it
    via ``token_mask``; rows of idle slots are all-padding).
    page_table: [B, P] — each slot's full page table (logical order).
    write_blocks: [B] int32 — the physical block receiving each row's k/v
    (idle rows target the reserved null block 0).
    offset: [B] int32 — absolute position of ``tokens[b, 0]``.
    last_idx: [B] int32 — in-chunk index whose logits to return per row
    (the final prompt token on a last chunk; ignored otherwise).
    token_mask: optional [B, bs] float — 0 for padding tokens (excluded
    from the MoE gating statistics).
    origin: optional [B] int32 originating EP rank per row.
    Returns (logits [B, V], new_cache, moe_stats).

    Donation-safe like ``decode_step``: every ``new_cache`` leaf is a
    same-shape functional update of the input pool, so the chunked-prefill
    executables compile with the pool donated."""
    h = _embed(rt, params, tokens)
    paged = {"page_table": page_table, "write_blocks": write_blocks}
    h, new_cache, mstats = _run_stack(rt, params, h, mode="chunk",
                                      cache=cache, pos=offset,
                                      placement=placement,
                                      token_mask=token_mask, paged=paged,
                                      origin=origin)
    B = h.shape[0]
    h_last = h[jnp.arange(B), jnp.asarray(last_idx)]       # [B, D]
    logits = _logits(rt, params, h_last)
    logits, _ = _constrain_outputs(rt, logits, None)
    return logits, new_cache, mstats


def copy_paged_block(pool, src, dst):
    """Copy one physical block across every layer of a paged pool (the
    serving-side copy-on-write primitive: clone a shared tail block before
    a sharer's first write). ``pool`` is the ``init_paged_cache`` pytree
    (leading n_groups dim per layer); src/dst are scalar block ids.
    Donation-safe (same-shape functional update): the engine AOT-compiles
    it with the pool donated so CoW clones allocate nothing."""
    return {k: attn.copy_pool_block(c, src, dst, block_axis=1)
            for k, c in pool.items()}


def supports_paging(rt: Runtime) -> bool:
    """Whether this runtime's caches can live in a paged block pool:
    attention-only state (no SSM recurrence), no sliding-window ring, and
    no sequence-sharded cache (the paged paths don't constrain block-pool
    shardings — a seq-sharded pool would silently reshard every step).
    Pure metadata — no allocation."""
    pattern, _ = rt.cfg.layer_pattern()
    return (not rt.window and not rt.cache_seq_sharded
            and not any(k in (MAMBA1, MAMBA2) for k in pattern))


def init_paged_cache(rt: Runtime, n_blocks: int, block_size: int,
                     dtype=None) -> dict:
    """Paged KV block pool: per attention group, ``[n_groups, n_blocks,
    block_size, KVH, hd]`` shared by all serving slots (block 0 reserved as
    the null block). Raises for architectures whose caches cannot be paged
    (see ``supports_paging``)."""
    if dtype is None:
        dtype = rt.dtype
    cfg = rt.cfg
    if not supports_paging(rt):
        raise ValueError(
            "paged KV pool requires attention-only caches without a "
            f"sliding window; {cfg.name} (window={rt.window}) does not "
            "qualify — use the dense slot pool")
    pattern, n_groups = cfg.layer_pattern()
    out = {}
    for i, kind in enumerate(pattern):
        if kind not in (ATTN, SHARED_ATTN):
            continue
        c = attn.init_paged_kv(cfg, n_blocks, block_size, dtype=dtype,
                               quantized=rt.kv_quant)
        out[f"b{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), c)
    return out


def init_cache(rt: Runtime, batch: int, seq_len: int,
               dtype=None) -> dict:
    if dtype is None:
        dtype = rt.dtype
    """Per-group cache pytree, leading dim = n_groups (stacked for scan)."""
    cfg = rt.cfg
    pattern, n_groups = cfg.layer_pattern()
    out = {}
    for i, kind in enumerate(pattern):
        if kind in (ATTN, SHARED_ATTN):
            c = attn.init_attn_cache(cfg, batch, seq_len, window=rt.window,
                                     dtype=dtype, quantized=rt.kv_quant)
        elif kind == MAMBA1:
            c = ssm.init_mamba1_cache(cfg, batch, dtype)
        elif kind == MAMBA2:
            c = ssm.init_mamba2_cache(cfg, batch, dtype)
        else:
            continue
        out[f"b{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), c)
    return out

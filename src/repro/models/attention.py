"""GQA attention: flash-style chunked prefill/train + KV-cache decode.

Head padding: q heads are padded to ``H_pad`` (next multiple of the model
axis) with zero projection rows so every assigned architecture shards evenly
over a 16-wide model axis. KV stays at its true head count and is expanded
(``jnp.repeat``) right before the score einsum — XLA fuses the expansion, so
neither HBM bytes nor collective bytes grow.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def attn_params(key, cfg, ep: int, dtype=jnp.float32) -> dict:
    d, hd, kvh = cfg.d_model, cfg.hd, cfg.num_kv_heads
    hp = cfg.padded_heads(ep)
    ks = jax.random.split(key, 5)
    wq = dense_init(ks[0], (d, hp * hd), 0, dtype)
    # zero the padded head rows so padding is function-preserving
    if hp != cfg.num_heads:
        mask = (jnp.arange(hp * hd) < cfg.num_heads * hd).astype(dtype)
        wq = wq * mask
    p = {
        "norm": jnp.ones((d,), dtype),
        "wq": wq,
        "wk": dense_init(ks[1], (d, kvh * hd), 0, dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), 0, dtype),
        "wo": dense_init(ks[3], (hp * hd, d), 0, dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((hp * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    return p


def _project_qkv(p, x, cfg, ep):
    hd, kvh = cfg.hd, cfg.num_kv_heads
    hp = cfg.padded_heads(ep)
    q = x @ p["wq"] + (p.get("bq", 0.0))
    k = x @ p["wk"] + (p.get("bk", 0.0))
    v = x @ p["wv"] + (p.get("bv", 0.0))
    B, T = x.shape[:2]
    return (q.reshape(B, T, hp, hd), k.reshape(B, T, kvh, hd),
            v.reshape(B, T, kvh, hd))


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_offset: Any = 0, chunk: int = 512) -> jax.Array:
    """Flash-style attention via a scan over KV chunks (O(T·chunk) memory).

    q: [B, Tq, H, hd]; k,v: [B, Tk, KVH, hd] with H % KVH == 0.
    ``window`` > 0 restricts to a sliding window (q attends to keys within
    the last `window` positions, inclusive of self). ``q_offset`` is the
    absolute position of q's first token — a scalar shared by the batch or
    a [B] vector when rows sit at different offsets (batched chunked
    prefill of different serving slots).
    """
    B, Tq, H, hd = q.shape
    Tk, kvh = k.shape[1], k.shape[2]
    grp = H // kvh
    nchunks = -(-Tk // chunk)
    pad = nchunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    scale = hd ** -0.5
    q32 = (q * scale).astype(q.dtype)
    offs = jnp.broadcast_to(jnp.asarray(q_offset), (B,))   # [B]
    qpos = jnp.arange(Tq)[None, :] + offs[:, None]         # [B, Tq]

    def body(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        kpos = ci * chunk + jnp.arange(chunk)              # [chunk]
        kex = jnp.repeat(kci, grp, axis=2)                 # [B, c, H, hd]
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kex,
                       preferred_element_type=jnp.float32)  # [B,H,Tq,c]
        mask = jnp.broadcast_to(kpos[None, None, :] < Tk,
                                (B, Tq, chunk))             # pad mask
        if causal:
            mask &= kpos[None, None, :] <= qpos[:, :, None]
        if window:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        vex = jnp.repeat(vci, grp, axis=2)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vex,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    a0 = jnp.zeros((B, Tq, H, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0), (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (beyond-paper: halves the decode memory term)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(position, head) symmetric int8. x: [B, T, KVH, hd]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q8.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q8: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q8.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def paged_gather(cache: dict, page_table: jax.Array, dtype) -> tuple:
    """Gather a slot-contiguous KV view out of a paged block pool.

    cache: k/v pools ``[n_blocks, block_size, KVH, hd]`` (plus
    ``k_scale``/``v_scale`` ``[n_blocks, block_size, KVH, 1]`` for the int8
    layout). ``page_table``: [B, P] physical block ids in *logical order*
    (entry ``j`` holds positions ``j*block_size .. (j+1)*block_size-1``),
    padded with the null block 0. The flattened gather index therefore
    equals the absolute cache position, so the standard ``decode_attention``
    validity mask (``idx <= pos``) applies unchanged.

    Returns (k, v) as ``[B, P*block_size, KVH, hd]`` in ``dtype``
    (dequantized when the pool is int8).
    """
    B, Pn = page_table.shape

    def flat(name):
        g = cache[name][page_table]                # [B, P, bs, KVH, *]
        return g.reshape((B, Pn * g.shape[2]) + g.shape[3:])

    if "k_scale" in cache:
        return (dequantize_kv(flat("k"), flat("k_scale"), dtype),
                dequantize_kv(flat("v"), flat("v_scale"), dtype))
    return flat("k").astype(dtype), flat("v").astype(dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, ring: bool = False,
                     mesh=None, seq_sharded: bool = False) -> jax.Array:
    """Single-token attention against a cache.

    q: [B, 1, H, hd]; caches: [B, S, KVH, hd]. `pos` is the current absolute
    position (already written to the cache) — a scalar shared by the batch,
    or a [B] vector when rows sit at different positions (continuous
    batching). With ``ring=True`` the cache is a sliding-window ring buffer:
    every entry older than `pos - S` has been overwritten, so validity is
    `entry_age < S` via the stored slot index.
    """
    B, S, kvh, hd = k_cache.shape
    H = q.shape[2]
    grp = H // kvh
    pos = jnp.broadcast_to(jnp.asarray(pos), (B,))         # [B]
    kex = jnp.repeat(k_cache, grp, axis=2)                 # [B,S,H,hd] (fused)
    vex = jnp.repeat(v_cache, grp, axis=2)
    if mesh is not None:
        # flash-decoding layout: kv stays sequence-sharded (matching the
        # cache), scores/softmax combine over the seq axes via small psums —
        # otherwise the partitioner reshards the whole cache per layer
        from jax.sharding import PartitionSpec
        from repro.models import sharding as _sh
        b = tuple(a for a in mesh.axis_names if a != "model")
        seq_axes = (b + ("model",)) if seq_sharded else "model"
        bb = None if seq_sharded else b
        kex = _sh.constrain(mesh, kex, PartitionSpec(bb, seq_axes, None, None))
        vex = _sh.constrain(mesh, vex, PartitionSpec(bb, seq_axes, None, None))
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * hd ** -0.5), kex,
                   preferred_element_type=jnp.float32)      # [B,H,1,S]
    idx = jnp.arange(S)
    if ring:
        # slot i currently holds absolute position: the latest p <= pos with
        # p % S == i. All S slots are valid once pos >= S - 1.
        slot_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % S)
        valid = slot_pos >= 0                              # [B, S]
    else:
        valid = idx[None, :] <= pos[:, None]               # [B, S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vex,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sublayer (norm -> qkv -> rope -> attn -> out proj)
# ---------------------------------------------------------------------------

def attn_apply(p, cfg, x, *, ep: int, mode: str, cache=None, pos=None,
               window: int = 0, norm_eps: float = 1e-5,
               use_kernel: bool = False, mesh=None, cache_seq_sharded=False,
               residual: bool = True, gather_kv: bool = False, paged=None,
               quant_consistent: bool = False):
    """Returns (out, new_cache). Cache layout: dict(k, v) [B, S, KVH, hd],
    or a paged block pool [n_blocks, block_size, KVH, hd] when ``paged`` is
    given (dict with ``page_table`` [B, P] and, for chunk mode,
    ``write_blocks`` [B]).

    mode: 'train' | 'prefill' | 'decode' | 'chunk'. For prefill the cache to
    fill is passed pre-allocated (zeros) in `cache`; for train cache is
    None. 'chunk' is batched paged chunked prefill: row ``b`` of x holds
    one ``block_size``-token block-aligned chunk of slot ``b``'s prompt
    starting at absolute position ``pos[b]``; its k/v are written into the
    whole block ``write_blocks[b]`` and attention runs against the gathered
    pages (earlier chunks + self, causal). Idle rows target the reserved
    null block 0.

    CoW contract (paged writes): the runtime guarantees every block named
    by a paged write — ``write_blocks`` in chunk mode, the
    ``(table[row][pos // bs])`` scatter target in decode mode — has
    refcount 1 (exclusively owned by the writing slot). Blocks shared via
    the radix prefix cache are only ever *gathered*; a sharer that must
    write a partially-filled shared tail block receives a
    ``copy_pool_block`` clone first.
    """
    B, T = x.shape[:2]
    h = rms_norm(x, p["norm"], norm_eps)
    q, k, v = _project_qkv(p, h, cfg, ep)
    if mode == "decode":
        # pos: scalar (whole batch at one position) or [B] vector
        # (continuous batching: every row has its own position)
        positions = jnp.broadcast_to(
            jnp.asarray(pos).reshape(-1, 1), (B, 1))
    elif mode == "chunk":
        # pos: scalar (single-slot chunk) or [B] vector (batched chunks of
        # different slots, each at its own prompt offset)
        offs = jnp.broadcast_to(jnp.asarray(pos), (B,))
        positions = jnp.arange(T)[None, :] + offs[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    def _attn(qq, kk, vv):
        if use_kernel and qq.shape[1] % 128 == 0 and kk.shape[1] % 128 == 0:
            from repro.kernels.ops import flash_attention
            return flash_attention(qq, kk, vv, causal=True, window=window)
        return chunked_attention(qq, kk, vv, causal=True, window=window)

    new_cache = None
    kv_quant = cache is not None and "k_scale" in cache
    if mode == "train" and gather_kv and mesh is not None:
        # context-parallel: q stays sequence-sharded, kv gathered (small —
        # grouped kv heads make this far cheaper than activation all-reduce)
        from jax.sharding import PartitionSpec
        from repro.models import sharding as _sh
        b = tuple(a for a in mesh.axis_names if a != "model")
        k = _sh.constrain(mesh, k, PartitionSpec(b, None, None, None))
        v = _sh.constrain(mesh, v, PartitionSpec(b, None, None, None))
        from jax.ad_checkpoint import checkpoint_name
        k = checkpoint_name(k, "kv_gathered")   # saveable across remat
        v = checkpoint_name(v, "kv_gathered")
    if mode != "train":
        if not kv_quant:
            k = k.astype(cache["k"].dtype)
            v = v.astype(cache["v"].dtype)
        if mesh is not None:
            # match the cache layout BEFORE the cache update: k/v leave the
            # projection sharded over (kvh*hd) on the model axis, and GSPMD
            # would otherwise reshard (all-gather) the whole cache per layer
            from jax.sharding import PartitionSpec
            from repro.models import sharding as _sh
            b = tuple(a for a in mesh.axis_names if a != "model")
            spec = PartitionSpec(b if not cache_seq_sharded else None,
                                 None, None, None)
            k = _sh.constrain(mesh, k, spec)
            v = _sh.constrain(mesh, v, spec)
    def _store(kk, vv):
        """Quantize (optionally) and return cache-layout tensors."""
        if not kv_quant:
            return {"k": kk, "v": vv}
        k8, ks_ = quantize_kv(kk)
        v8, vs_ = quantize_kv(vv)
        return {"k": k8, "v": v8, "k_scale": ks_, "v_scale": vs_}

    if mode == "train":
        out = _attn(q, k, v)
    elif mode == "chunk":
        # batched paged chunked prefill: every row writes its whole chunk
        # block into the pool, then attends over its own gathered pages.
        # Flattened gather index == absolute position, and masked (future /
        # stale) entries contribute exact zeros, so the result is
        # bit-identical to the full-prompt prefill path. Rows of idle
        # slots all target the null block 0 (garbage, never read valid).
        wb = paged["write_blocks"]                         # [B] block ids
        entry = _store(k, v)                               # [B, bs, KVH, *]
        new_cache = {key: cache[key].at[wb].set(
            val.astype(cache[key].dtype)) for key, val in entry.items()}
        kc, vc = paged_gather(new_cache, paged["page_table"], q.dtype)
        out = chunked_attention(q, kc, vc, causal=True, q_offset=pos)
    elif mode == "prefill":
        if kv_quant and quant_consistent:
            # serve-consistent fake-quant (opted into by ServingEngine):
            # prefill attends to the same dequantized values every later
            # decode step (and the paged chunked-prefill path) reads back
            # from the int8 cache — full and chunked prefill stay
            # token-identical under quantization
            k8, ks_ = quantize_kv(k)
            v8, vs_ = quantize_kv(v)
            out = _attn(q, dequantize_kv(k8, ks_, q.dtype),
                        dequantize_kv(v8, vs_, q.dtype))
        else:
            out = _attn(q, k.astype(q.dtype), v.astype(q.dtype))
        S = cache["k"].shape[1]
        if S < T:   # ring cache: keep only the last S, rotated to p % S
            shift = (T - S) % S
            k = jnp.roll(k[:, T - S:], shift, axis=1)
            v = jnp.roll(v[:, T - S:], shift, axis=1)
        entry = _store(k, v)
        new_cache = {key: lax.dynamic_update_slice(
            cache[key], val.astype(cache[key].dtype),
            (0,) * cache[key].ndim) for key, val in entry.items()}
    elif mode == "decode" and paged is not None:
        # paged decode: scatter each row's k/v into (its current block,
        # in-block offset), then attend over the gathered pages. Vacant
        # rows carry an all-null page table, so their garbage lands in the
        # reserved null block 0.
        bs = cache["k"].shape[1]
        tbl = paged["page_table"]                          # [B, P]
        pos_arr = jnp.broadcast_to(jnp.asarray(pos), (B,))
        blocks = jnp.take_along_axis(
            tbl, (pos_arr // bs)[:, None], axis=1)[:, 0]   # [B]
        offs = pos_arr % bs
        entry = _store(k, v)
        new_cache = {key: cache[key].at[blocks, offs].set(
            val[:, 0].astype(cache[key].dtype)) for key, val in entry.items()}
        kc, vc = paged_gather(new_cache, tbl, q.dtype)
        out = decode_attention(q, kc, vc, pos, ring=False)
    elif mode == "decode":
        S = cache["k"].shape[1]
        ring = window > 0  # windowed cache is a ring buffer (S == window)
        pos_arr = jnp.asarray(pos)
        slot = (pos_arr % S) if ring else pos_arr
        entry = _store(k, v)
        if pos_arr.ndim == 0:
            new_cache = {key: lax.dynamic_update_slice(
                cache[key], val.astype(cache[key].dtype),
                (0, slot) + (0,) * (cache[key].ndim - 2))
                for key, val in entry.items()}
        else:
            # per-row write slot (continuous batching): one-hot scatter
            # along the cache sequence dim
            hit = slot[:, None] == jnp.arange(S)[None, :]  # [B, S]
            new_cache = {}
            for key, val in entry.items():
                mask = hit.reshape((B, S) + (1,) * (cache[key].ndim - 2))
                new_cache[key] = jnp.where(
                    mask, val.astype(cache[key].dtype), cache[key])
        if kv_quant:
            kc = dequantize_kv(new_cache["k"], new_cache["k_scale"], q.dtype)
            vc = dequantize_kv(new_cache["v"], new_cache["v_scale"], q.dtype)
        else:
            kc, vc = new_cache["k"], new_cache["v"]
        out = decode_attention(q, kc, vc, pos, ring=ring, mesh=mesh,
                               seq_sharded=cache_seq_sharded)
    else:
        raise ValueError(mode)
    hp, hd = cfg.padded_heads(ep), cfg.hd
    out = out.reshape(B, T, hp * hd) @ p["wo"]
    return (x + out if residual else out), new_cache


def copy_pool_block(cache: dict, src, dst, block_axis: int = 0) -> dict:
    """Copy one physical block of a paged KV pool (all layouts: k/v plus
    int8 scales) — the copy-on-write primitive behind prefix sharing. A
    slot that must write into a block whose refcount is > 1 (a shared,
    partially-filled tail from the radix cache) writes into the ``dst``
    clone instead; the shared ``src`` stays immutable.

    ``block_axis`` selects the blocks dimension: 0 for a single-layer pool
    ``[n_blocks, bs, KVH, *]``, 1 for the grouped stacks
    ``[n_groups, n_blocks, bs, KVH, *]``.

    Same-shape functional update on every leaf — safe to compile with the
    pool donated (the serving engine's AOT copy-block executable does).
    """
    pre = (slice(None),) * block_axis

    def cp(a):
        return a.at[pre + (dst,)].set(a[pre + (src,)])

    return jax.tree.map(cp, cache)


def init_paged_kv(cfg, n_blocks: int, block_size: int, *,
                  dtype=jnp.bfloat16, quantized: bool = False) -> dict:
    """Paged block pool for one attention sublayer: ``n_blocks`` physical
    blocks of ``block_size`` positions shared by every serving slot (block 0
    is reserved as the null block — see ``serving.runtime.BlockAllocator``).
    """
    kvh, hd = cfg.num_kv_heads, cfg.hd
    shape = (n_blocks, block_size, kvh, hd)
    if quantized:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_attn_cache(cfg, batch: int, seq_len: int, *, window: int = 0,
                    dtype=jnp.bfloat16, quantized: bool = False) -> dict:
    S = min(window, seq_len) if window else seq_len
    kvh, hd = cfg.num_kv_heads, cfg.hd
    if quantized:
        return {"k": jnp.zeros((batch, S, kvh, hd), jnp.int8),
                "v": jnp.zeros((batch, S, kvh, hd), jnp.int8),
                "k_scale": jnp.zeros((batch, S, kvh, 1), jnp.bfloat16),
                "v_scale": jnp.zeros((batch, S, kvh, 1), jnp.bfloat16)}
    return {"k": jnp.zeros((batch, S, kvh, hd), dtype),
            "v": jnp.zeros((batch, S, kvh, hd), dtype)}

"""Shared neural-net building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jax.Array:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (standard; M-RoPE in text mode degenerates to this — all three
# position sections carry the same index, which is exact for text decode).
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_params(key, d: int, f: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d, f), 0, dtype),
        "w3": dense_init(k2, (d, f), 0, dtype),
        "w2": dense_init(k3, (f, d), 0, dtype),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, T, C]; w: [C, K]; b: [C]."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_j x[t - (K-1) + j] * w[:, j]
    out = jnp.zeros_like(x)
    for j in range(k):  # K is tiny (4); unrolled adds, no conv primitive needed
        out = out + xp[:, j:j + x.shape[1], :] * w[:, j]
    return out + b


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token cross entropy, fp32. logits [..., V], targets [...] int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - gold

"""Placement-aware Mixture-of-Experts layer (the paper's technique as a
first-class JAX feature).

Two implementations with identical math:

* ``dense`` — reference oracle: every expert computed for every token,
  combined with the routing weights. Used for correctness tests and tiny
  training runs.
* ``ep`` — expert-parallel SPMD. Every EP rank (the TPU analogue of the
  paper's *edge server*) holds ``S`` expert **slots**; a static
  ``slot_to_expert`` table (produced by the DanceMoE placement algorithms,
  including replication of hot experts) defines what lives where, and
  ``expert_to_target`` routes each source rank's tokens to its *nearest
  replica* by mesh distance. Tokens whose chosen expert is resident at their
  source rank never cross the interconnect — the paper's "local compute
  ratio" becomes the fraction of a2a traffic that stays on-chip.

  Two dispatch modes:
  - ``dispatch`` (train/prefill): capacity-bounded ``all_to_all`` exchange,
    tokens row-sharded over the EP axes.
  - ``gather`` (decode): token counts are tiny (batch <= 128), so tokens are
    all-gathered, each rank computes the (token, expert) pairs assigned to
    it, and a psum combines — far cheaper than a ragged a2a at that scale.

Mesh convention: the tensor/expert-parallel axis is named ``model``; all
other axes (``pod``, ``data``) shard the batch.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, rms_norm

if hasattr(jax, "shard_map"):             # jax >= 0.5
    def _shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _esm

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return _esm(f, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class EPSpec:
    """Static expert-parallel geometry."""
    axes: tuple[str, ...]        # mesh axes forming the EP dimension
    mesh_axes: tuple[str, ...]   # all mesh axis names, in order
    n_ep: int                    # number of EP ranks (product of axes sizes)
    slots: int                   # S: expert slots per rank
    capacity: int                # C: per (src->dst) a2a send capacity
    slot_capacity: int           # C2: per-slot compute capacity (recv side)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a != "model")

    @property
    def dispatch_row_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a not in self.axes) + self.axes

    @staticmethod
    def build(mesh, cfg, *, ep_axes=("model",), capacity_factor: float = 2.0,
              rows_per_rank: int = 4096, slots: int | None = None,
              capacity: int | None = None, slot_capacity: int | None = None):
        n_ep = int(np.prod([mesh.shape[a] for a in ep_axes]))
        S = slots if slots is not None else (
            -(-cfg.num_experts // n_ep) + (1 if n_ep > 1 else 0))
        C = capacity if capacity is not None else max(
            8, int(np.ceil(rows_per_rank * cfg.top_k / n_ep
                           * capacity_factor)))
        C2 = slot_capacity if slot_capacity is not None else max(
            8, int(np.ceil(n_ep * C / S)))
        return EPSpec(tuple(ep_axes), tuple(mesh.axis_names), n_ep, S, C, C2)


class EPPlacement(NamedTuple):
    """Device arrays derived from a placement plan. They are jit *arguments*
    (not compile-time constants), so a migration — adopting a new plan —
    does NOT trigger recompilation."""
    slot_to_expert: jax.Array    # [n_ep, S] int32 (-1 = empty slot)
    expert_to_slot: jax.Array    # [n_ep, E] int32 (-1 = not resident)
    expert_to_target: jax.Array  # [n_ep, E] int32 (src rank -> replica rank)


def uniform_placement(n_ep: int, S: int, E: int) -> EPPlacement:
    """Megatron-style uniform EP layout (the paper's `Uniform` baseline):
    expert e lives on rank e % n_ep; no replication."""
    s2e = -np.ones((n_ep, S), np.int32)
    for e in range(E):
        r, s = e % n_ep, e // n_ep
        if s < S:
            s2e[r, s] = e
    return placement_from_tables(s2e, num_experts=E)


def placement_from_tables(s2e: np.ndarray, mesh_distance=None,
                          num_experts: int | None = None) -> EPPlacement:
    """Build runtime tables from a slot_to_expert matrix [n_ep, S]
    (output of the placement algorithms; -1 = empty slot).

    ``expert_to_target`` picks, per source rank, the nearest replica by
    ``mesh_distance[src, dst]`` (default: ring distance over EP ranks — the
    ICI-hop analogue of the paper's cross-server latency matrix).
    """
    n_ep, S = s2e.shape
    E = num_experts if num_experts is not None else int(s2e.max()) + 1
    e2s = -np.ones((n_ep, E), np.int32)
    for r in range(n_ep):
        for s in range(S):
            e = int(s2e[r, s])
            if e >= 0:
                e2s[r, e] = s
    if mesh_distance is None:
        idx = np.arange(n_ep)
        mesh_distance = np.minimum(np.abs(idx[:, None] - idx[None, :]),
                                   n_ep - np.abs(idx[:, None] - idx[None, :]))
    e2t = np.zeros((n_ep, E), np.int32)
    for e in range(E):
        holders = np.where(e2s[:, e] >= 0)[0]
        if len(holders) == 0:
            raise ValueError(f"expert {e} unplaced (coverage violated)")
        d = mesh_distance[:, holders]                  # [n_ep, n_holders]
        e2t[:, e] = holders[np.argmin(d, axis=1)]
    return EPPlacement(jnp.asarray(s2e.astype(np.int32)),
                       jnp.asarray(e2s), jnp.asarray(e2t))


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def moe_params_dense(key, cfg, dtype=jnp.float32) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), dtype),
        "router": dense_init(ks[0], (d, E), 0, dtype),
        "w1": dense_init(ks[1], (E, d, f), 1, dtype),
        "w3": dense_init(ks[2], (E, d, f), 1, dtype),
        "w2": dense_init(ks[3], (E, f, d), 1, dtype),
    }


def moe_params_ep(key, cfg, spec: EPSpec, dtype=jnp.float32) -> dict:
    """EP-layout params: expert weights stored per (rank, slot)."""
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), dtype),
        "router": dense_init(ks[0], (d, cfg.num_experts), 0, dtype),
        "w1": dense_init(ks[1], (spec.n_ep, spec.slots, d, f), 2, dtype),
        "w3": dense_init(ks[2], (spec.n_ep, spec.slots, d, f), 2, dtype),
        "w2": dense_init(ks[3], (spec.n_ep, spec.slots, f, d), 2, dtype),
    }


def dense_to_ep(dense_p: dict, placement: EPPlacement) -> dict:
    """Materialise EP-layout weights from dense weights + a placement
    (also the migration primitive: a new placement is just a new gather)."""
    s2e = jnp.maximum(placement.slot_to_expert, 0)
    out = {k: dense_p[k] for k in ("norm", "router")}
    for k in ("w1", "w3", "w2"):
        out[k] = dense_p[k][s2e]        # [n_ep, S, ...]
    return out


def regather_ep_groups(dense_groups: dict, placement_stacked,
                       n_groups: int) -> dict:
    """Apply ``dense_to_ep`` per layer group: dense master group params
    (stacked [G, E, ...]) + stacked placement tables -> EP-layout groups.
    Non-MoE groups pass through unchanged."""
    out = {}
    for k, v in dense_groups.items():
        if "router" in v:
            per = [dense_to_ep(jax.tree.map(lambda a: a[g], v),
                               jax.tree.map(lambda a: a[g],
                                            placement_stacked))
                   for g in range(n_groups)]
            out[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        else:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# Routing (shared by both impls — guarantees identical math)
# ---------------------------------------------------------------------------

def route(router_w, h2d, top_k):
    logits = (h2d @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return probs, topv, topi


def aux_load_balance_loss(probs, topi, E):
    """Switch-transformer load-balance loss."""
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(-2)  # [T, E]
    frac = onehot.mean(0)
    mean_prob = probs.mean(0)
    return E * jnp.sum(frac * mean_prob)


def grouped_ffn(x, w1, w3, w2, use_kernel: bool = False):
    """Batched expert FFN: x [S, C, D] x weights [S, D, F] -> [S, C, D]."""
    if use_kernel:
        from repro.kernels.ops import moe_gmm
        return moe_gmm(x, w1, w3, w2)
    a = jnp.einsum("scd,sdf->scf", x, w1)
    b = jnp.einsum("scd,sdf->scf", x, w3)
    hmid = (jax.nn.silu(a) * b).astype(x.dtype)
    return jnp.einsum("scf,sfd->scd", hmid, w2)


# ---------------------------------------------------------------------------
# Dense reference
# ---------------------------------------------------------------------------

def moe_apply_dense(p, cfg, x, *, norm_eps: float = 1e-5):
    """x: [B, T, D]. Returns (out, stats)."""
    B, T, D = x.shape
    h = rms_norm(x, p["norm"], norm_eps).reshape(B * T, D)
    probs, topv, topi = route(p["router"], h, cfg.top_k)
    a = jnp.einsum("td,edf->tef", h, p["w1"])
    b = jnp.einsum("td,edf->tef", h, p["w3"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(a) * b, p["w2"])
    sel = jnp.take_along_axis(y_all, topi[..., None], axis=1)   # [T, K, D]
    y = jnp.einsum("tkd,tk->td", sel, topv.astype(y_all.dtype))
    counts = jax.nn.one_hot(topi, cfg.num_experts, dtype=jnp.float32).sum((0, 1))
    stats = {"counts": counts,
             "counts_per_rank": counts[None],
             "aux_loss": aux_load_balance_loss(probs, topi, cfg.num_experts),
             "local_frac": jnp.float32(1.0)}
    return x + y.reshape(B, T, D).astype(x.dtype), stats


# ---------------------------------------------------------------------------
# Expert-parallel implementation
# ---------------------------------------------------------------------------

def _bucket(keys, n_buckets, capacity):
    """Sort-based capacity bucketing. keys: [N] int in [0, n_buckets]
    (== n_buckets means invalid). Returns (order, pos-in-bucket, keep),
    all aligned with sorted order."""
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    starts = jnp.searchsorted(sk, jnp.arange(n_buckets))
    pos = jnp.arange(keys.shape[0]) - starts[jnp.clip(sk, 0, n_buckets - 1)]
    keep = (sk < n_buckets) & (pos < capacity)
    return order, pos, keep


def _local_slots(p):
    """Per-device slice of the EP weights inside shard_map ([1,S,...]→[S,...])."""
    return {k: p[k][0] for k in ("w1", "w3", "w2")}


def _ep_dispatch_local(h_loc, p, placement, cfg, spec: EPSpec,
                       use_kernel: bool, m_loc=None, o_loc=None):
    """Per-device body (inside shard_map) — a2a dispatch mode.
    h_loc: [R, D] this rank's rows. m_loc: optional [R] float validity —
    0-rows (chunked-prefill padding) are excluded from the gating counts.
    o_loc: optional [R] int32 — the EP rank each row's *request* originated
    at. Gating counts are attributed to it; without it they fall back to
    the physical row-sharding rank (which mis-credits mixed-origin batches
    — the serving runtime always passes the true origin)."""
    R, D = h_loc.shape
    E, K = cfg.num_experts, cfg.top_k
    n_ep, S, C, C2 = spec.n_ep, spec.slots, spec.capacity, spec.slot_capacity
    my = lax.axis_index(spec.axes)
    probs, topv, topi = route(p["router"], h_loc, K)

    flat_e = topi.reshape(R * K)
    flat_w = topv.reshape(R * K)
    flat_src = jnp.repeat(jnp.arange(R), K)
    tgt = placement.expert_to_target[my, flat_e]              # [RK]
    order, pos, keep = _bucket(tgt, n_ep, C)
    dest = jnp.where(keep, tgt[order] * C + pos, n_ep * C)    # OOB = drop
    buf_x = jnp.zeros((n_ep * C, D), h_loc.dtype).at[dest].set(
        h_loc[flat_src[order]], mode="drop")
    buf_e = jnp.full((n_ep * C,), -1, jnp.int32).at[dest].set(
        flat_e[order].astype(jnp.int32), mode="drop")

    recv_x = lax.all_to_all(buf_x.reshape(n_ep, C, D), spec.axes, 0, 0,
                            tiled=False)
    recv_e = lax.all_to_all(buf_e.reshape(n_ep, C), spec.axes, 0, 0,
                            tiled=False)

    # --- receiver: slot bucketing + grouped FFN over the slot buffer ---
    xs = recv_x.reshape(n_ep * C, D)
    es = recv_e.reshape(n_ep * C)
    slot = jnp.where(es >= 0,
                     placement.expert_to_slot[my, jnp.maximum(es, 0)], -1)
    slot_key = jnp.where(slot >= 0, slot, S).astype(jnp.int32)
    order2, pos2, keep2 = _bucket(slot_key, S, C2)
    dest2 = jnp.where(keep2, slot_key[order2] * C2 + pos2, S * C2)
    sbuf = jnp.zeros((S * C2, D), h_loc.dtype).at[dest2].set(
        xs[order2], mode="drop")
    w = _local_slots(p)
    y = grouped_ffn(sbuf.reshape(S, C2, D), w["w1"], w["w3"], w["w2"],
                    use_kernel).reshape(S * C2, D)
    # scatter expert outputs back into recv-buffer order
    got = jnp.where(keep2[:, None],
                    y[jnp.clip(dest2, 0, S * C2 - 1)], 0).astype(h_loc.dtype)
    out_tok = jnp.zeros((n_ep * C, D), h_loc.dtype).at[order2].set(got)

    back = lax.all_to_all(out_tok.reshape(n_ep, C, D), spec.axes, 0, 0,
                          tiled=False).reshape(n_ep * C, D)
    contrib = jnp.where(keep[:, None],
                        back[jnp.clip(dest, 0, n_ep * C - 1)], 0)
    contrib = contrib * flat_w[order][:, None].astype(h_loc.dtype)
    out = jnp.zeros((R, D), h_loc.dtype).at[flat_src[order]].add(contrib)

    # --- stats: f_n(e) per *originating* server. Every row scatter-adds
    # its expert choices into its origin's row of an [n_ep, E] matrix; the
    # full-mesh psum (rows are sharded over every axis) then yields the
    # replicated global attribution — identical totals to the old
    # stacked-per-physical-rank output, but credited correctly under
    # mixed-origin batches. Scalars are pmean'd over the whole mesh. ---
    hot = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    if m_loc is not None:
        hot = hot * m_loc[:, None, None]
    org = o_loc if o_loc is not None else jnp.full((R,), my, jnp.int32)
    counts = jnp.zeros((n_ep, E), jnp.float32).at[org].add(
        hot.sum(1), mode="drop")
    counts = lax.psum(counts, spec.mesh_axes)
    local = lax.pmean(jnp.mean((tgt == my).astype(jnp.float32)),
                      spec.mesh_axes)
    aux = lax.pmean(aux_load_balance_loss(probs, topi, E), spec.mesh_axes)
    return out, counts, local, aux


def _ep_gather_local(h_loc, m_loc, p, placement, cfg, spec: EPSpec,
                     use_kernel: bool, gather_axes: tuple[str, ...],
                     o_loc=None):
    """Per-device body — decode gather mode. h_loc: [R, D] rows sharded over
    the batch axes only (replicated over `model`). m_loc: [R] float row
    validity mask — vacant slots in a continuous-batching pool carry 0 and
    are excluded from the activation statistics (their compute is discarded
    by the caller anyway). o_loc: optional [R] int32 originating EP rank
    per row — stats and the local ratio are attributed to it; without it
    requests "arrive at" the first EP rank of their batch shard."""
    R, D = h_loc.shape
    E, K = cfg.num_experts, cfg.top_k
    n_ep, S, C2 = spec.n_ep, spec.slots, spec.slot_capacity
    my = lax.axis_index(spec.axes)
    h_all = (lax.all_gather(h_loc, gather_axes, tiled=True)
             if gather_axes else h_loc)                        # [Btok, D]
    m_all = (lax.all_gather(m_loc, gather_axes, tiled=True)
             if gather_axes else m_loc)                        # [Btok]
    Btok = h_all.shape[0]
    probs, topv, topi = route(p["router"], h_all, K)
    if o_loc is not None:
        # explicit origin: the edge server each request arrived at
        src_ep = (lax.all_gather(o_loc, gather_axes, tiled=True)
                  if gather_axes else o_loc)                   # [Btok]
    else:
        # positional fallback: requests "arrive at" the first EP rank of
        # their batch shard (the paper's server identity)
        n_gather = max(Btok // R, 1)
        span = max(n_ep // n_gather, 1)
        src_ep = (jnp.arange(Btok) // R) * span                # [Btok]
    flat_e = topi.reshape(-1)
    flat_src = jnp.repeat(jnp.arange(Btok), K)
    tgt = placement.expert_to_target[src_ep[flat_src], flat_e]
    mine = tgt == my
    slot = jnp.where(mine, placement.expert_to_slot[my, flat_e], -1)
    slot_key = jnp.where(slot >= 0, slot, S).astype(jnp.int32)
    order2, pos2, keep2 = _bucket(slot_key, S, C2)
    dest2 = jnp.where(keep2, slot_key[order2] * C2 + pos2, S * C2)
    sbuf = jnp.zeros((S * C2, D), h_loc.dtype).at[dest2].set(
        h_all[flat_src[order2]], mode="drop")
    w = _local_slots(p)
    y = grouped_ffn(sbuf.reshape(S, C2, D), w["w1"], w["w3"], w["w2"],
                    use_kernel).reshape(S * C2, D)
    yw = jnp.where(keep2[:, None],
                   y[jnp.clip(dest2, 0, S * C2 - 1)], 0).astype(h_loc.dtype)
    yw = yw * topv.reshape(-1)[order2][:, None].astype(h_loc.dtype)
    out_all = jnp.zeros((Btok, D), h_loc.dtype).at[flat_src[order2]].add(yw)
    out_all = lax.psum(out_all, spec.axes)
    if gather_axes:
        g_idx = lax.axis_index(gather_axes)
        out = lax.dynamic_slice_in_dim(out_all, g_idx * R, R, 0)
    else:
        out = out_all

    # stats: every EP rank sees the same gathered tokens, so the per-origin
    # [n_ep, E] matrix is computed identically everywhere (replicated over
    # the EP axes); only batch axes outside the gather still shard tokens
    # and need a psum
    valid = m_all[flat_src].astype(jnp.float32)
    counts = jnp.zeros((n_ep, E), jnp.float32).at[src_ep[flat_src]].add(
        jax.nn.one_hot(flat_e, E, dtype=jnp.float32) * valid[:, None],
        mode="drop")
    non_ep = tuple(a for a in spec.mesh_axes
                   if a not in spec.axes and a not in gather_axes)
    if non_ep:
        counts = lax.psum(counts, non_ep)
    local = lax.pmean(
        jnp.sum((tgt == src_ep[flat_src]).astype(jnp.float32) * valid)
        / jnp.maximum(jnp.sum(valid), 1.0), spec.mesh_axes)
    aux = lax.pmean(aux_load_balance_loss(probs, topi, E), spec.mesh_axes)
    return out, counts, local, aux


def moe_apply_ep(p, cfg, x, *, mesh, spec: EPSpec, placement: EPPlacement,
                 mode: str, use_kernel: bool = False,
                 norm_eps: float = 1e-5, seq_sharded_out: bool = False,
                 token_mask=None, origin=None):
    """Placement-aware EP MoE. x: [B, T, D]. Returns (out, stats).

    token_mask: [B] float validity per batch row (decode: vacant
    continuous-batching slots) or [B, T] per token (chunked prefill:
    prompt padding); 0-entries are excluded from the gating statistics.
    origin: [B] or [B, T] int32 — the EP rank each token's *request*
    originated at. ``counts_per_rank[r]`` then holds the gating counts of
    traffic that arrived at server ``r`` regardless of how the rows were
    sharded for compute; without it attribution falls back to the physical
    rank (row-sharding rank in dispatch mode, batch-shard position in
    decode mode), which mis-credits mixed-origin batches."""
    B, T, D = x.shape
    h = rms_norm(x, p["norm"], norm_eps)
    wspec = {
        "router": P(),
        "w1": P(spec.axes, None, None, None),
        "w3": P(spec.axes, None, None, None),
        "w2": P(spec.axes, None, None, None),
    }
    pl_spec = EPPlacement(P(), P(), P())      # tiny tables: replicate
    p_in = {k: p[k] for k in wspec}
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = int(np.prod([sizes[a] for a in spec.batch_axes])) \
        if spec.batch_axes else 1
    rows_shardable = (B * T) % max(n_batch, 1) == 0 and B * T >= n_batch
    batch_row_axes = spec.batch_axes if rows_shardable else ()
    use_origin = origin is not None

    if mode == "decode":
        rows_spec = P(batch_row_axes if batch_row_axes else None, None)
        gather_axes = tuple(a for a in spec.axes if a in batch_row_axes)

        def body(h_loc, m_loc, o_loc, p_loc, pl_loc):
            return _ep_gather_local(h_loc, m_loc, p_loc, pl_loc, cfg, spec,
                                    use_kernel, gather_axes,
                                    o_loc=o_loc if use_origin else None)
    elif seq_sharded_out and T % sizes.get("model", 1) == 0:
        # sequence-parallel residual: h is [B(batch axes), T(model), D].
        # NOTE: flattening two sharded dims globally is NOT a free reshape
        # (block tiling vs b-major order mismatch — measured as a hidden
        # all-gather per MoE layer). Keep the 3-D sharding into shard_map and
        # reshape LOCALLY per device: genuinely free, and the EP rank index
        # (data-major, model-minor) matches the token ownership exactly.
        rows_spec3 = P(batch_row_axes or None, "model", None)

        def body3(h3, p_loc, pl_loc):
            b_, t_, d_ = h3.shape
            o, c, l, a = _ep_dispatch_local(h3.reshape(b_ * t_, d_), p_loc,
                                            pl_loc, cfg, spec, use_kernel)
            return o.reshape(b_, t_, d_), c, l, a

        fn = _shard_map(body3, mesh=mesh,
                        in_specs=(rows_spec3, wspec, pl_spec),
                        out_specs=(rows_spec3, P(), P(), P()))
        out, counts, local, aux = fn(h, p_in, placement)
        stats = {"counts": counts.sum(0), "counts_per_rank": counts,
                 "aux_loss": aux, "local_frac": local}
        return x + out.astype(x.dtype), stats
    else:
        rows_spec = P(spec.dispatch_row_axes, None)

        def body(h_loc, m_loc, o_loc, p_loc, pl_loc):
            # mask excludes chunked-prefill padding from the gating counts
            return _ep_dispatch_local(h_loc, p_loc, pl_loc, cfg, spec,
                                      use_kernel, m_loc=m_loc,
                                      o_loc=o_loc if use_origin else None)

    # counts leave both bodies as a replicated [n_ep, E] per-origin matrix
    out_specs = (rows_spec, P(), P(), P())
    mask_spec = P(rows_spec[0])
    # the row axis must divide evenly over its mesh axes; short batches
    # (e.g. a chunked-prefill geometry whose max_slots * block_size is not
    # a device-count multiple) are padded with masked zero rows instead of
    # pushing a divisibility constraint onto every serving caller. Padding
    # rows route like chunk-padding rows always have (they consume a2a
    # capacity but are masked out of the gating statistics).
    row_axes = rows_spec[0]
    if row_axes:
        axes = row_axes if isinstance(row_axes, tuple) else (row_axes,)
        n_shards = int(np.prod([sizes[a] for a in axes]))
    else:
        n_shards = 1
    pad = (-(B * T)) % n_shards
    rows = h.reshape(B * T, D)
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.zeros((pad, D), rows.dtype)])
    rows = lax.with_sharding_constraint(rows, NamedSharding(mesh, rows_spec))

    def to_rows(v, dtype, pad_value=0):
        vv = v.astype(dtype)
        vv = (vv if vv.ndim == 2 else
              jnp.broadcast_to(vv[:, None], (B, T)))
        vv = vv.reshape(B * T)
        if pad:
            vv = jnp.concatenate(
                [vv, jnp.full((pad,), pad_value, dtype)])
        return lax.with_sharding_constraint(
            vv, NamedSharding(mesh, mask_spec))

    mask_rows = to_rows(token_mask if token_mask is not None
                        else jnp.ones((B, T)), jnp.float32)
    origin_rows = to_rows(origin if use_origin
                          else jnp.zeros((B, T), jnp.int32), jnp.int32)
    fn = _shard_map(body, mesh=mesh,
                    in_specs=(rows_spec, mask_spec, mask_spec, wspec,
                              pl_spec),
                    out_specs=out_specs)
    out_rows, counts, local, aux = fn(rows, mask_rows, origin_rows, p_in,
                                      placement)
    out = out_rows[:B * T].reshape(B, T, D)
    if batch_row_axes and B % n_batch == 0:
        out = lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(batch_row_axes, None, None)))
    stats = {"counts": counts.sum(0), "counts_per_rank": counts,
             "aux_loss": aux, "local_frac": local}
    return x + out.astype(x.dtype), stats

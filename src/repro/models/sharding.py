"""Sharding rules: parameter and cache PartitionSpecs for the production
mesh. Convention: tensor/expert-parallel axis is named ``model``; remaining
axes (``pod``, ``data``) shard the batch (and the sequence for long-context
decode)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

M = "model"


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != M)


# per-leaf specs for each block kind (unstacked; scan groups prepend None)
_ATTN = {
    "norm": P(), "wq": P(None, M), "wk": P(None, M), "wv": P(None, M),
    "wo": P(M, None), "bq": P(M), "bk": P(M), "bv": P(M),
}
_MLP = {"norm": P(), "w1": P(None, M), "w3": P(None, M), "w2": P(M, None)}
_MAMBA1 = {
    "norm": P(), "in_proj": P(None, M), "conv_w": P(M, None), "conv_b": P(M),
    "x_proj": P(M, None), "dt_proj": P(None, M), "dt_bias": P(M),
    "A_log": P(M, None), "D": P(M), "out_proj": P(M, None),
}
_MAMBA2 = {
    "norm": P(), "in_zx": P(None, M), "in_bc": P(), "in_dt": P(None, M),
    "conv_w": P(M, None), "conv_b": P(M), "conv_bc_w": P(), "conv_bc_b": P(),
    "dt_bias": P(M), "A_log": P(M), "D": P(M), "gnorm": P(M),
    "out_proj": P(M, None),
}


def _moe_specs(ep_axes) -> dict:
    e = tuple(ep_axes)
    return {"norm": P(), "router": P(),
            "w1": P(e, None, None, None), "w3": P(e, None, None, None),
            "w2": P(e, None, None, None)}


def _moe_dense_specs() -> dict:
    return {"norm": P(), "router": P(),
            "w1": P(None, None, M), "w3": P(None, None, M),
            "w2": P(None, M, None)}


def block_pspecs(kind: str, *, moe_impl: str = "ep",
                 ep_axes=("model",)) -> dict:
    from repro.configs.base import ATTN, MLP, MOE, MAMBA1, MAMBA2, SHARED_ATTN
    if kind in (ATTN, SHARED_ATTN):
        return dict(_ATTN)
    if kind == MLP:
        return dict(_MLP)
    if kind == MOE:
        return _moe_specs(ep_axes) if moe_impl == "ep" else _moe_dense_specs()
    if kind == MAMBA1:
        return dict(_MAMBA1)
    if kind == MAMBA2:
        return dict(_MAMBA2)
    raise ValueError(kind)


def _prepend(spec: P) -> P:
    return P(*((None,) + tuple(spec)))


def _replicate_all(tree):
    return jax.tree.map(lambda s: P(), tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_pspecs(rt) -> dict:
    """PartitionSpec tree mirroring ``init_params`` output. Layout 'cp'
    (context-parallel) replicates every weight; parallelism then comes from
    batch (data) x sequence (model) activation sharding."""
    cfg = rt.cfg
    pattern, _ = cfg.layer_pattern()
    groups = {}
    for i, kind in enumerate(pattern):
        from repro.configs.base import SHARED_ATTN
        if kind == SHARED_ATTN:
            continue
        blk = block_pspecs(kind, moe_impl=rt.moe_impl, ep_axes=rt.ep_axes)
        groups[f"b{i}"] = {k: _prepend(v) for k, v in blk.items()}
    out = {
        "embed": P(M, None),
        "final_norm": P(),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(None, M)
    from repro.configs.base import SHARED_ATTN
    if SHARED_ATTN in pattern:
        out["shared_attn"] = dict(_ATTN)
    if getattr(rt, "layout", "tp") == "cp":
        return _replicate_all(out)
    if getattr(rt, "layout", "tp") == "fsdp":
        # vocab-sharded embedding would be gathered WHOLE per lookup when
        # the token stream is sequence-sharded (measured: 4.1 GB/step on
        # llama4); shard d_model instead — lookups stay local, only the
        # [*, D] result needs a (6x smaller) gather
        out["embed"] = P(None, M)
        if "lm_head" in out:
            out["lm_head"] = P(M, None)
    return out


def _prune_to(params, specs):
    """Keep only spec entries whose param exists (e.g. optional biases)."""
    if isinstance(params, dict):
        return {k: _prune_to(params[k], specs[k]) for k in params}
    return specs


def pspecs_for(rt, params) -> dict:
    return _prune_to(params, param_pspecs(rt))


def cache_pspecs(rt, *, seq_sharded: bool = False) -> dict:
    """Spec tree mirroring ``init_cache`` output (leading group dim on all)."""
    from repro.configs.base import ATTN, MAMBA1, MAMBA2, SHARED_ATTN
    cfg = rt.cfg
    pattern, _ = cfg.layer_pattern()
    b = tuple(a for a in rt.mesh.axis_names if a != M) if rt.mesh else ()
    if seq_sharded:
        # long-context, batch=1: flash-decoding over the whole mesh
        seq_axes = tuple(b) + (M,)
        kv_spec = P(None, None, seq_axes, None, None)
    else:
        # batch over data axes, sequence over model (flash-decoding):
        # 16x less cache per chip and no per-layer cache resharding
        kv_spec = P(None, b, M, None, None)
    attn_spec = {"k": kv_spec, "v": kv_spec}
    if getattr(rt, "kv_quant", False):
        attn_spec["k_scale"] = kv_spec
        attn_spec["v_scale"] = kv_spec
    m1 = {"conv": P(None, b, None, M), "ssm": P(None, b, M, None)}
    m2 = {"conv_x": P(None, b, None, M), "conv_bc": P(None, b, None, None),
          "ssm": P(None, b, M, None, None)}
    out = {}
    for i, kind in enumerate(pattern):
        if kind in (ATTN, SHARED_ATTN):
            out[f"b{i}"] = dict(attn_spec)
        elif kind == MAMBA1:
            out[f"b{i}"] = dict(m1)
        elif kind == MAMBA2:
            out[f"b{i}"] = dict(m2)
    return out


def shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _feasible_spec(mesh, shape, spec: P) -> P:
    """Drop sharding on any dim the array size can't evenly divide."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    spec = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, s in zip(shape, spec):
        if s is None:
            out.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(s if (dim % n == 0 and dim >= n) else None)
    return P(*out)


def constrain(mesh, tree, spec_tree):
    """with_sharding_constraint with per-leaf feasibility fallback."""
    def one(x, s):
        sp = _feasible_spec(mesh, x.shape, s)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, sp))
    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD, scalar
per-head decay). Train/prefill run a chunked associative scan over time;
decode is an O(1) recurrent state update (no KV cache).

Distribution: the channel dimension d_inner shards over the `model` axis;
the recurrent state [B, d_inner(, ...), N] inherits that sharding, so the
time scan is embarrassingly parallel across chips (the paper's technique
is inapplicable to attention-free archs — see DESIGN §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import causal_conv1d, dense_init, rms_norm


# ---------------------------------------------------------------------------
# Generic chunked linear scan:  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                chunk: int = 256) -> tuple[jax.Array, jax.Array]:
    """a, b: [B, T, ...]; h0: [B, ...]. Returns (h per step [B,T,...], h_T).

    Runs an associative scan within chunks and a sequential scan across
    chunks, bounding peak memory at [B, chunk, ...].
    """
    B, T = b.shape[:2]
    nchunks = -(-T // chunk)
    pad = nchunks * chunk - T
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = a.reshape((B, nchunks, chunk) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    bc = b.reshape((B, nchunks, chunk) + b.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, b.ndim + 1)))

    def combine(x, y):
        (a1, b1), (a2, b2) = x, y
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        aci, bci = xs  # [B, chunk, ...]
        a_acc, b_acc = lax.associative_scan(combine, (aci, bci), axis=1)
        hs = a_acc * h[:, None] + b_acc
        return hs[:, -1], hs

    hT, hs = lax.scan(body, h0, (ac, bc))
    hs = hs.transpose((1, 0, 2) + tuple(range(3, b.ndim + 1)))
    hs = hs.reshape((B, nchunks * chunk) + b.shape[2:])[:, :T]
    return hs, hT


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def mamba1_params(key, cfg, dtype=jnp.float32) -> dict:
    d, di, n, ck = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtr = max(di // 16, 1)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,), dtype),
        "in_proj": dense_init(ks[0], (d, 2 * di), 0, dtype),
        "conv_w": dense_init(ks[1], (di, ck), 1, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), 0, dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), 0, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), 0, dtype),
    }


def _mamba1_core(p, cfg, x, conv_in, h0, *, single_step: bool,
                 use_kernel: bool = False):
    """Shared math. x: [B, T, di] post-in_proj gate split; conv_in: [B, T', di]
    window including left context. Returns (y, hT, new_conv_tail)."""
    di, n = cfg.d_inner, cfg.ssm_state
    dtr = max(di // 16, 1)
    xc = jax.nn.silu(causal_conv1d(conv_in, p["conv_w"], p["conv_b"]))
    xc = xc[:, -x.shape[1]:]                              # drop left context
    proj = xc @ p["x_proj"]
    dt_raw, Bs, Cs = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # [B,T,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # [di,n]
    if use_kernel and not single_step and xc.shape[1] % 32 == 0 \
            and di % 128 == 0:
        from repro.kernels.ops import ssm_scan
        y = ssm_scan(xc, dt, Bs, Cs, A, p["D"].astype(jnp.float32),
                     bd=min(256, di), bt=32)
        # the fused kernel does not emit the final state; only usable when
        # the caller discards it (training)
        return y, h0, xc
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)          # [B,T,di,n]
    b = (dt * xc).astype(jnp.float32)[..., None] * \
        Bs.astype(jnp.float32)[..., None, :]                    # [B,T,di,n]
    if single_step:
        hT = a[:, 0] * h0 + b[:, 0]
        hs = hT[:, None]
    else:
        hs, hT = linear_scan(a, b, h0)
    y = jnp.einsum("btdn,btn->btd", hs, Cs.astype(jnp.float32))
    y = (y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    return y, hT, xc


def mamba1_apply(p, cfg, x, *, mode: str, cache=None, norm_eps: float = 1e-5,
                 use_kernel: bool = False):
    """x: [B, T, D]. cache: {'conv': [B, ck-1, di], 'ssm': [B, di, n]}."""
    B, T = x.shape[:2]
    di, ck, n = cfg.d_inner, cfg.ssm_conv, cfg.ssm_state
    h = rms_norm(x, p["norm"], norm_eps)
    xz = h @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if mode == "train":
        h0 = jnp.zeros((B, di, n), jnp.float32)
        y, _, _ = _mamba1_core(p, cfg, xi, xi, h0, single_step=False,
                               use_kernel=use_kernel)
        new_cache = None
    elif mode == "prefill":
        h0 = jnp.zeros((B, di, n), jnp.float32)
        y, hT, _ = _mamba1_core(p, cfg, xi, xi, h0, single_step=False)
        conv_tail = _conv_tail(xi, ck)
        new_cache = {"conv": conv_tail, "ssm": hT}
    elif mode == "decode":
        conv_in = jnp.concatenate([cache["conv"].astype(xi.dtype), xi], axis=1)
        y, hT, _ = _mamba1_core(p, cfg, xi, conv_in, cache["ssm"],
                                single_step=True)
        new_cache = {"conv": conv_in[:, 1:], "ssm": hT}
    else:
        raise ValueError(mode)
    y = y * jax.nn.silu(z)
    return x + y @ p["out_proj"], new_cache


def _conv_tail(x, ck):
    """Last ck-1 inputs (left-padded with zeros if T < ck-1)."""
    B, T, C = x.shape
    if T >= ck - 1:
        return x[:, T - (ck - 1):]
    return jnp.pad(x, ((0, 0), (ck - 1 - T, 0), (0, 0)))


def init_mamba1_cache(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD: scalar decay per head)
# ---------------------------------------------------------------------------

def mamba2_params(key, cfg, dtype=jnp.float32) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, ck = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,), dtype),
        "in_zx": dense_init(ks[0], (d, 2 * di), 0, dtype),
        "in_bc": dense_init(ks[1], (d, 2 * n), 0, dtype),
        "in_dt": dense_init(ks[2], (d, nh), 0, dtype),
        "conv_w": dense_init(ks[3], (di, ck), 1, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_bc_w": dense_init(ks[4], (2 * n, ck), 1, dtype),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "gnorm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[5], (di, d), 0, dtype),
    }


def _mamba2_core(p, cfg, xi, bc, dt_raw, h0, *, single_step: bool):
    """xi: [B,T,di] (post conv+silu), bc: [B,T,2n] (post conv), dt_raw [B,T,nh].
    State h: [B, nh, hd, n]."""
    n, nh, hd = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, T = xi.shape[:2]
    Bs, Cs = jnp.split(bc.astype(jnp.float32), 2, axis=-1)       # [B,T,n]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [nh]
    a = jnp.exp(dt * A)                                          # [B,T,nh]
    xh = xi.astype(jnp.float32).reshape(B, T, nh, hd)
    b = (dt[..., None, None] * xh[..., None]) * Bs[:, :, None, None, :]
    #     [B,T,nh,hd,n]
    a_b = a[..., None, None]                                     # [B,T,nh,1,1]
    if single_step:
        hT = a_b[:, 0] * h0 + b[:, 0]
        hs = hT[:, None]
    else:
        hs, hT = linear_scan(jnp.broadcast_to(a_b, b.shape), b, h0)
    y = jnp.einsum("bthdn,btn->bthd", hs, Cs)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    return y.reshape(B, T, nh * hd).astype(xi.dtype), hT


def mamba2_apply(p, cfg, x, *, mode: str, cache=None, norm_eps: float = 1e-5):
    B, T = x.shape[:2]
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ck = cfg.ssm_conv
    h = rms_norm(x, p["norm"], norm_eps)
    zx = h @ p["in_zx"]
    z, xi = jnp.split(zx, 2, axis=-1)
    bc = h @ p["in_bc"]
    dt_raw = h @ p["in_dt"]
    if mode == "decode":
        conv_x_in = jnp.concatenate([cache["conv_x"].astype(xi.dtype), xi], 1)
        conv_bc_in = jnp.concatenate([cache["conv_bc"].astype(bc.dtype), bc], 1)
        h0 = cache["ssm"]
    else:
        conv_x_in, conv_bc_in = xi, bc
        h0 = jnp.zeros((B, nh, hd, n), jnp.float32)
    xc = jax.nn.silu(causal_conv1d(conv_x_in, p["conv_w"], p["conv_b"]))[:, -T:]
    bcc = causal_conv1d(conv_bc_in, p["conv_bc_w"], p["conv_bc_b"])[:, -T:]
    y, hT = _mamba2_core(p, cfg, xc, bcc, dt_raw, h0,
                         single_step=(mode == "decode"))
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"], norm_eps)
    out = y @ p["out_proj"]
    if mode == "train":
        new_cache = None
    elif mode == "prefill":
        new_cache = {"conv_x": _conv_tail(xi, ck), "conv_bc": _conv_tail(bc, ck),
                     "ssm": hT}
    else:
        new_cache = {"conv_x": conv_x_in[:, 1:], "conv_bc": conv_bc_in[:, 1:],
                     "ssm": hT}
    return x + out, new_cache


def init_mamba2_cache(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }

"""Synthetic data pipeline: task-conditioned token streams for training and
serving experiments (no external datasets in this offline environment).

Each task draws tokens from its own Zipf-permuted unigram+bigram process, so
(i) models can actually learn structure (loss decreases), and (ii) different
tasks induce different routing distributions — the property the placement
algorithms exploit."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class TaskTokenSource:
    name: str
    vocab_size: int
    seed: int = 0
    zipf: float = 1.1

    def __post_init__(self):
        rng = np.random.default_rng(abs(hash((self.name, self.seed)))
                                    % (2 ** 31))
        V = self.vocab_size
        base = 1.0 / (np.arange(V) + 1.0) ** self.zipf
        self.unigram = base[np.argsort(rng.permutation(V))]
        self.unigram /= self.unigram.sum()
        # sparse bigram preference: each token has a few likely successors
        self.succ = rng.integers(0, V, size=(V, 4))
        self.rng = rng

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = self.rng.choice(self.vocab_size, size=batch, p=self.unigram)
        for t in range(seq_len):
            out[:, t] = cur
            use_bigram = self.rng.random(batch) < 0.7
            succ_pick = self.succ[cur, self.rng.integers(0, 4, batch)]
            fresh = self.rng.choice(self.vocab_size, size=batch,
                                    p=self.unigram)
            cur = np.where(use_bigram, succ_pick, fresh).astype(np.int32)
        return out


def train_batches(vocab_size: int, batch: int, seq_len: int, steps: int,
                  tasks: tuple[str, ...] = ("code", "math", "chat"),
                  seed: int = 0) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (tokens, targets) with examples drawn from a task mixture."""
    sources = [TaskTokenSource(t, vocab_size, seed) for t in tasks]
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        parts = []
        split = np.sort(rng.integers(0, batch + 1, size=len(sources) - 1))
        sizes = np.diff(np.concatenate([[0], split, [batch]]))
        for src, m in zip(sources, sizes):
            if m > 0:
                parts.append(src.sample(int(m), seq_len + 1))
        full = np.concatenate(parts, axis=0)
        rng.shuffle(full)
        yield full[:, :-1], full[:, 1:]


def request_batches(task: str, vocab_size: int, batch: int, prompt_len: int,
                    n_batches: int, seed: int = 0
                    ) -> Iterator[np.ndarray]:
    """Serving-side prompt batches for one task (one edge server's
    traffic)."""
    src = TaskTokenSource(task, vocab_size, seed)
    for _ in range(n_batches):
        yield src.sample(batch, prompt_len)

"""Workload traces: task-conditioned expert-activation patterns and Poisson
request arrivals.

Models the paper's Sec. II-A observations: activation distributions are
(i) heavily skewed *per task* (Fig. 2 — arithmetic vs ASCII-recognition
activate different dominant experts) and (ii) layer-dependent within a task
(Fig. 3 — layer 0 skewed, layer 1 near-uniform). We realize this as
Zipf-shaped distributions whose permutation is task-seeded and whose
exponent varies per (task, layer).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# named after the paper's BIG-bench server specialisations + MultiData setup
BIGBENCH_TASKS = ("abstract_narrative", "arithmetic", "ascii_recognition")
MULTIDATA_TASKS = ("mmlu_pro", "wikitext", "tako")


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    """Per-task activation distributions p[l, e]."""
    name: str
    probs: np.ndarray  # [L, E]


def make_task_profile(name: str, num_layers: int, num_experts: int,
                      seed: int, skew_lo: float = 0.3,
                      skew_hi: float = 1.6) -> TaskProfile:
    rng = np.random.default_rng(abs(hash((name, seed))) % (2 ** 31))
    probs = np.zeros((num_layers, num_experts))
    for l in range(num_layers):
        # layer-dependent skew (Fig. 3): alternate strongly/weakly skewed
        a = skew_lo + (skew_hi - skew_lo) * rng.random()
        z = 1.0 / (np.arange(num_experts) + 1.0) ** a
        perm = rng.permutation(num_experts)
        probs[l] = z[np.argsort(perm)] / z.sum()
    return TaskProfile(name=name, probs=probs)


@dataclasses.dataclass(frozen=True)
class Request:
    arrival: float
    server: int
    task: str
    prompt_tokens: int
    decode_tokens: int


@dataclasses.dataclass
class Workload:
    requests: list[Request]
    tasks: dict[str, TaskProfile]
    duration: float

    def freqs_by_server(self, num_servers: int) -> np.ndarray:
        """Expected f_n^l(e) [L, N, E] implied by the request mix (ground
        truth the scheduler tries to estimate)."""
        any_task = next(iter(self.tasks.values()))
        L, E = any_task.probs.shape
        out = np.zeros((L, num_servers, E))
        for r in self.requests:
            w = r.prompt_tokens + r.decode_tokens
            out[:, r.server, :] += w * self.tasks[r.task].probs
        s = out.sum(-1, keepdims=True)
        return np.where(s > 0, out / np.maximum(s, 1e-12), 1.0 / E)


def poisson_workload(task_per_server: list[str], *, num_layers: int,
                     num_experts: int, mean_interarrival: float = 10.0,
                     duration: float = 1800.0, prompt_tokens: int = 128,
                     decode_tokens: int = 20, seed: int = 0,
                     task_mix: dict[int, dict[str, float]] | None = None
                     ) -> Workload:
    """Poisson arrivals per server; each server draws tasks from its own mix
    (default: the single task assigned to it — the paper's specialised
    setup; pass `task_mix` for heterogeneous mixes)."""
    rng = np.random.default_rng(seed)
    names = sorted(set(task_per_server) |
                   (set().union(*[set(m) for m in task_mix.values()])
                    if task_mix else set()))
    tasks = {t: make_task_profile(t, num_layers, num_experts, seed)
             for t in names}
    reqs: list[Request] = []
    for server, task in enumerate(task_per_server):
        t = 0.0
        while True:
            t += rng.exponential(mean_interarrival)
            if t >= duration:
                break
            if task_mix and server in task_mix:
                mix = task_mix[server]
                choice = rng.choice(list(mix), p=np.array(list(mix.values()))
                                    / sum(mix.values()))
            else:
                choice = task
            pt = max(8, int(rng.normal(prompt_tokens, prompt_tokens / 4)))
            reqs.append(Request(arrival=t, server=server, task=str(choice),
                                prompt_tokens=pt,
                                decode_tokens=decode_tokens))
    reqs.sort(key=lambda r: r.arrival)
    return Workload(requests=reqs, tasks=tasks, duration=duration)


def sample_expert_counts(rng, probs_l: np.ndarray, tokens: int,
                         top_k: int) -> np.ndarray:
    """Sample the number of token-assignments each expert receives in one
    layer for a batch of `tokens` tokens with top_k routing."""
    return rng.multinomial(tokens * top_k, probs_l)

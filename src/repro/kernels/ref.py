"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def moe_gmm_ref(x, w1, w3, w2):
    """Grouped expert FFN. x: [S, C, D]; w1/w3: [S, D, F]; w2: [S, F, D]."""
    a = jnp.einsum("scd,sdf->scf", x, w1)
    b = jnp.einsum("scd,sdf->scf", x, w3)
    mid = (jax.nn.silu(a.astype(jnp.float32))
           * b.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("scf,sfd->scd", mid, w2)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: [BH, Tq, hd]; k, v: [BH, Tk, hd] (kv already expanded to q heads).
    Returns [BH, Tq, hd]."""
    Tq, Tk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(x, dt, Bs, Cs, A, D):
    """Mamba-1 selective scan.
    x, dt: [B, T, d]; Bs, Cs: [B, T, N]; A: [d, N]; D: [d].
    Returns y: [B, T, d] (fp32 math, cast to x.dtype)."""
    B, T, d = x.shape
    N = Bs.shape[-1]
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])  # [B,T,d,N]
    b = (dt * x).astype(jnp.float32)[..., None] * \
        Bs.astype(jnp.float32)[:, :, None, :]

    def step(h, ab):
        at, bt, ct = ab
        h = at * h + bt
        y = (h * ct[:, None, :]).sum(-1)
        return h, y

    h0 = jnp.zeros((B, d, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2, 3), b.transpose(1, 0, 2, 3),
                   Cs.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + D.astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(x.dtype)

"""Jit'd public wrappers around the Pallas kernels.

Each op auto-selects interpret mode on CPU (the validation environment) and
compiles the real TPU kernel otherwise; the pure-jnp oracles live in
``ref.py`` and every kernel is swept against them in tests/.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gmm import moe_gmm as _gmm
from repro.kernels.ssm_scan import ssm_scan as _scan


def moe_gmm(x, w1, w3, w2, **kw):
    """Grouped expert FFN [S, C, D] -> [S, C, D] (used by the EP MoE layer)."""
    return _gmm(x, w1, w3, w2, **kw)


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    """Multi-head attention on [B, T, H, hd] with grouped KV [B, S, KVH, hd].

    Reshapes to the kernel's [BH, T, hd] layout and expands KV to the q
    heads (fused by XLA/Mosaic)."""
    B, Tq, H, hd = q.shape
    kvh = k.shape[2]
    grp = H // kvh
    kx = jnp.repeat(k, grp, axis=2)
    vx = jnp.repeat(v, grp, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    o = _flash(qf, kf, vf, causal=causal, window=window, **kw)
    return o.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)


def ssm_scan(x, dt, Bs, Cs, A, D, **kw):
    """Fused Mamba-1 selective scan (used by the SSM block)."""
    return _scan(x, dt, Bs, Cs, A, D, **kw)

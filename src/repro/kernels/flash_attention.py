"""Pallas TPU kernel: causal / sliding-window flash attention.

Layout: q/k/v are [BH, T, hd] with KV already expanded to the q heads (the
ops.py wrapper handles the GQA grouping — the expansion fuses on TPU).

Grid: (BH, Tq/bq, Tk/bk), KV innermost/sequential. Scratch keeps the running
(max, denom) [bq] and the fp32 output accumulator [bq, hd]; the final KV
step normalises and writes out — the standard one-pass online-softmax
schedule, tiled so one [bq, bk] score block lives in VMEM at a time.
Fully-masked KV blocks (beyond the causal frontier or outside the sliding
window) are skipped with pl.when so the MXU never sees them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, causal: bool, window: int,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: causal => k block must start at or before q block end
    live = k_start >= 0
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _step():
        q = q_ref[0]                                   # [bq, hd]
        k = k_ref[0]                                   # [bk, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256,
                    interpret: bool | None = None):
    """q: [BH, Tq, hd]; k, v: [BH, Tk, hd] -> [BH, Tq, hd]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    BH, Tq, hd = q.shape
    Tk = k.shape[1]
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    nk = Tk // bk
    grid = (BH, Tq // bq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

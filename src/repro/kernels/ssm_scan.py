"""Pallas TPU kernel: fused Mamba-1 selective scan.

Computes y directly from (x, dt, B, C, A, D) without ever materialising the
[B, T, d, N] state trajectory in HBM — the state h [bd, N] lives in a fp32
VMEM scratch that persists across the sequential T grid dimension. The decay
a_t = exp(dt_t * A) and input b_t = (dt_t * x_t) B_t are formed on the fly
per time step inside the kernel (VPU elementwise + small outer products).

Grid: (B, d/bd, T/bt) with T innermost/sequential; channels are
embarrassingly parallel (and shard over the `model` mesh axis one level up).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, h_ref, *,
            bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)            # [bd, N]
    Dp = d_ref[...].astype(jnp.float32)           # [bd]
    x = x_ref[0].astype(jnp.float32)              # [bt, bd]
    dt = dt_ref[0].astype(jnp.float32)            # [bt, bd]
    Bs = b_ref[0].astype(jnp.float32)             # [bt, N]
    Cs = c_ref[0].astype(jnp.float32)             # [bt, N]

    def step(t, carry):
        h, ys = carry
        a_t = jnp.exp(dt[t][:, None] * A)         # [bd, N]
        b_t = (dt[t] * x[t])[:, None] * Bs[t][None, :]
        h = a_t * h + b_t
        y_t = (h * Cs[t][None, :]).sum(-1) + Dp * x[t]
        return h, ys.at[t].set(y_t)

    h0 = h_ref[...]
    ys0 = jnp.zeros((bt,) + h0.shape[:1], jnp.float32)
    h, ys = jax.lax.fori_loop(0, bt, step, (h0, ys0))
    h_ref[...] = h
    o_ref[0] = ys.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bt", "interpret"))
def ssm_scan(x, dt, Bs, Cs, A, D, *, bd: int = 256, bt: int = 64,
             interpret: bool | None = None):
    """x, dt: [B, T, d]; Bs, Cs: [B, T, N]; A: [d, N]; D: [d] -> y [B, T, d]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, T, d = x.shape
    N = Bs.shape[-1]
    bd = min(bd, d)
    bt = min(bt, T)
    assert d % bd == 0 and T % bt == 0
    grid = (B, d // bd, T // bt)
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, i, t: (b, t, i)),  # x
            pl.BlockSpec((1, bt, bd), lambda b, i, t: (b, t, i)),  # dt
            pl.BlockSpec((1, bt, N), lambda b, i, t: (b, t, 0)),   # B
            pl.BlockSpec((1, bt, N), lambda b, i, t: (b, t, 0)),   # C
            pl.BlockSpec((bd, N), lambda b, i, t: (i, 0)),         # A
            pl.BlockSpec((bd,), lambda b, i, t: (i,)),             # D
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda b, i, t: (b, t, i)),
        out_shape=jax.ShapeDtypeStruct((B, T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bs, Cs, A, D)

"""Pallas TPU kernel: grouped expert FFN (the MoE compute hot-spot).

Computes, per expert slot s:  y[s] = (silu(x[s] @ w1[s]) * (x[s] @ w3[s])) @ w2[s]

Grid: (S, C/bc, F/bf) with the F dimension innermost/sequential — each step
loads one [D, bf] tile of w1/w3 and one [bf, D] tile of w2 into VMEM,
accumulating the output tile in a fp32 VMEM scratch (classic K-blocked
matmul with the gated nonlinearity fused between the two matmuls, so the
[C, F] intermediate never touches HBM).

Tiling: bc x bf blocks are MXU-aligned (multiples of 128 whenever the
problem shape allows); D stays resident per block (<= ~12k works in VMEM:
x tile bc*D + three weight tiles D*bf/bf*D + fp32 accumulator bc*D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *, nf: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # [bc, D]
    a = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    b = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
    mid = (jax.nn.silu(a) * b).astype(x.dtype)     # [bc, bf]
    acc_ref[...] += jnp.dot(mid, w2_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def moe_gmm(x, w1, w3, w2, *, bc: int = 128, bf: int = 512,
            interpret: bool | None = None):
    """x: [S, C, D]; w1/w3: [S, D, F]; w2: [S, F, D] -> [S, C, D]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    S, C, D = x.shape
    F = w1.shape[-1]
    bc = min(bc, C)
    bf = min(bf, F)
    assert C % bc == 0 and F % bf == 0, (C, bc, F, bf)
    nf = F // bf
    grid = (S, C // bc, nf)
    return pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda s, c, f: (s, c, 0)),
            pl.BlockSpec((1, D, bf), lambda s, c, f: (s, 0, f)),
            pl.BlockSpec((1, D, bf), lambda s, c, f: (s, 0, f)),
            pl.BlockSpec((1, bf, D), lambda s, c, f: (s, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda s, c, f: (s, c, 0)),
        out_shape=jax.ShapeDtypeStruct((S, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)

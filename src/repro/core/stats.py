"""Activation statistics: the scheduler-side view of f_n^l(e).

``ActivationStats`` accumulates per-(layer, server, expert) activation
counts — fed either by the JAX runtime (``counts_per_rank`` emitted by the
MoE layer) or by the event-driven simulator — and exposes the normalized
frequencies and Shannon entropies that drive Algorithms 1 and 2.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def entropy(p: np.ndarray, axis: int = -1, eps: float = 1e-12) -> np.ndarray:
    """Shannon entropy (bits) of distributions along `axis`."""
    p = np.asarray(p, np.float64)
    s = p.sum(axis=axis, keepdims=True)
    q = p / np.maximum(s, eps)
    h = -(q * np.log2(np.maximum(q, eps))).sum(axis=axis)
    return np.where(s.squeeze(axis) > eps, h, 0.0)


def lemma1_coverage_bound(h_bits: float, num_experts: int,
                          delta: float) -> float:
    """Lemma 1: k_delta > 2^{H(p) - delta * log2 E}."""
    return 2.0 ** (h_bits - delta * np.log2(max(num_experts, 2)))


def coverage_count(p: np.ndarray, delta: float) -> int:
    """Smallest k with top-k mass >= 1 - delta (used to check Lemma 1)."""
    q = np.sort(np.asarray(p, np.float64))[::-1]
    q = q / max(q.sum(), 1e-12)
    cum = np.cumsum(q)
    return int(np.searchsorted(cum, 1.0 - delta) + 1)


@dataclasses.dataclass
class ActivationStats:
    """EMA-tracked activation counts, shape [L, N, E]."""
    num_layers: int
    num_servers: int
    num_experts: int
    decay: float = 0.0            # 0 = plain accumulation; >0 = EMA

    def __post_init__(self):
        self.counts = np.zeros(
            (self.num_layers, self.num_servers, self.num_experts), np.float64)
        self.total_updates = 0

    def update(self, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, N, E] new activation counts."""
        lc = np.asarray(layer_counts, np.float64)
        if self.decay > 0:
            self.counts = self.decay * self.counts + lc
        else:
            self.counts = self.counts + lc
        self.total_updates += 1

    def update_server(self, server: int, layer_counts: np.ndarray) -> None:
        """layer_counts: [L, E] counts for one server (no allocation)."""
        if self.decay > 0:
            self.counts *= self.decay
        self.counts[:, server, :] += layer_counts
        self.total_updates += 1

    def reset(self) -> None:
        self.counts[:] = 0.0
        self.total_updates = 0

    def freqs(self) -> np.ndarray:
        """Normalized f_n^l(e): [L, N, E], each (l, n) row sums to 1
        (uniform if no data observed)."""
        s = self.counts.sum(-1, keepdims=True)
        uniform = np.full_like(self.counts, 1.0 / self.num_experts)
        return np.where(s > 0, self.counts / np.maximum(s, 1e-12), uniform)

    def entropies(self) -> np.ndarray:
        """v_{n,l}: [L, N] Shannon entropy of each server/layer distribution.
        Unobserved (l, n) pairs get maximum entropy (log2 E) — the most
        conservative assumption for count allocation."""
        h = entropy(self.counts, axis=-1)
        unseen = self.counts.sum(-1) <= 0
        return np.where(unseen, np.log2(max(self.num_experts, 2)), h)

"""DanceMoE activation-aware expert placement (Sec. III-C).

Algorithm 1 — layer-wise expert *count* allocation: per-server budgets split
across layers proportionally to activation entropy, then rebalanced so every
layer's system-wide count reaches E_l (expert coverage).

Algorithm 2 — expert-to-server *assignment*: each server greedily takes its
top-N_{n,l} most frequent experts (the (1-1/e)-optimal greedy of Theorem 1),
then a coverage-repair loop places every unassigned expert by replacing the
least-used duplicate on the server currently holding the fewest duplicates.

Both operate on numpy (scheduler-side); ``build_ep_placement`` converts the
result into the stacked per-layer EPPlacement tables consumed by the SPMD
runtime.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stats import entropy


# ---------------------------------------------------------------------------
# Algorithm 1: layer-wise expert count allocation
# ---------------------------------------------------------------------------

def allocate_expert_counts(experts_per_layer: np.ndarray,
                           capacity: np.ndarray,
                           entropies: np.ndarray,
                           max_per_layer: np.ndarray | None = None
                           ) -> np.ndarray:
    """Algorithm 1.

    experts_per_layer: [L] int — E_l.
    capacity:          [N] int — per-server expert-slot budget (M_n / m_e).
    entropies:         [L, N]  — v_{n,l}.
    max_per_layer:     [N] int or None — per-(server, layer) slot cap
                       (the SPMD runtime's S; None = no cap).
    Returns N_{n,l} as [L, N] int.
    """
    E_l = np.asarray(experts_per_layer, int)
    cap = np.asarray(capacity, int)
    v = np.asarray(entropies, float)
    L, N = v.shape
    assert len(E_l) == L and len(cap) == N

    # Step 1: initialize proportional to activation diversity.
    vsum = np.maximum(v.sum(0, keepdims=True), 1e-12)      # [1, N]
    counts = np.floor(cap[None, :] * v / vsum).astype(int)  # [L, N]
    counts = np.minimum(counts, E_l[:, None])
    if max_per_layer is not None:
        counts = np.minimum(counts, np.asarray(max_per_layer, int)[None, :])

    # Step 2: rebalance so each layer reaches its coverage count. Two moves
    # are possible: (a) spend spare server capacity left by the floor in
    # Step 1, (b) borrow a slot from the most over-provisioned layer on the
    # largest-memory server (the paper's loop), preserving memory limits.
    def cap_ok(l, n):
        if counts[l, n] >= E_l[l]:
            return False
        return max_per_layer is None or counts[l, n] < max_per_layer[n]

    for l in range(L):
        guard = 0
        while counts[l].sum() < E_l[l]:
            guard += 1
            if guard > 100000:
                raise RuntimeError("Algorithm 1: rebalancing did not "
                                   f"converge (layer {l})")
            used = counts.sum(0)                      # per-server slot usage
            placed = False
            for n in np.argsort(-cap):                # memory-descending
                if used[n] < cap[n] and cap_ok(l, n):
                    counts[l, n] += 1
                    placed = True
                    break
            if placed:
                continue
            surplus = counts.sum(1) - E_l
            surplus[l] = -10**9
            donor = int(np.argmax(surplus))
            if surplus[donor] <= 0:
                raise RuntimeError(
                    "Algorithm 1 cannot satisfy coverage: total memory too "
                    f"small for layer {l} ({counts[l].sum()} < {E_l[l]})")
            moved = False
            for n in np.argsort(-cap):
                if counts[donor, n] > 0 and cap_ok(l, n):
                    counts[donor, n] -= 1
                    counts[l, n] += 1
                    moved = True
                    break
            if not moved:
                raise RuntimeError(
                    f"Algorithm 1: rebalancing stuck (layer {l})")
    return counts


# ---------------------------------------------------------------------------
# Algorithm 2: expert-to-server assignment
# ---------------------------------------------------------------------------

def assign_experts_layer(n_counts: np.ndarray, freqs: np.ndarray
                         ) -> list[list[int]]:
    """Algorithm 2 for one layer.

    n_counts: [N] int — N_{n,l} from Algorithm 1.
    freqs:    [N, E]  — f_n^l(e).
    Returns per-server expert lists (len == n_counts[n]).
    """
    N, E = freqs.shape
    if int(np.sum(n_counts)) < E:
        raise ValueError(
            f"coverage infeasible: {int(np.sum(n_counts))} slots < {E} "
            "experts (Algorithm 1 must provide sum(N_n,l) >= E_l)")
    # greedy top-N_{n,l} by local activation frequency
    assign = [list(np.argsort(-freqs[n], kind="stable")[: n_counts[n]])
              for n in range(N)]

    def placement_count():
        c = np.zeros(E, int)
        for a in assign:
            for e in a:
                c[e] += 1
        return c

    guard = 0
    while True:
        guard += 1
        if guard > E * N + 10:
            raise RuntimeError("Algorithm 2: coverage repair did not converge")
        pc = placement_count()
        unassigned = [e for e in range(E) if pc[e] == 0]
        if not unassigned:
            break
        # servers ordered by number of duplicates ascending (paper line 7)
        dup_count = [sum(1 for e in assign[n] if pc[e] >= 2) for n in range(N)]
        made_progress = False
        for n in np.argsort(dup_count, kind="stable"):
            pc = placement_count()
            unassigned = [e for e in range(E) if pc[e] == 0]
            if not unassigned:
                break
            # most frequent unassigned expert according to this server
            e_new = max(unassigned, key=lambda e: freqs[n, e])
            if e_new in assign[n]:
                continue
            dups = [e for e in assign[n] if pc[e] >= 2]
            if not dups:
                continue
            e_rep = min(dups, key=lambda e: freqs[n, e])  # least-used dup
            assign[n][assign[n].index(e_rep)] = e_new
            made_progress = True
        if not made_progress:
            # fall back: force onto the server with the most slots
            pc = placement_count()
            unassigned = [e for e in range(E) if pc[e] == 0]
            n = int(np.argmax(n_counts))
            repl = [e for e in assign[n] if pc[e] >= 2] or assign[n]
            e_rep = min(repl, key=lambda e: freqs[n, e])
            assign[n][assign[n].index(e_rep)] = unassigned[0]
    return assign


# ---------------------------------------------------------------------------
# Full pipeline + SPMD table construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlacementPlan:
    """Scheduler-side placement: per-layer per-server expert sets."""
    assign: list[list[list[int]]]     # [L][N] -> expert ids
    counts: np.ndarray                # [L, N]
    num_experts: int

    def slot_tables(self, slots: int,
                    priority: np.ndarray | None = None) -> np.ndarray:
        """[L, N, slots] int32 slot_to_expert (-1 = empty).

        ``priority`` ([L, N, E], lower = hotter — e.g. the tier table from
        ``repro.serving.tiers``) reorders each server's assignment before
        the slot truncation, so when a tiered plan assigns more experts
        than the engine has physical slots, the GPU-tier subset is what
        actually lands in the tables."""
        L = len(self.assign)
        N = len(self.assign[0])
        out = -np.ones((L, N, slots), np.int32)
        for l in range(L):
            for n in range(N):
                ex = self.assign[l][n]
                if priority is not None:
                    ex = sorted(ex, key=lambda e: (priority[l, n, e], e))
                ex = ex[:slots]
                out[l, n, :len(ex)] = ex
        return out

    def residency(self) -> np.ndarray:
        """[L, N, E] 0/1 — expert resident on server?"""
        L, N = self.counts.shape
        r = np.zeros((L, N, self.num_experts), np.float64)
        for l in range(L):
            for n in range(N):
                for e in self.assign[l][n]:
                    r[l, n, e] = 1.0
        return r


def iter_added_experts(old: "PlacementPlan", new: "PlacementPlan"):
    """Yield ``(layer, server, expert)`` for every placement entry present
    in ``new`` but absent from ``old`` — the entries a migration must
    actually move (removals are free: weights are dropped, not
    transferred). Deterministic order: (layer, server, ascending expert).
    Shared by the Eq.-3 estimate (``core.migration.migration_time``) and
    the staged transfer planner (``serving.net.plan_transfers``)."""
    for l, (lo, ln) in enumerate(zip(old.assign, new.assign)):
        for n, (ao, an) in enumerate(zip(lo, ln)):
            for e in sorted(set(an) - set(ao)):
                yield l, n, int(e)


def local_utility(assign_layer: list[list[int]], freqs: np.ndarray) -> float:
    """U_n summed over servers for one layer (Theorem 1's objective)."""
    return float(sum(freqs[n, list(set(a))].sum()
                     for n, a in enumerate(assign_layer)))


def remote_cost(plan: PlacementPlan, freqs: np.ndarray) -> float:
    """Proxy objective Eq. (2): expected remote invocations per token-layer,
    weighted by f_n^l(e). freqs: [L, N, E] (normalized per (l, n))."""
    res = plan.residency()
    return float((freqs * (1.0 - res)).sum())


def dancemoe_placement(freqs: np.ndarray, capacity: np.ndarray,
                       slots_cap: np.ndarray | None = None,
                       fill_spare: bool = True) -> PlacementPlan:
    """The full DanceMoE pipeline (Algorithm 1 + Algorithm 2).

    freqs:    [L, N, E] empirical activation frequencies.
    capacity: [N] per-server total expert-slot budget across all layers.
    slots_cap:[N] per-(server, layer) slot cap (SPMD S), optional.
    fill_spare: fill leftover per-layer slots with each server's next most
      frequent experts (extra replication at zero memory cost — this is what
      maximises U_n once coverage holds).
    """
    L, N, E = freqs.shape
    v = entropy(freqs, axis=-1)                     # [L, N]
    counts = allocate_expert_counts(
        np.full(L, E, int), capacity, v,
        max_per_layer=slots_cap)
    assign = []
    for l in range(L):
        a = assign_experts_layer(counts[l], freqs[l])
        if fill_spare and slots_cap is not None:
            for n in range(N):
                room = int(slots_cap[n]) - len(a[n])
                if room > 0:
                    extra = [e for e in np.argsort(-freqs[l, n], kind="stable")
                             if e not in a[n]][:room]
                    a[n] = a[n] + [int(e) for e in extra]
        assign.append(a)
    return PlacementPlan(assign=assign, counts=counts, num_experts=E)


def effective_dispatch_bytes(plan: PlacementPlan, freqs: np.ndarray,
                             tokens_per_server_layer: float,
                             hidden_bytes: float) -> float:
    """The placement-dependent ICI traffic the static HLO cannot see:
    expected bytes actually crossing the interconnect per step =
    remote fraction (Eq. 2) x dispatched activations x 2 (there and back).
    This is the quantity DanceMoE minimizes — reported alongside the static
    all-to-all operand size in EXPERIMENTS §Perf."""
    L = freqs.shape[0]
    remote_frac = remote_cost(plan, freqs) / max(
        freqs.shape[0] * freqs.shape[1], 1)
    return 2.0 * remote_frac * L * freqs.shape[1] \
        * tokens_per_server_layer * hidden_bytes


def build_ep_placement(plan: PlacementPlan, slots: int, mesh_distance=None,
                       priority: np.ndarray | None = None):
    """Convert a PlacementPlan into stacked per-layer EPPlacement tables
    ([L, n_ep, ...]) for the SPMD runtime. ``priority`` (see
    ``PlacementPlan.slot_tables``) keeps GPU-tier experts in the physical
    slots when the plan over-assigns against a tier hierarchy."""
    import jax
    from repro.models.moe import placement_from_tables
    tables = plan.slot_tables(slots, priority=priority)   # [L, N, S]
    per_layer = [placement_from_tables(tables[l], mesh_distance,
                                       num_experts=plan.num_experts)
                 for l in range(tables.shape[0])]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_layer)

from repro.core.stats import ActivationStats, entropy, lemma1_coverage_bound
from repro.core.placement import (allocate_expert_counts, assign_experts_layer,
                                  dancemoe_placement, build_ep_placement,
                                  PlacementPlan, remote_cost, local_utility)
from repro.core.baselines import (uniform_plan, redundance_plan,
                                  smartmoe_plan, eplb_plan)
from repro.core.migration import (CostModel, MigrationController,
                                  migration_time, should_migrate)
from repro.core.policies import (ClusterView, PlacementController,
                                 PlacementDecision, PlacementPolicy,
                                 as_policy, get_policy, list_policies,
                                 register_policy)
